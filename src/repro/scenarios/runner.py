"""Scenario execution: spec in, structured report out.

`ScenarioRunner` materializes the engine(s) a spec describes, installs the
fault program and background contention on the fabric, drives the workload
for every policy in the ablation list, and reduces the outcome to one
`ScenarioReport`: throughput, latency percentiles, per-rail byte balance,
recovery/stall time after fault onsets, retry/exclusion counters, and the
zero-lost-slice audit. `report.violations` evaluates the spec's declared
expectations, so the regression tests, the benchmark driver, and ad-hoc
experiments all agree on what "this scenario is healthy" means.

Everything runs on the virtual clock from a fixed seed: the same spec always
yields the same report, byte for byte.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core import LinkClass, TentEngine
from ..obs import MetricsRegistry
from .spec import ClusterWorkload, FaultEvent, ScenarioSpec
from .workloads import (
    WorkloadOutcome,
    add_background_turbulence,
    add_tenant_contention,
    run_cluster_workload,
    run_workload,
)

RAIL_FULL_HORIZON = 1e15  # "forever" for rail_bw_factors degradations


@dataclasses.dataclass
class PolicyReport:
    """Metrics for one (scenario, policy) run."""

    policy: str
    ok: bool
    bytes_total: int
    makespan: float
    throughput: float  # bytes/s (closed-loop & checkpoint) or tokens/s (serve)
    requests: int
    latency_p50: float
    latency_p90: float
    latency_p99: float
    retries: int
    exclusions: int
    readmissions: int
    substitutions: int
    batches_failed: int
    lost_slices: int
    rail_imbalance: float  # max/mean bytes over the busiest node's RDMA rails
    recovery_ms: float  # worst post-onset throughput dip (-1 when n/a)
    stall_ms: float  # worst post-onset completion gap (-1 when n/a)
    bytes_by_rail: Dict[str, int]
    buckets_gbps: List[float]
    extra: Dict[str, float]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ScenarioReport:
    scenario: str
    policies: Dict[str, PolicyReport]
    violations: List[str]
    spec: dict

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario,
            "ok": self.ok,
            "violations": list(self.violations),
            "policies": {p: r.to_dict() for p, r in self.policies.items()},
            "spec": self.spec,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


class ScenarioRunner:
    def __init__(self, spec: ScenarioSpec):
        self.spec = spec

    # ------------------------------------------------------------- engine
    def build_engine(self, policy: str,
                     recorder=None) -> Tuple[TentEngine, Set[int]]:
        """One engine with the spec's topology, engine knobs, heterogeneity,
        fault program, and background contention installed. Returns the
        engine plus the batch ids owned by background tenants (excluded from
        the workload audit)."""
        spec = self.spec
        if any(f.is_churn for f in spec.faults):
            raise ValueError(
                "join/leave churn events need a cluster workload "
                "(ClusterWorkload) — a single engine has no membership")
        engine = TentEngine(
            spec.topology.to_fabric_spec(),
            config=spec.engine.to_engine_config(policy),
            seed=spec.seed,
        )
        if recorder is not None:
            # before the environment install, so schedule-time fault records
            # (degradation windows) land in the trace
            engine.attach_recorder(recorder)
        self._install_environment(engine)
        tenant_batches: Set[int] = set()
        bg = spec.background
        if bg.tenant_streams > 0:
            add_tenant_contention(
                engine, streams=bg.tenant_streams, block=bg.tenant_block,
                record=tenant_batches)
        return engine, tenant_batches

    def _install_environment(self, engine: TentEngine) -> None:
        """The spec's fabric-level environment — heterogeneity derating,
        fault program, background turbulence — installed through one engine's
        topology/fabric handles (on a cluster every engine shares them)."""
        spec = self.spec
        for nic_idx, factor in spec.topology.rail_bw_factors:
            for node in range(spec.topology.n_nodes):
                link = engine.topology.rdma_nic(node, nic_idx)
                engine.fabric.schedule_degradation(
                    link.link_id, at=0.0, until=RAIL_FULL_HORIZON, factor=factor)
        for f in spec.faults:
            if not f.is_churn:  # join/leave are cluster events, not wire faults
                self._apply_fault(engine, f)
        bg = spec.background
        if bg.turbulence_severity > 0:
            add_background_turbulence(
                engine, seed=bg.turbulence_seed, horizon=bg.turbulence_horizon,
                severity=bg.turbulence_severity)

    @staticmethod
    def _apply_fault(engine: TentEngine, f: FaultEvent) -> None:
        link = engine.topology.rdma_nic(f.node, f.nic)
        if f.kind == "fail":
            engine.fabric.schedule_failure(link.link_id, at=f.at, recover_at=f.until)
        else:
            engine.fabric.schedule_degradation(
                link.link_id, at=f.at, until=f.until, factor=f.factor)

    # ------------------------------------------------------------- cluster
    def build_cluster(self, policy: str, recorder=None):
        """Materialize the `TentCluster` a ClusterWorkload describes: one
        engine per role on a shared fabric, plus the spec's faults and
        turbulence. Policy names like "tent+diffusion" enable the cluster
        control plane (global load table + failure rumors); plain names run
        the same engines as silos."""
        from ..cluster import ClusterParams, EngineRole, TentCluster

        spec = self.spec
        wl = spec.workload
        base, _, flag = policy.partition("+")
        if flag not in ("", "diffusion"):
            raise ValueError(
                f"unknown cluster policy flag {flag!r} in {policy!r} "
                "(supported: '+diffusion')")
        roles = []
        if wl.pattern == "kv_incast":
            roles += [EngineRole(f"prefill{n}", (n,), base) for n in wl.producer_nodes]
            roles.append(EngineRole("decode", tuple(wl.consumer_nodes), base))
        else:  # ckpt_broadcast
            roles.append(EngineRole("trainer", tuple(wl.producer_nodes), base))
            roles += [EngineRole(f"serving{n}", (n,), base) for n in wl.consumer_nodes]
        if wl.contender_nodes:
            roles.append(EngineRole("cache", tuple(wl.contender_nodes), wl.contender_policy))
        params = ClusterParams(
            diffusion=(flag == "diffusion"),
            global_weight=wl.global_weight,
            diffusion_period=wl.diffusion_period,
            diffusion_staleness=wl.diffusion_staleness,
            gossip_delay=wl.gossip_delay,
            gossip_loss=wl.gossip_loss,
            gossip_link_delay=wl.gossip_link_delay,
            fanout=wl.fanout,
        )
        if spec.background.tenant_streams > 0:
            raise ValueError(
                "background.tenant_streams is not supported for cluster "
                "scenarios — model co-located tenants as the contender role "
                "(ClusterWorkload.contender_nodes)")
        cluster = TentCluster(
            spec.topology.to_fabric_spec(), roles,
            engine_config=spec.engine.to_engine_config(base),
            params=params, seed=spec.seed,
        )
        if recorder is not None:
            cluster.attach_recorder(recorder)
        self._install_environment(next(iter(cluster.engines.values())))
        return cluster

    # ------------------------------------------------------------- one run
    def run_policy(self, policy: str, *, recorder=None) -> PolicyReport:
        """Run one policy. `recorder` optionally attaches a
        `repro.obs.FlightRecorder` before the workload starts; attaching one
        never changes the resulting report (parity-pinned in
        tests/test_obs.py). All three workload kinds surface their engine/
        cluster counters through one `MetricsRegistry` collection, so
        `ScenarioReport.extra` carries a uniform counter surface.

        The whole body runs under `maybe_sanitized()`: with REPRO_SANITIZE=1
        any engine-side wall-clock or global-RNG call raises (the dynamic
        side of the tentlint no-wall-clock/no-global-rng rules); with the
        env var unset this is a nullcontext and costs nothing."""
        from ..analysis.sanitize import maybe_sanitized

        wl = self.spec.workload
        reg = MetricsRegistry()
        with maybe_sanitized():
            if isinstance(wl, ClusterWorkload):
                cluster = self.build_cluster(policy, recorder=recorder)
                base = policy.partition("+")[0]
                churn = tuple(f for f in self.spec.faults if f.is_churn)
                outcome, ignore = run_cluster_workload(
                    cluster, wl, churn, join_policy=base)
                audit = cluster.audit(ignore=ignore)["total"]
                counters = cluster.counters()
                cluster.register_metrics(reg)
                return self._reduce(
                    policy, fabric=cluster.fabric, audit=audit,
                    counters={k: counters[k] for k in
                              ("retries", "exclusions", "readmissions",
                               "substitutions")},
                    outcome=outcome, extra=reg.collect())
            engine, tenant_batches = self.build_engine(policy, recorder=recorder)
            outcome = run_workload(engine, wl)
            engine.register_metrics(reg)
            return self._reduce(
                policy, fabric=engine.fabric,
                audit=engine.audit(ignore=tenant_batches),
                counters={
                    "retries": engine.slices_retried,
                    "exclusions": engine.health.exclusions,
                    "readmissions": engine.health.readmissions,
                    "substitutions": engine.backend_substitutions,
                },
                outcome=outcome,
                extra=reg.collect())

    def run(self) -> ScenarioReport:
        reports = {p: self.run_policy(p) for p in self.spec.policies}
        return ScenarioReport(
            scenario=self.spec.name,
            policies=reports,
            violations=self._violations(reports),
            spec=self.spec.to_dict(),
        )

    # ------------------------------------------------------------- metrics
    def _reduce(
        self,
        policy: str,
        *,
        fabric,
        audit: Dict[str, int],
        counters: Dict[str, int],
        outcome: WorkloadOutcome,
        extra: Optional[Dict[str, float]] = None,
    ) -> PolicyReport:
        """Reduce one policy run (single engine or whole cluster: the audit
        and resilience counters arrive pre-aggregated) to a PolicyReport."""
        lost = audit["slices_outstanding"]
        lat = np.asarray([c[2] for c in outcome.completions])
        p50, p90, p99 = (
            (float(np.percentile(lat, q)) for q in (50, 90, 99))
            if lat.size else (0.0, 0.0, 0.0)
        )
        throughput = outcome.extra.get(
            "input_throughput", outcome.bytes_total / max(outcome.makespan, 1e-12))
        buckets = self._buckets(outcome)
        onsets = sorted(f.at for f in self.spec.faults if f.kind == "fail")
        recovery_ms = self._recovery_ms(buckets, onsets) if onsets else -1.0
        stall_ms = self._stall_ms(outcome, onsets) if onsets else -1.0
        rail_bytes = self._rail_bytes(fabric)
        all_extra = dict(outcome.extra)
        all_extra.update(self._class_bytes(fabric))
        all_extra.update(extra or {})
        return PolicyReport(
            policy=policy,
            ok=audit["batches_failed"] == 0 and lost == 0,
            bytes_total=outcome.bytes_total,
            makespan=outcome.makespan,
            throughput=throughput,
            requests=len(outcome.completions),
            latency_p50=p50, latency_p90=p90, latency_p99=p99,
            retries=counters["retries"],
            exclusions=counters["exclusions"],
            readmissions=counters["readmissions"],
            substitutions=counters["substitutions"],
            batches_failed=audit["batches_failed"],
            lost_slices=lost,
            rail_imbalance=self._imbalance(rail_bytes),
            recovery_ms=recovery_ms,
            stall_ms=stall_ms,
            bytes_by_rail={name: b for (_, name), b in rail_bytes.items()},
            buckets_gbps=buckets,
            extra=all_extra,
        )

    def _buckets(self, outcome: WorkloadOutcome) -> List[float]:
        """Completion-bucketized throughput timeline in GB/s."""
        if not outcome.completions:
            return []
        dt = self.spec.bucket
        end = max(t for t, _, _ in outcome.completions)
        out = np.zeros(int(end / dt) + 1)
        for t, nbytes, _ in outcome.completions:
            out[int(t / dt)] += nbytes
        return list(out / dt / 1e9)

    def _recovery_ms(self, buckets: List[float], onsets: List[float]) -> float:
        """Worst consecutive run of post-onset buckets below 50% of the
        healthy (pre-first-onset) median — fig10's dip-duration metric."""
        if not buckets:
            return -1.0
        dt = self.spec.bucket
        first = int(onsets[0] / dt)
        warm = min(2, first)
        healthy_window = buckets[warm:first]
        if not healthy_window:
            return -1.0
        healthy = float(np.median(healthy_window))
        if healthy <= 0:
            return -1.0
        worst = 0
        for onset in onsets:
            dip = 0
            for v in buckets[int(onset / dt):]:
                if v < 0.5 * healthy:
                    dip += 1
                else:
                    break
            worst = max(worst, dip)
        return worst * dt * 1e3

    # finite "never completed again" sentinel: trips any max_stall_ms
    # expectation while keeping reports strict-JSON (inf would serialize as
    # the non-standard `Infinity` token)
    NEVER_RECOVERED_MS = 1e12

    @classmethod
    def _stall_ms(cls, outcome: WorkloadOutcome, onsets: List[float]) -> float:
        """Worst time from a fault onset to the next successful completion:
        how long the engine takes to resume making progress when capacity
        drops too far for the dip metric to be meaningful."""
        times = sorted(t for t, _, _ in outcome.completions)
        if not times:
            return -1.0
        worst = 0.0
        for onset in onsets:
            i = int(np.searchsorted(np.asarray(times), onset))
            if i >= len(times):
                return cls.NEVER_RECOVERED_MS
            worst = max(worst, times[i] - onset)
        return worst * 1e3

    @staticmethod
    def _rail_bytes(fabric) -> Dict[Tuple[int, str], int]:
        return {
            (l.desc.node, l.desc.name): l.bytes_completed
            for l in fabric.links.values()
            if l.desc.link_class == LinkClass.RDMA
        }

    @staticmethod
    def _class_bytes(fabric) -> Dict[str, float]:
        """Completed bytes per interconnect class ("bytes_rdma", "bytes_ub",
        ...) — how the portability scenarios assert which fabric actually
        carried the traffic."""
        out: Dict[str, float] = {}
        for l in fabric.links.values():
            key = f"bytes_{l.desc.link_class.value}"
            out[key] = out.get(key, 0.0) + float(l.bytes_completed)
        return out

    @staticmethod
    def _imbalance(rail_bytes: Dict[Tuple[int, str], int]) -> float:
        """max/mean byte ratio across the RDMA rails of the busiest node —
        1.0 is a perfect spray; large values mean a few rails carried it all."""
        per_node: Dict[int, List[int]] = {}
        for (node, _), b in rail_bytes.items():
            per_node.setdefault(node, []).append(b)
        busiest = max(per_node.values(), key=sum, default=[])
        if not busiest or sum(busiest) == 0:
            return 0.0
        return max(busiest) / (sum(busiest) / len(busiest))

    # ------------------------------------------------------------- checks
    def _violations(self, reports: Dict[str, PolicyReport]) -> List[str]:
        exp = self.spec.expectations
        primary = reports[self.spec.primary_policy]
        out: List[str] = []
        if exp.zero_lost_slices:
            for p, r in reports.items():
                if r.batches_failed:
                    out.append(f"{p}: {r.batches_failed} app-visible batch failures")
                if r.lost_slices:
                    out.append(f"{p}: {r.lost_slices} slices unaccounted for")
        if exp.tent_vs_baseline > 0:
            for p in self.spec.baseline_policies:
                base = reports[p]
                if primary.throughput < exp.tent_vs_baseline * base.throughput:
                    out.append(
                        f"{primary.policy} throughput {primary.throughput:.3e} < "
                        f"{exp.tent_vs_baseline:.2f} x {p} ({base.throughput:.3e})")
        if exp.max_recovery_ms > 0 and primary.recovery_ms >= 0:
            if primary.recovery_ms > exp.max_recovery_ms:
                out.append(
                    f"{primary.policy} recovery {primary.recovery_ms:.1f} ms > "
                    f"{exp.max_recovery_ms:.0f} ms budget")
        if exp.max_stall_ms > 0 and primary.stall_ms >= 0:
            if primary.stall_ms > exp.max_stall_ms:
                out.append(
                    f"{primary.policy} stall {primary.stall_ms:.1f} ms > "
                    f"{exp.max_stall_ms:.0f} ms budget")
        if exp.max_rail_imbalance > 0 and primary.rail_imbalance > exp.max_rail_imbalance:
            out.append(
                f"{primary.policy} rail imbalance {primary.rail_imbalance:.2f} > "
                f"{exp.max_rail_imbalance:.2f}")
        for attr, factor in (("latency_p99", exp.p99_vs_baseline),
                             ("latency_p50", exp.p50_vs_baseline)):
            if factor <= 0:
                continue
            for p in self.spec.baseline_policies:
                ours, theirs = getattr(primary, attr), getattr(reports[p], attr)
                if theirs > 0 and ours > factor * theirs:
                    out.append(
                        f"{primary.policy} {attr} {ours:.4f}s > "
                        f"{factor:.2f} x {p} ({theirs:.4f}s)")
        # serving SLOs (reported by the serving executors via `extra`)
        if exp.ttft_p90_vs_baseline > 0:
            ours = primary.extra.get("p90_ttft_s", 0.0)
            for p in self.spec.baseline_policies:
                theirs = reports[p].extra.get("p90_ttft_s", 0.0)
                if theirs > 0 and ours > exp.ttft_p90_vs_baseline * theirs:
                    out.append(
                        f"{primary.policy} TTFT P90 {ours:.4f}s > "
                        f"{exp.ttft_p90_vs_baseline:.2f} x {p} ({theirs:.4f}s)")
        for key, limit, label in (
                ("p99_ttft_s", exp.max_ttft_p99_s, "TTFT P99"),
                ("p99_tpot_s", exp.max_tpot_p99_s, "TPOT P99")):
            if limit > 0 and primary.extra.get(key, 0.0) > limit:
                out.append(
                    f"{primary.policy} {label} {primary.extra[key]:.4f}s > "
                    f"{limit:.4f}s SLO")
        return out


def run_scenario(spec: ScenarioSpec) -> ScenarioReport:
    return ScenarioRunner(spec).run()
