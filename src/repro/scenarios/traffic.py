"""Seeded arrival-stream generation: the one source of truth for workload
shape across scenarios, benchmarks, and examples.

Production serving traffic has two statistical signatures the paper's
Table 2 microbenchmarks don't exercise: Poisson arrivals (open-loop — the
world does not wait for the server) and Zipfian popularity over prefix
groups (a few system prompts / hot conversations dominate, a long tail
misses every cache). `TrafficSpec.generate()` derives both from one seed;
the same spec therefore reproduces the same stream in the
`serving_production_stream` scenario, in `benchmarks/serving_scale.py`,
and in any example — and the determinism is pinned by
tests/test_traffic.py (same seed => identical arrays).

`promotion_bytes` folds the stream through the vectorized KV-residency
model the batched serving loop uses: a group's prefix KV stays GPU-resident
for `resident_s` after its last use, so hot Zipf groups re-hit for free
while the tail pays a store->GPU promotion — the transfer-bound elephant
flows TENT sprays. It lives here (not in the simulator) because the jitted
sweep lowering needs the same per-request byte schedule without building a
simulator.

`conversation_tokens` is the legacy multi-turn token-id generator the
sync/async `ServingSimulator` modes and `benchmarks/serving_closed_loop.py`
share (it used to be inlined in each).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

__all__ = [
    "TrafficSpec",
    "TrafficStream",
    "conversation_tokens",
    "promotion_bytes",
]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Declarative request-stream shape. JSON round-trippable like the rest
    of the scenario vocabulary."""

    requests: int
    arrival_rate: float  # mean arrivals/s (Poisson process)
    zipf_alpha: float = 1.1  # popularity skew over prefix groups
    groups: int = 64  # distinct prefix groups (system prompts / convos)
    input_tokens: int = 1024  # mean prompt length
    output_tokens: int = 64  # decode length (fixed per request)
    input_jitter: float = 0.25  # relative spread of prompt lengths
    seed: int = 0

    def __post_init__(self) -> None:
        if self.requests < 0:
            raise ValueError("requests must be >= 0")
        if self.requests > 0 and self.arrival_rate <= 0:
            raise ValueError("arrival_rate must be > 0")
        if self.zipf_alpha <= 0 or self.groups < 1:
            raise ValueError("need zipf_alpha > 0 and groups >= 1")

    def generate(self) -> "TrafficStream":
        n = self.requests
        rng = np.random.default_rng(self.seed)
        if n == 0:
            z = np.zeros(0)
            return TrafficStream(
                spec=self, arrival=z, group=z.astype(np.int64),
                input_tokens=z.astype(np.int64),
                output_tokens=z.astype(np.int64))
        arrival = np.cumsum(rng.exponential(1.0 / self.arrival_rate, size=n))
        ranks = np.arange(1, self.groups + 1, dtype=np.float64)
        weights = ranks ** -self.zipf_alpha
        weights /= weights.sum()
        group = rng.choice(self.groups, size=n, p=weights).astype(np.int64)
        itok = np.maximum(
            16,
            np.rint(self.input_tokens *
                    (1.0 + self.input_jitter * rng.standard_normal(n))),
        ).astype(np.int64)
        otok = np.full(n, self.output_tokens, dtype=np.int64)
        return TrafficStream(
            spec=self, arrival=arrival, group=group,
            input_tokens=itok, output_tokens=otok)

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class TrafficStream:
    """One generated stream: parallel per-request arrays, arrival-sorted."""

    spec: TrafficSpec
    arrival: np.ndarray  # float64 (N,) seconds from stream start
    group: np.ndarray  # int64 (N,) prefix-group id
    input_tokens: np.ndarray  # int64 (N,)
    output_tokens: np.ndarray  # int64 (N,)

    def __len__(self) -> int:
        return int(self.arrival.shape[0])


def promotion_bytes(stream: TrafficStream, *, prefix_frac: float,
                    kv_bytes_per_token: int,
                    resident_s: float) -> np.ndarray:
    """Per-request store->GPU promotion bytes under the group-residency
    model: a request promotes its group's prefix KV (`prefix_frac` of its
    prompt, in `kv_bytes_per_token` units) iff the group is cold — first
    appearance, or more than `resident_s` since the group's previous
    request evicted it from GPU HBM. Pure function of the stream, so the
    scheduler-independent byte schedule can also be lowered into the jitted
    sweep skeleton."""
    n = len(stream)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    order = np.lexsort((stream.arrival, stream.group))
    g = stream.group[order]
    a = stream.arrival[order]
    cold = np.empty(n, dtype=bool)
    cold[0] = True
    same = g[1:] == g[:-1]
    cold[1:] = ~same | ((a[1:] - a[:-1]) > resident_s)
    promote = np.empty(n, dtype=bool)
    promote[order] = cold
    prefix_tokens = np.rint(stream.input_tokens * prefix_frac).astype(np.int64)
    return np.where(promote, prefix_tokens * int(kv_bytes_per_token),
                    0).astype(np.int64)


def conversation_tokens(clients: int, turns: int, input_tokens: int,
                        seed: int) -> Dict[int, List[int]]:
    """Per-client multi-turn token-id streams for the sync/async serving
    modes: client `c` holds `turns * input_tokens` token ids; turn `k`
    prefixes the first `k * input_tokens` of them (shared history => HiCache
    prefix hits). One seeded generator for all clients keeps the draw order
    stable however the caller iterates."""
    rng = np.random.default_rng(seed)
    return {
        c: rng.integers(1, 50_000, size=turns * input_tokens).tolist()
        for c in range(clients)
    }
