"""Workload executors and background-contention generators.

Everything here drives a `TentEngine` on its virtual clock and reports a
uniform `WorkloadOutcome` (completion timeline + byte totals + audit), so the
`ScenarioRunner` can compute the same metrics for very different workloads.
`benchmarks/common.py` re-exports the generators so TEBench scripts and the
scenario matrix share one implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core import Location, MemoryKind, TentEngine
from .spec import CheckpointWorkload, ClosedLoopWorkload, ServeWorkload, Workload

EVENT_BUDGET = 60_000_000


@dataclasses.dataclass
class WorkloadOutcome:
    """What one policy-run of one workload produced, before metric reduction."""

    completions: List[Tuple[float, int, float]]  # (t_end, nbytes, latency)
    bytes_total: int
    makespan: float
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Segment placement helpers
# ---------------------------------------------------------------------------


def host_loc(node: int, numa: int = 0) -> Location:
    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


def gpu_loc(engine: TentEngine, node: int, gpu: int) -> Location:
    spec = engine.topology.spec
    return Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu,
                    numa=spec.node.gpu_numa(gpu))


def _cyc(t: Tuple[int, ...], i: int) -> int:
    return t[i % len(t)]


def _stream_endpoints(engine: TentEngine, wl: ClosedLoopWorkload, i: int):
    src_node, dst_node = _cyc(wl.src_nodes, i), _cyc(wl.dst_nodes, i)
    block = _cyc(wl.blocks, i)
    if wl.endpoints == "gpu":
        n_gpus = engine.topology.spec.node.n_gpus
        src = gpu_loc(engine, src_node, i % n_gpus)
        dst = gpu_loc(engine, dst_node, i % n_gpus)
    elif wl.endpoints == "host":
        src = host_loc(src_node, _cyc(wl.src_numa, i))
        dst = host_loc(dst_node, _cyc(wl.dst_numa, i))
    else:
        raise ValueError(f"unknown endpoints kind {wl.endpoints!r}")
    s = engine.register_segment(src, block, materialize=False)
    d = engine.register_segment(dst, block, materialize=False)
    return s, d, block


# ---------------------------------------------------------------------------
# Closed-loop (TEBench) executor
# ---------------------------------------------------------------------------


def drive_closed_loop(
    engine: TentEngine,
    streams: List[Tuple[int, int, int]],  # (src_seg_id, dst_seg_id, block_bytes)
    *,
    iters: int,
    batch_size: int = 1,
    duration: float = 0.0,
) -> WorkloadOutcome:
    """The TEBench submission loop: each stream keeps exactly one batch of
    `batch_size` transfers in flight, resubmitting on completion — `iters`
    times, or until `duration` on the virtual clock when set. Shared by the
    scenario runner and benchmarks/common.py."""
    completions: List[Tuple[float, int, float]] = []
    pending: Set[int] = set()
    done = [0] * len(streams)
    bytes_total = 0
    t_start = engine.fabric.now
    timed = duration > 0
    deadline = t_start + duration  # duration is relative to the current clock

    def submit(i: int) -> None:
        nonlocal bytes_total
        if timed and engine.fabric.now >= deadline:
            return
        src, dst, block = streams[i]
        b = engine.allocate_batch()
        t0 = engine.fabric.now
        engine.submit_transfer(b, [(src, 0, dst, 0, block)] * batch_size)
        pending.add(b)
        bytes_total += block * batch_size

        def on_done(res, i=i, b=b, t0=t0, block=block):
            pending.discard(b)
            completions.append((engine.fabric.now, block * batch_size,
                                engine.fabric.now - t0))
            done[i] += 1
            if timed or done[i] < iters:
                submit(i)

        engine.on_batch_done(b, on_done)

    for i in range(len(streams)):
        submit(i)

    def active() -> bool:
        if pending:
            return True
        return (not timed) and any(d < iters for d in done)

    guard = 0
    while active():
        if not engine.fabric.step():
            raise RuntimeError("fabric idle before workload completed")
        guard += 1
        if guard > EVENT_BUDGET:
            raise RuntimeError("workload event budget exceeded")
    return WorkloadOutcome(
        completions=completions,
        bytes_total=bytes_total,
        makespan=engine.fabric.now - t_start,
    )


def run_closed_loop(engine: TentEngine, wl: ClosedLoopWorkload) -> WorkloadOutcome:
    streams = []
    for i in range(wl.streams):
        src, dst, block = _stream_endpoints(engine, wl, i)
        streams.append((src.segment_id, dst.segment_id, block))
    return drive_closed_loop(
        engine, streams, iters=wl.iters, batch_size=wl.batch_size,
        duration=wl.duration)


# ---------------------------------------------------------------------------
# HiCache serving executor
# ---------------------------------------------------------------------------


def run_serve(engine: TentEngine, wl: ServeWorkload) -> WorkloadOutcome:
    from ..configs import get_config
    from ..serving import (
        HiCache,
        ServeSimConfig,
        ServingSimulator,
        from_table2,
        kv_bytes_per_token,
        make_cpu_pool,
        make_disk_pool,
        make_gpu_pool,
    )

    cfg = get_config(wl.model)
    hc: Optional[HiCache] = None
    if wl.use_hicache:
        pb = kv_bytes_per_token(cfg) * wl.page_tokens
        turns_pages = wl.turns * wl.input_tokens // wl.page_tokens + 2
        hc = HiCache(
            engine, cfg,
            gpu_pool=make_gpu_pool(engine, wl.gpu_node, 0, page_bytes=pb,
                                   num_pages=3 * turns_pages, materialize=False),
            cpu_pool=make_cpu_pool(engine, wl.store_node, page_bytes=pb,
                                   num_pages=wl.clients * turns_pages + 8,
                                   materialize=False),
            disk_pool=make_disk_pool(engine, wl.store_node, page_bytes=pb,
                                     num_pages=wl.clients * turns_pages + 8,
                                     materialize=False),
            page_tokens=wl.page_tokens,
        )
    sim = ServingSimulator(
        engine, from_table2(), hicache=hc,
        sim_cfg=ServeSimConfig(
            clients=wl.clients, concurrency=wl.concurrency, turns=wl.turns,
            input_tokens=wl.input_tokens, output_tokens=wl.output_tokens,
        ),
    )
    t0 = engine.fabric.now
    st = sim.run()
    extra = {
        "input_throughput": st.input_throughput,
        "avg_ttft_s": st.avg_ttft,
        "p50_ttft_s": st.p50_ttft,
        "p90_ttft_s": st.p90_ttft,
        "p99_ttft_s": st.p99_ttft,
        "bytes_promoted": float(st.bytes_promoted),
    }
    for r, v in st.round_avg_ttft.items():
        extra[f"round_avg_ttft_R{r}"] = v
    return WorkloadOutcome(
        completions=[],
        bytes_total=st.bytes_promoted,
        makespan=engine.fabric.now - t0 if engine.fabric.now > t0 else st.makespan,
        extra=extra,
    )


# ---------------------------------------------------------------------------
# Checkpoint-broadcast executor
# ---------------------------------------------------------------------------


def run_checkpoint(engine: TentEngine, wl: CheckpointWorkload) -> WorkloadOutcome:
    from ..serving import CheckpointEngine

    ce = CheckpointEngine(
        engine, nodes=wl.nodes, gpus_per_node=wl.gpus_per_node,
        source_node=wl.source_node, materialize=False,
    )
    ce.register_checkpoint({"ckpt": wl.nbytes})
    t0 = engine.fabric.now
    res = ce.update()
    return WorkloadOutcome(
        completions=[(engine.fabric.now, res.bytes, res.seconds)],
        bytes_total=res.bytes,
        makespan=res.seconds,
        extra={
            "update_seconds": res.seconds,
            "aggregate_bandwidth": res.aggregate_bandwidth,
            "ranks": float(res.ranks),
        },
    )


def run_workload(engine: TentEngine, wl: Workload) -> WorkloadOutcome:
    if isinstance(wl, ClosedLoopWorkload):
        return run_closed_loop(engine, wl)
    if isinstance(wl, ServeWorkload):
        return run_serve(engine, wl)
    if isinstance(wl, CheckpointWorkload):
        return run_checkpoint(engine, wl)
    raise TypeError(f"unknown workload {type(wl).__name__}")


# ---------------------------------------------------------------------------
# Background contention generators (shared with benchmarks/common.py)
# ---------------------------------------------------------------------------


def add_background_turbulence(engine: TentEngine, *, seed: int = 7,
                              horizon: float = 60.0, severity: float = 0.5) -> None:
    """Transient per-rail slowdowns (noisy neighbours / signal degradation,
    paper §2.2): deterministic schedule of degradation windows on RDMA rails."""
    rng = np.random.default_rng(seed)
    for node in range(engine.topology.spec.n_nodes):
        for nic in engine.topology.rdma_nics(node):
            # windows cover t=0 onward so short virtual-time experiments see
            # the same non-uniform fabric that long-running services do
            t = 0.0
            while t < horizon:
                dur = float(rng.uniform(0.05, 0.5))
                if rng.random() < 0.4:
                    factor = float(rng.uniform(1 - severity, 0.9))
                    engine.fabric.schedule_degradation(nic.link_id, at=t, until=t + dur, factor=factor)
                t += dur + float(rng.uniform(0.0, 0.3))


def add_tenant_contention(engine: TentEngine, *, streams: int = 4,
                          block: int = 64 << 20, horizon: float = 1e12,
                          record: Optional[Set[int]] = None) -> None:
    """Co-located tenants saturating the same rails (paper §2.2 "noisy
    neighbours"): closed-loop host-to-host elephant flows that run for the
    whole experiment, scheduled through the same engine/fabric. Batch ids are
    added to `record` so audits can separate tenant traffic from the workload
    under test."""
    for i in range(streams):
        numa = i % 2
        src = engine.register_segment(host_loc(0, numa), block, materialize=False)
        dst = engine.register_segment(host_loc(1, numa), block, materialize=False)

        def pump(src=src, dst=dst):
            if engine.fabric.now >= horizon:
                return
            b = engine.allocate_batch()
            engine.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, block)])
            if record is not None:
                record.add(b)
            engine.on_batch_done(b, lambda res: pump())

        pump()
