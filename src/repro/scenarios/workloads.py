"""Workload executors and background-contention generators.

Everything here drives a `TentEngine` on its virtual clock and reports a
uniform `WorkloadOutcome` (completion timeline + byte totals + audit), so the
`ScenarioRunner` can compute the same metrics for very different workloads.
`benchmarks/common.py` re-exports the generators so TEBench scripts and the
scenario matrix share one implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core import Location, MemoryKind, TentEngine
from .spec import (
    CheckpointWorkload,
    ClosedLoopWorkload,
    ClusterWorkload,
    FaultEvent,
    ServeWorkload,
    ServingWorkload,
    Workload,
)

EVENT_BUDGET = 60_000_000


@dataclasses.dataclass
class WorkloadOutcome:
    """What one policy-run of one workload produced, before metric reduction."""

    completions: List[Tuple[float, int, float]]  # (t_end, nbytes, latency)
    bytes_total: int
    makespan: float
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Segment placement helpers
# ---------------------------------------------------------------------------


def host_loc(node: int, numa: int = 0) -> Location:
    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


def gpu_loc(engine: TentEngine, node: int, gpu: int) -> Location:
    spec = engine.topology.spec
    return Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu,
                    numa=spec.node.gpu_numa(gpu))


def _cyc(t: Tuple[int, ...], i: int) -> int:
    return t[i % len(t)]


def _stream_endpoints(engine: TentEngine, wl: ClosedLoopWorkload, i: int):
    src_node, dst_node = _cyc(wl.src_nodes, i), _cyc(wl.dst_nodes, i)
    block = _cyc(wl.blocks, i)
    if wl.endpoints == "gpu":
        n_gpus = engine.topology.spec.node.n_gpus
        src = gpu_loc(engine, src_node, i % n_gpus)
        dst = gpu_loc(engine, dst_node, i % n_gpus)
    elif wl.endpoints == "host":
        src = host_loc(src_node, _cyc(wl.src_numa, i))
        dst = host_loc(dst_node, _cyc(wl.dst_numa, i))
    else:
        raise ValueError(f"unknown endpoints kind {wl.endpoints!r}")
    s = engine.register_segment(src, block, materialize=False)
    d = engine.register_segment(dst, block, materialize=False)
    return s, d, block


# ---------------------------------------------------------------------------
# Closed-loop (TEBench) executor
# ---------------------------------------------------------------------------


class StreamDriver:
    """The generalized TEBench submission loop on one (possibly shared)
    fabric: each stream is (owning engine, [(src_seg, dst_seg, nbytes), ...])
    and keeps exactly one batch of those transfers in flight, resubmitting on
    completion — `iters` times, or until `duration` on the virtual clock when
    set. Single-engine closed loops and multi-engine cluster workloads both
    reduce to this.

    Streams may be added while the loop is running (`add_stream` from a
    scheduled callback) — that is how an engine joining the cluster mid-run
    starts producing. The `alive` predicate is consulted before every
    (re)submission, so a departed engine's streams stop pumping the moment
    it leaves while its in-flight batches still drain and count.
    `hold_until` keeps the loop stepping through quiet gaps up to a known
    future event (e.g. a join scheduled after the current work drains)."""

    def __init__(
        self,
        fabric,
        *,
        iters: int,
        duration: float = 0.0,
        alive: Optional[Callable[[TentEngine], bool]] = None,
    ):
        self.fabric = fabric
        self.iters = iters
        self.timed = duration > 0
        self.alive = alive or (lambda engine: True)
        self.completions: List[Tuple[float, int, float]] = []
        self.bytes_total = 0
        self._pending: Set[int] = set()
        self._streams: List[Tuple[TentEngine, List[Tuple[int, int, int]]]] = []
        self._done: List[int] = []
        self._t_start = fabric.now
        self._deadline = self._t_start + duration  # relative to current clock
        self._hold = self._t_start

    def add_stream(
        self, engine: TentEngine, transfers: List[Tuple[int, int, int]]
    ) -> None:
        self._streams.append((engine, transfers))
        self._done.append(0)
        self._submit(len(self._streams) - 1)

    def hold_until(self, t: float) -> None:
        """Keep the loop alive at least to virtual time `t` (a scheduled
        churn event), even if all in-flight work drains first."""
        self._hold = max(self._hold, t)

    def _submit(self, i: int) -> None:
        if self.timed and self.fabric.now >= self._deadline:
            return
        eng, transfers = self._streams[i]
        if not self.alive(eng):
            return
        nbytes = sum(t[2] for t in transfers)
        b = eng.allocate_batch()
        t0 = self.fabric.now
        eng.submit_transfer(b, [(s, 0, d, 0, n) for (s, d, n) in transfers])
        self._pending.add(b)
        self.bytes_total += nbytes

        def on_done(res, i=i, b=b, t0=t0, nbytes=nbytes):
            self._pending.discard(b)
            self.completions.append(
                (self.fabric.now, nbytes, self.fabric.now - t0))
            self._done[i] += 1
            if self.timed or self._done[i] < self.iters:
                self._submit(i)

        eng.on_batch_done(b, on_done)

    def _active(self) -> bool:
        if self._pending or self.fabric.now < self._hold:
            return True
        if self.timed:
            return False
        return any(
            d < self.iters
            for (eng, _), d in zip(self._streams, self._done)
            if self.alive(eng)
        )

    def run(self) -> WorkloadOutcome:
        guard = 0
        while self._active():
            if not self.fabric.step():
                raise RuntimeError("fabric idle before workload completed")
            guard += 1
            if guard > EVENT_BUDGET:
                raise RuntimeError("workload event budget exceeded")
        return WorkloadOutcome(
            completions=self.completions,
            bytes_total=self.bytes_total,
            makespan=self.fabric.now - self._t_start,
        )


def drive_streams(
    fabric,
    streams: List[Tuple[TentEngine, List[Tuple[int, int, int]]]],
    *,
    iters: int,
    duration: float = 0.0,
) -> WorkloadOutcome:
    """Static-stream convenience wrapper over `StreamDriver`."""
    driver = StreamDriver(fabric, iters=iters, duration=duration)
    for eng, transfers in streams:
        driver.add_stream(eng, transfers)
    return driver.run()


def drive_closed_loop(
    engine: TentEngine,
    streams: List[Tuple[int, int, int]],  # (src_seg_id, dst_seg_id, block_bytes)
    *,
    iters: int,
    batch_size: int = 1,
    duration: float = 0.0,
) -> WorkloadOutcome:
    """The single-engine TEBench loop: each stream keeps one batch of
    `batch_size` identical transfers in flight. Shared by the scenario
    runner and benchmarks/common.py."""
    flat = [
        (engine, [(src, dst, block)] * batch_size) for (src, dst, block) in streams
    ]
    return drive_streams(engine.fabric, flat, iters=iters, duration=duration)


def run_closed_loop(engine: TentEngine, wl: ClosedLoopWorkload) -> WorkloadOutcome:
    streams = []
    for i in range(wl.streams):
        src, dst, block = _stream_endpoints(engine, wl, i)
        streams.append((src.segment_id, dst.segment_id, block))
    return drive_closed_loop(
        engine, streams, iters=wl.iters, batch_size=wl.batch_size,
        duration=wl.duration)


# ---------------------------------------------------------------------------
# HiCache serving executor
# ---------------------------------------------------------------------------


def run_serve(engine: TentEngine, wl: ServeWorkload) -> WorkloadOutcome:
    from ..configs import get_config
    from ..serving import (
        HiCache,
        ServeSimConfig,
        ServingSimulator,
        from_table2,
        kv_bytes_per_token,
        make_cpu_pool,
        make_disk_pool,
        make_gpu_pool,
    )

    cfg = get_config(wl.model)
    hc: Optional[HiCache] = None
    if wl.use_hicache:
        pb = kv_bytes_per_token(cfg) * wl.page_tokens
        turns_pages = wl.turns * wl.input_tokens // wl.page_tokens + 2
        hc = HiCache(
            engine, cfg,
            gpu_pool=make_gpu_pool(engine, wl.gpu_node, 0, page_bytes=pb,
                                   num_pages=3 * turns_pages, materialize=False),
            cpu_pool=make_cpu_pool(engine, wl.store_node, page_bytes=pb,
                                   num_pages=wl.clients * turns_pages + 8,
                                   materialize=False),
            disk_pool=make_disk_pool(engine, wl.store_node, page_bytes=pb,
                                     num_pages=wl.clients * turns_pages + 8,
                                     materialize=False),
            page_tokens=wl.page_tokens,
        )
    sim = ServingSimulator(
        engine, from_table2(), hicache=hc,
        sim_cfg=ServeSimConfig(
            clients=wl.clients, concurrency=wl.concurrency, turns=wl.turns,
            input_tokens=wl.input_tokens, output_tokens=wl.output_tokens,
        ),
    )
    t0 = engine.fabric.now
    st = sim.run()
    extra = {
        "input_throughput": st.input_throughput,
        "avg_ttft_s": st.avg_ttft,
        "p50_ttft_s": st.p50_ttft,
        "p90_ttft_s": st.p90_ttft,
        "p99_ttft_s": st.p99_ttft,
        "bytes_promoted": float(st.bytes_promoted),
    }
    for r, v in st.round_avg_ttft.items():
        extra[f"round_avg_ttft_R{r}"] = v
    return WorkloadOutcome(
        completions=list(st.request_log),
        bytes_total=st.bytes_promoted,
        makespan=engine.fabric.now - t0 if engine.fabric.now > t0 else st.makespan,
        extra=extra,
    )


# ---------------------------------------------------------------------------
# Closed-loop serving executor (event-driven, async transfer intents)
# ---------------------------------------------------------------------------


def run_serving(engine: TentEngine, wl: ServingWorkload) -> WorkloadOutcome:
    from ..configs import get_config
    from ..serving import (
        CheckpointEngine,
        HiCache,
        ServeSimConfig,
        ServingSimulator,
        from_table2,
        kv_bytes_per_token,
        make_cpu_pool,
        make_disk_pool,
        make_gpu_pool,
    )

    if wl.stream_requests > 0:
        return _run_serving_stream(engine, wl)

    cfg = get_config(wl.model)
    hc: Optional[HiCache] = None
    if wl.use_hicache:
        pb = kv_bytes_per_token(cfg) * wl.page_tokens
        turns_pages = wl.turns * wl.input_tokens // wl.page_tokens + 2
        hc = HiCache(
            engine, cfg,
            gpu_pool=make_gpu_pool(engine, wl.gpu_node, 0, page_bytes=pb,
                                   num_pages=3 * turns_pages, materialize=False),
            cpu_pool=make_cpu_pool(engine, wl.store_node, page_bytes=pb,
                                   num_pages=wl.clients * turns_pages + 8,
                                   materialize=False),
            disk_pool=make_disk_pool(engine, wl.store_node, page_bytes=pb,
                                     num_pages=wl.clients * turns_pages + 8,
                                     materialize=False),
            page_tokens=wl.page_tokens,
        )
    ckpt: Optional[CheckpointEngine] = None
    if wl.checkpoint_nbytes > 0 and wl.checkpoint_updates > 0:
        spec = engine.topology.spec
        ckpt = CheckpointEngine(
            engine, nodes=spec.n_nodes, gpus_per_node=min(spec.node.n_gpus, 4),
            source_node=wl.store_node, materialize=False)
        ckpt.register_checkpoint({"weights": wl.checkpoint_nbytes})
    sim = ServingSimulator(
        engine, from_table2(), hicache=hc, checkpoint=ckpt,
        sim_cfg=ServeSimConfig(
            clients=wl.clients, concurrency=wl.concurrency, turns=wl.turns,
            input_tokens=wl.input_tokens, output_tokens=wl.output_tokens,
            mode="async", chunk_tokens=wl.chunk_tokens,
            decode_chunk=wl.decode_chunk,
            handoff_bytes_per_token=(
                kv_bytes_per_token(cfg) if wl.pd_handoff else 0),
            gpu_node=wl.gpu_node, decode_node=wl.decode_node,
            checkpoint_updates=wl.checkpoint_updates,
        ),
    )
    t0 = engine.fabric.now
    st = sim.run()
    extra = {
        "input_throughput": st.input_throughput,
        "avg_ttft_s": st.avg_ttft,
        "p50_ttft_s": st.p50_ttft,
        "p90_ttft_s": st.p90_ttft,
        "p99_ttft_s": st.p99_ttft,
        "avg_tpot_s": st.avg_tpot,
        "p99_tpot_s": st.p99_tpot,
        "serialized_s": st.serialized_seconds,
        "overlap_ratio": (
            st.serialized_seconds / st.makespan if st.makespan > 0 else 0.0),
        "bytes_promoted": float(st.bytes_promoted),
        "bytes_handoff": float(st.bytes_handoff),
        "checkpoint_updates": float(st.checkpoint_updates),
        "checkpoint_seconds": st.checkpoint_seconds,
    }
    for r, v in st.round_avg_ttft.items():
        extra[f"round_avg_ttft_R{r}"] = v
    return WorkloadOutcome(
        completions=list(st.request_log),
        bytes_total=st.bytes_promoted + st.bytes_handoff,
        makespan=st.makespan,
        extra=extra,
    )


def _run_serving_stream(engine: TentEngine, wl: ServingWorkload) -> WorkloadOutcome:
    """Production-stream executor: the batched SoA stepper over a seeded
    Poisson/Zipf arrival stream (`ServingSimulator(mode="batched")`). No
    HiCache object at this scale — prefix caching is the vectorized
    group-residency model in `repro.scenarios.traffic.promotion_bytes`."""
    from ..serving import ServeSimConfig, ServingSimulator, from_table2

    sim = ServingSimulator(
        engine, from_table2(), hicache=None,
        sim_cfg=ServeSimConfig(
            mode="batched",
            concurrency=wl.concurrency,
            input_tokens=wl.input_tokens,
            output_tokens=wl.output_tokens,
            chunk_tokens=wl.chunk_tokens,
            gpu_node=wl.gpu_node,
            store_node=wl.store_node,
            stream_requests=wl.stream_requests,
            arrival_rate=wl.arrival_rate,
            zipf_alpha=wl.zipf_alpha,
            traffic_groups=wl.traffic_groups,
            prefix_frac=wl.prefix_frac,
            stream_kv_bytes_per_token=wl.stream_kv_bytes_per_token,
            resident_s=wl.resident_s,
            tick_s=wl.tick_s,
        ),
    )
    st = sim.run()
    extra = {
        "input_throughput": st.input_throughput,
        "avg_ttft_s": st.avg_ttft,
        "p50_ttft_s": st.p50_ttft,
        "p90_ttft_s": st.p90_ttft,
        "p99_ttft_s": st.p99_ttft,
        "avg_tpot_s": st.avg_tpot,
        "p99_tpot_s": st.p99_tpot,
        "serialized_s": st.serialized_seconds,
        "overlap_ratio": (
            st.serialized_seconds / st.makespan if st.makespan > 0 else 0.0),
        "bytes_promoted": float(st.bytes_promoted),
        "requests_completed": float(st.requests),
    }
    return WorkloadOutcome(
        completions=list(st.request_log),
        bytes_total=st.bytes_promoted,
        makespan=st.makespan,
        extra=extra,
    )


# ---------------------------------------------------------------------------
# Checkpoint-broadcast executor
# ---------------------------------------------------------------------------


def run_checkpoint(engine: TentEngine, wl: CheckpointWorkload) -> WorkloadOutcome:
    from ..serving import CheckpointEngine

    ce = CheckpointEngine(
        engine, nodes=wl.nodes, gpus_per_node=wl.gpus_per_node,
        source_node=wl.source_node, materialize=False,
    )
    ce.register_checkpoint({"ckpt": wl.nbytes})
    t0 = engine.fabric.now
    res = ce.update()
    return WorkloadOutcome(
        completions=[(engine.fabric.now, res.bytes, res.seconds)],
        bytes_total=res.bytes,
        makespan=res.seconds,
        extra={
            "update_seconds": res.seconds,
            "aggregate_bandwidth": res.aggregate_bandwidth,
            "ranks": float(res.ranks),
        },
    )


def run_workload(engine: TentEngine, wl: Workload) -> WorkloadOutcome:
    if isinstance(wl, ClosedLoopWorkload):
        return run_closed_loop(engine, wl)
    if isinstance(wl, ServeWorkload):
        return run_serve(engine, wl)
    if isinstance(wl, ServingWorkload):
        return run_serving(engine, wl)
    if isinstance(wl, CheckpointWorkload):
        return run_checkpoint(engine, wl)
    if isinstance(wl, ClusterWorkload):
        raise TypeError(
            "ClusterWorkload needs a TentCluster; use run_cluster_workload "
            "(ScenarioRunner.run_policy dispatches there automatically)")
    raise TypeError(f"unknown workload {type(wl).__name__}")


# ---------------------------------------------------------------------------
# Multi-engine cluster executor
# ---------------------------------------------------------------------------


def _pump_cluster_contender(cluster, wl: ClusterWorkload, ignore: Dict[str, Set[int]]) -> None:
    """The cache-tier contender: open-ended elephant flows from the cache
    node(s) into the consumer pool, submitted through the contender's own
    engine (typically a statically ranked policy that pins a few receiver
    NICs). Batch ids are recorded so workload metrics and audits can separate
    this background pressure from the traffic under test."""
    eng = cluster.engines["cache"]
    rec = ignore.setdefault("cache", set())
    for cn in wl.contender_nodes:
        for s in range(wl.contender_streams):
            numa = s % 2
            src = eng.register_segment(
                host_loc(cn, numa), wl.contender_block, materialize=False)
            dst = eng.register_segment(
                host_loc(wl.consumer_nodes[s % len(wl.consumer_nodes)], numa),
                wl.contender_block, materialize=False)

            def pump(src=src, dst=dst):
                b = eng.allocate_batch()
                eng.submit_transfer(
                    b, [(src.segment_id, 0, dst.segment_id, 0, wl.contender_block)])
                rec.add(b)
                eng.on_batch_done(b, lambda res: pump())

            pump()


def _producer_streams(
    eng: TentEngine, wl: ClusterWorkload, node: int, phase: int
) -> List[List[Tuple[int, int, int]]]:
    """The `streams_per_engine` closed-loop KV streams one producer engine
    on `node` ships into the consumer pool (phase staggers the consumer
    round-robin so multiple producers spread across the pool)."""
    out = []
    for s in range(wl.streams_per_engine):
        numa = s % 2
        src = eng.register_segment(host_loc(node, numa), wl.block, materialize=False)
        cnode = wl.consumer_nodes[(phase + s) % len(wl.consumer_nodes)]
        dst = eng.register_segment(host_loc(cnode, numa), wl.block, materialize=False)
        out.append([(src.segment_id, dst.segment_id, wl.block)])
    return out


def _schedule_churn(
    cluster,
    driver: StreamDriver,
    wl: ClusterWorkload,
    churn: Sequence[FaultEvent],
    join_policy: str,
) -> None:
    """Install the fault program's join/leave events on the shared clock.
    A leaver is removed from the control plane (its streams stop at the next
    resubmission; in-flight batches drain and stay audited). A joiner is
    built cold and immediately starts producing into the consumer pool —
    the same declarative stream shape the original producers use."""
    for i, ev in enumerate(churn):
        driver.hold_until(ev.at)
        if ev.kind == "leave":
            cluster.fabric.call_at(
                ev.at, lambda name=ev.engine: cluster.remove_engine(name))
        else:  # join

            def _join(ev=ev, phase=i):
                eng = cluster.add_engine(ev.engine, (ev.node,), policy=join_policy)
                for transfers in _producer_streams(eng, wl, ev.node, phase):
                    driver.add_stream(eng, transfers)

            cluster.fabric.call_at(ev.at, _join)


def run_cluster_workload(
    cluster,
    wl: ClusterWorkload,
    churn: Sequence[FaultEvent] = (),
    *,
    join_policy: str = "tent",
) -> Tuple[WorkloadOutcome, Dict[str, Set[int]]]:
    """Drive a ClusterWorkload on a built `repro.cluster.TentCluster`,
    optionally under membership churn (`churn`: the spec's join/leave
    events). Returns the outcome plus per-engine batch ids to exclude from
    audits (open-ended contender flows)."""
    ignore: Dict[str, Set[int]] = {}
    driver = StreamDriver(
        cluster.fabric, iters=wl.iters, duration=wl.duration,
        alive=lambda eng: eng.name not in cluster.departed)
    streams: List[Tuple[TentEngine, List[Tuple[int, int, int]]]] = []
    if wl.pattern == "kv_incast":
        # many prefill engines -> few decode nodes (receiver-side incast)
        for i, node in enumerate(wl.producer_nodes):
            eng = cluster.engines[f"prefill{node}"]
            for transfers in _producer_streams(eng, wl, node, i):
                streams.append((eng, transfers))
    else:  # ckpt_broadcast
        # trainer pushes one shard per consumer node in one declarative
        # batch, striping shard sources across its staging (producer) nodes
        tr = cluster.engines["trainer"]
        transfers = []
        for i, cnode in enumerate(wl.consumer_nodes):
            tnode = wl.producer_nodes[i % len(wl.producer_nodes)]
            src = tr.register_segment(
                host_loc(tnode, cnode % 2), wl.nbytes, materialize=False)
            dst = tr.register_segment(
                host_loc(cnode, cnode % 2), wl.nbytes, materialize=False)
            transfers.append((src.segment_id, dst.segment_id, wl.nbytes))
        streams.append((tr, transfers))
        # serving engines churn KV among themselves on the same rails
        for i, cnode in enumerate(wl.consumer_nodes):
            eng = cluster.engines[f"serving{cnode}"]
            nxt = wl.consumer_nodes[(i + 1) % len(wl.consumer_nodes)]
            for s in range(wl.streams_per_engine):
                numa = s % 2
                src = eng.register_segment(
                    host_loc(cnode, numa), wl.block, materialize=False)
                dst = eng.register_segment(
                    host_loc(nxt, numa), wl.block, materialize=False)
                streams.append((eng, [(src.segment_id, dst.segment_id, wl.block)]))
    if wl.contender_nodes:
        _pump_cluster_contender(cluster, wl, ignore)
    if churn:
        _schedule_churn(cluster, driver, wl, churn, join_policy)
    cluster.start()  # arm the diffusion timer now that work is in flight
    for eng, transfers in streams:
        driver.add_stream(eng, transfers)
    return driver.run(), ignore


# ---------------------------------------------------------------------------
# Background contention generators (shared with benchmarks/common.py)
# ---------------------------------------------------------------------------


def add_background_turbulence(engine: TentEngine, *, seed: int = 7,
                              horizon: float = 60.0, severity: float = 0.5) -> None:
    """Transient per-rail slowdowns (noisy neighbours / signal degradation,
    paper §2.2): deterministic schedule of degradation windows on RDMA rails."""
    rng = np.random.default_rng(seed)
    for node in range(engine.topology.spec.n_nodes):
        for nic in engine.topology.rdma_nics(node):
            # windows cover t=0 onward so short virtual-time experiments see
            # the same non-uniform fabric that long-running services do
            t = 0.0
            while t < horizon:
                dur = float(rng.uniform(0.05, 0.5))
                if rng.random() < 0.4:
                    factor = float(rng.uniform(1 - severity, 0.9))
                    engine.fabric.schedule_degradation(nic.link_id, at=t, until=t + dur, factor=factor)
                t += dur + float(rng.uniform(0.0, 0.3))


def add_tenant_contention(engine: TentEngine, *, streams: int = 4,
                          block: int = 64 << 20, horizon: float = 1e12,
                          record: Optional[Set[int]] = None) -> None:
    """Co-located tenants saturating the same rails (paper §2.2 "noisy
    neighbours"): closed-loop host-to-host elephant flows that run for the
    whole experiment, scheduled through the same engine/fabric. Batch ids are
    added to `record` so audits can separate tenant traffic from the workload
    under test."""
    for i in range(streams):
        numa = i % 2
        src = engine.register_segment(host_loc(0, numa), block, materialize=False)
        dst = engine.register_segment(host_loc(1, numa), block, materialize=False)

        def pump(src=src, dst=dst):
            if engine.fabric.now >= horizon:
                return
            b = engine.allocate_batch()
            engine.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, block)])
            if record is not None:
                record.add(b)
            engine.on_batch_done(b, lambda res: pump())

        pump()
