"""Declarative scenarios: one spec consumed by the engine, benchmarks, and
the fast regression tier alike (see README.md in this directory)."""
from .library import SCENARIOS, get, names
from .runner import PolicyReport, ScenarioReport, ScenarioRunner, run_scenario
from .sweep import (
    MonteCarloSweep,
    SweepPolicyDist,
    SweepReport,
    compile_spray_program,
)
from .spec import (
    BackgroundSpec,
    CheckpointWorkload,
    ClosedLoopWorkload,
    ClusterWorkload,
    EngineParams,
    Expectations,
    FaultEvent,
    ScenarioSpec,
    ServeWorkload,
    ServingWorkload,
    TopologyParams,
    degrade_ramp,
    engine_join,
    engine_leave,
    flap_storm,
    rail_outage,
)
from .workloads import (
    StreamDriver,
    WorkloadOutcome,
    add_background_turbulence,
    add_tenant_contention,
    drive_closed_loop,
    drive_streams,
    gpu_loc,
    host_loc,
    run_closed_loop,
    run_cluster_workload,
    run_serve,
    run_serving,
    run_workload,
)

__all__ = [
    "SCENARIOS", "get", "names", "PolicyReport", "ScenarioReport",
    "ScenarioRunner", "run_scenario", "MonteCarloSweep", "SweepPolicyDist",
    "SweepReport", "compile_spray_program",
    "BackgroundSpec", "CheckpointWorkload",
    "ClosedLoopWorkload", "ClusterWorkload", "EngineParams", "Expectations",
    "FaultEvent", "ScenarioSpec", "ServeWorkload", "ServingWorkload",
    "TopologyParams", "degrade_ramp", "engine_join", "engine_leave",
    "flap_storm", "rail_outage", "StreamDriver", "WorkloadOutcome",
    "add_background_turbulence", "add_tenant_contention", "drive_closed_loop",
    "drive_streams", "gpu_loc", "host_loc", "run_closed_loop",
    "run_cluster_workload", "run_serve", "run_serving", "run_workload",
]
