"""Declarative scenario specifications.

A `ScenarioSpec` is a pure-data description of one reproducible experiment:
a topology (`FabricSpec` parameters), a workload (closed-loop TEBench load,
HiCache serving turns, or a checkpoint broadcast), a fault program (failure
and degradation windows, flap storms, correlated multi-rail outages), the
background contention, the policy ablation list, and the invariants the run
is expected to uphold. The engine, the benchmarks, and the regression tests
all consume the same spec objects, so every claim in the paper is checked
against the same scenario matrix everywhere.

Specs are frozen dataclasses with a dict/JSON round-trip (`to_dict` /
`from_dict`, `to_json` / `from_json`): a scenario can live in code, in a
JSON file, or on a benchmark command line and mean exactly the same run.
"""
from __future__ import annotations

import dataclasses
import json
from typing import ClassVar, Dict, Tuple, Union

from ..core import EngineConfig, FabricSpec, HealthConfig, NodeSpec

# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologyParams:
    """The subset of `FabricSpec` a scenario varies, plus heterogeneity.

    `rail_bw_factors` models heterogeneous rails (mixed NIC generations,
    mis-negotiated links): each (nic_index, factor) entry derates that rail
    ordinal on *every* node for the whole run. It is applied as a silent
    fabric-level degradation, so the engine only learns it via telemetry —
    exactly the paper's hetero-bandwidth setting (§2.2).
    """

    n_nodes: int = 2
    n_numa: int = 2
    n_gpus: int = 8
    n_nics: int = 8
    nic_bw: float = 25.0e9
    # TCP fallback rail; scaled-down scenarios must derate it alongside
    # nic_bw or the engine simply routes the contention onto the 3 GB/s
    # default and the NIC numbers become decorative
    tcp_bw: float = 3.0e9
    has_nvlink: bool = True
    has_gpudirect: bool = True
    has_mnnvl: bool = False
    has_ub: bool = False
    rail_bw_factors: Tuple[Tuple[int, float], ...] = ()

    def to_fabric_spec(self) -> FabricSpec:
        return FabricSpec(
            n_nodes=self.n_nodes,
            node=NodeSpec(n_numa=self.n_numa, n_gpus=self.n_gpus, n_nics=self.n_nics),
            nic_bw=self.nic_bw,
            tcp_bw=self.tcp_bw,
            has_nvlink=self.has_nvlink,
            has_gpudirect=self.has_gpudirect,
            has_mnnvl=self.has_mnnvl,
            has_ub=self.has_ub,
        )

    @classmethod
    def from_dict(cls, d: dict) -> "TopologyParams":
        d = dict(d)
        d["rail_bw_factors"] = tuple(
            (int(i), float(f)) for i, f in d.get("rail_bw_factors", ())
        )
        return cls(**d)


# ---------------------------------------------------------------------------
# Fault program
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault-program event.

    kind "fail":    the rail (node, nic) flaps down over [at, until) —
                    in-flight slices abort (paper §2.3) and new posts error.
    kind "degrade": effective bandwidth of (node, nic) is multiplied by
                    `factor` over [at, until) — silent, only telemetry sees it.
    kind "join":    engine `engine` joins the cluster at `at`, owning `node`
                    (cluster workloads only) and starts producing.
    kind "leave":   engine `engine` departs the cluster at `at` (cluster
                    workloads only); its streams stop resubmitting, its
                    control-plane state is garbage-collected, and its
                    in-flight slices drain on the data plane.
    """

    kind: str  # "fail" | "degrade" | "join" | "leave"
    node: int
    nic: int
    at: float
    until: float = 0.0
    factor: float = 1.0
    engine: str = ""  # churn kinds: which engine joins/leaves

    def __post_init__(self) -> None:
        if self.kind not in ("fail", "degrade", "join", "leave"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in ("fail", "degrade") and self.until <= self.at:
            raise ValueError("fault window must have until > at")
        if self.kind in ("join", "leave") and not self.engine:
            raise ValueError(f"churn event {self.kind!r} needs an engine name")

    @property
    def is_churn(self) -> bool:
        return self.kind in ("join", "leave")

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(**d)


def engine_join(engine: str, node: int, *, at: float) -> FaultEvent:
    """Engine `engine` joins the cluster mid-run, owning `node`."""
    return FaultEvent("join", node, 0, at=at, engine=engine)


def engine_leave(engine: str, *, at: float) -> FaultEvent:
    """Engine `engine` departs the cluster mid-run."""
    return FaultEvent("leave", 0, 0, at=at, engine=engine)


def flap_storm(
    node: int, nic: int, *, start: float, flaps: int, down: float, up: float
) -> Tuple[FaultEvent, ...]:
    """Repeated short down/up cycles on one rail (the paper's link flaps)."""
    out = []
    t = start
    for _ in range(flaps):
        out.append(FaultEvent("fail", node, nic, at=t, until=t + down))
        t += down + up
    return tuple(out)


def rail_outage(
    node: int, nics: Tuple[int, ...], *, at: float, until: float
) -> Tuple[FaultEvent, ...]:
    """Correlated multi-rail outage (ToR/leaf failure takes several NICs)."""
    return tuple(FaultEvent("fail", node, n, at=at, until=until) for n in nics)


def degrade_ramp(
    node: int, nic: int, *, start: float, step: float, factors: Tuple[float, ...]
) -> Tuple[FaultEvent, ...]:
    """Stepwise degrade-then-recover ramp (e.g. 0.7 -> 0.4 -> 0.15 -> healthy)."""
    return tuple(
        FaultEvent("degrade", node, nic, at=start + i * step,
                   until=start + (i + 1) * step, factor=f)
        for i, f in enumerate(factors)
    )


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClosedLoopWorkload:
    """TEBench-style closed-loop load (paper §5.1.3): each stream keeps one
    batch of `batch_size` block transfers in flight, resubmitting on
    completion. Stream i draws its block size / endpoints cyclically from
    the tuples, so elephant+mice mixes and multi-node incast are just data.

    Either `iters` (each stream submits that many batches) or, when
    `duration` > 0, streams pump until the virtual clock passes `duration`.
    """

    kind: ClassVar[str] = "closed_loop"
    streams: int = 4
    blocks: Tuple[int, ...] = (16 << 20,)
    iters: int = 16
    batch_size: int = 1
    duration: float = 0.0
    endpoints: str = "host"  # "host" | "gpu"
    src_nodes: Tuple[int, ...] = (0,)
    dst_nodes: Tuple[int, ...] = (1,)
    src_numa: Tuple[int, ...] = (0, 1)
    dst_numa: Tuple[int, ...] = (0, 1)

    @classmethod
    def from_dict(cls, d: dict) -> "ClosedLoopWorkload":
        d = dict(d)
        for key in ("blocks", "src_nodes", "dst_nodes", "src_numa", "dst_numa"):
            if key in d:
                d[key] = tuple(int(v) for v in d[key])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """HiCache multi-turn serving (paper §5.1.1 / Table 2): conversations on
    `gpu_node`, the global KV pool's CPU/disk tiers on `store_node`; cached
    prefixes are promoted through the engine under test."""

    kind: ClassVar[str] = "serve"
    model: str = "qwen3-moe-235b-a22b"
    clients: int = 4
    concurrency: int = 2
    turns: int = 4
    input_tokens: int = 1024
    output_tokens: int = 32
    page_tokens: int = 256
    use_hicache: bool = True
    gpu_node: int = 0
    store_node: int = 1

    @classmethod
    def from_dict(cls, d: dict) -> "ServeWorkload":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ServingWorkload:
    """Event-driven serving closed loop on the wave engine (paper §5.1): each
    request's HiCache promotion, prefill->decode KV handoff, and decode are
    stages whose transfers are asynchronous TENT batches — concurrent
    requests genuinely overlap and contend on the fabric, chunked prefill
    interleaves with decode, and an optional checkpoint refresh runs
    overlapped with live traffic. TTFT/TPOT SLOs are declared in the spec's
    `Expectations` and evaluated by the runner."""

    kind: ClassVar[str] = "serving"
    model: str = "qwen3-moe-235b-a22b"
    clients: int = 6
    concurrency: int = 3
    turns: int = 3
    input_tokens: int = 1024
    output_tokens: int = 64
    page_tokens: int = 256
    chunk_tokens: int = 512  # prefill chunk; 0 = monolithic prefill
    decode_chunk: int = 16
    use_hicache: bool = True
    pd_handoff: bool = False  # ship prefill->decode KV through TENT
    checkpoint_nbytes: int = 0  # > 0: overlapped weight refresh of this size
    checkpoint_updates: int = 0
    gpu_node: int = 0
    store_node: int = 1
    decode_node: int = 1
    # --- production-stream fields (> 0 selects the batched SoA stepper) ---
    # total single-turn requests drawn from the seeded Poisson/Zipf stream
    # (repro.scenarios.traffic); clients/turns/use_hicache are ignored —
    # prefix caching becomes the vectorized group-residency model
    stream_requests: int = 0
    arrival_rate: float = 0.0  # mean arrivals/s
    zipf_alpha: float = 1.1  # popularity skew over prefix groups
    traffic_groups: int = 64
    prefix_frac: float = 0.5  # cached-prefix share of each prompt
    # KV bytes promoted per cold prefix token; pins the wire-contention
    # level independently of the model's true KV width
    stream_kv_bytes_per_token: int = 1024
    resident_s: float = 1.0  # GPU residency window per prefix group
    tick_s: float = 0.005  # batched stepper's virtual-clock tick

    @classmethod
    def from_dict(cls, d: dict) -> "ServingWorkload":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CheckpointWorkload:
    """Checkpoint-engine broadcast (paper §5.1.2 / Table 3): every rank pulls
    its weight shard from the parameter-server node in one declarative batch."""

    kind: ClassVar[str] = "checkpoint"
    nbytes: int = 1 << 30
    nodes: int = 2
    gpus_per_node: int = 8
    source_node: int = 0

    @classmethod
    def from_dict(cls, d: dict) -> "CheckpointWorkload":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ClusterWorkload:
    """Multi-engine cluster workload: a `repro.cluster.TentCluster` of
    engines on one shared fabric/virtual clock, each owning a disjoint node
    subset (paper's one-engine-per-role deployment model).

    pattern "kv_incast":      one prefill engine per producer node ships KV
        closed-loop into the decode-pool engine owning `consumer_nodes`,
        while an optional cache-tier contender engine (whose static policy
        pins its elephants to a few receiver NICs) creates cross-engine
        pressure that siloed telemetry cannot see in advance.
    pattern "ckpt_broadcast": a trainer engine owning `producer_nodes`
        pushes one `nbytes` shard per consumer node in a single declarative
        batch per round, while per-node serving engines churn KV among
        themselves on the same rails.

    Policy names of the form "<base>+diffusion" in the spec's ablation list
    run with the cluster control plane enabled (global load table + failure
    rumors, `global_weight` as omega); plain names run the same engines as
    silos — that contrast is the paper's §4.2 headline experiment.
    """

    kind: ClassVar[str] = "cluster"
    pattern: str = "kv_incast"  # "kv_incast" | "ckpt_broadcast"
    producer_nodes: Tuple[int, ...] = (0, 1, 2)
    consumer_nodes: Tuple[int, ...] = (3,)
    contender_nodes: Tuple[int, ...] = ()  # () disables the cache-tier role
    streams_per_engine: int = 2
    block: int = 1 << 20
    iters: int = 8
    duration: float = 0.0
    contender_streams: int = 2
    contender_block: int = 16 << 20
    contender_policy: str = "static_best2"
    nbytes: int = 8 << 20  # ckpt_broadcast shard per consumer node
    # control-plane knobs (used only by "+diffusion" policies)
    diffusion_period: float = 0.001
    diffusion_staleness: float = 0.02
    gossip_delay: float = 0.0005
    global_weight: float = 0.6
    # control-plane link model (0/0/0 = idealized lossless broadcast):
    # per-message drop probability, per-message delivery delay (virtual s),
    # and fanout-k partial membership views (<=0 addresses every peer)
    gossip_loss: float = 0.0
    gossip_link_delay: float = 0.0
    fanout: int = 0

    def __post_init__(self) -> None:
        if self.pattern not in ("kv_incast", "ckpt_broadcast"):
            raise ValueError(f"unknown cluster pattern {self.pattern!r}")
        if not self.producer_nodes or not self.consumer_nodes:
            raise ValueError("cluster workload needs producers and consumers")
        if self.diffusion_period > 0 and self.diffusion_staleness < self.diffusion_period:
            # snapshots are delivered one period stale by construction, so a
            # staleness horizon below the period silently drops every entry
            raise ValueError(
                f"diffusion_staleness ({self.diffusion_staleness}) must be >= "
                f"diffusion_period ({self.diffusion_period})")
        if not 0.0 <= self.gossip_loss < 1.0:
            raise ValueError(f"gossip_loss must be in [0, 1), got {self.gossip_loss}")
        if self.gossip_link_delay < 0:
            raise ValueError(
                f"gossip_link_delay must be >= 0, got {self.gossip_link_delay}")
        if self.gossip_link_delay > 0 and self.diffusion_period > 0 and (
                self.gossip_link_delay + self.diffusion_period
                > self.diffusion_staleness):
            # a snapshot ages one period before it ships plus the link delay
            # in flight; past the horizon every delivery would arrive stale
            raise ValueError(
                f"gossip_link_delay ({self.gossip_link_delay}) + diffusion_period "
                f"({self.diffusion_period}) must be <= diffusion_staleness "
                f"({self.diffusion_staleness}) or every telemetry delivery "
                "arrives stale")

    @classmethod
    def from_dict(cls, d: dict) -> "ClusterWorkload":
        d = dict(d)
        for key in ("producer_nodes", "consumer_nodes", "contender_nodes"):
            if key in d:
                d[key] = tuple(int(v) for v in d[key])
        return cls(**d)


Workload = Union[
    ClosedLoopWorkload, ServeWorkload, ServingWorkload, CheckpointWorkload,
    ClusterWorkload,
]

WORKLOAD_KINDS: Dict[str, type] = {
    w.kind: w
    for w in (ClosedLoopWorkload, ServeWorkload, ServingWorkload,
              CheckpointWorkload, ClusterWorkload)
}


# ---------------------------------------------------------------------------
# Background contention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackgroundSpec:
    """Fabric-level noise the engine does not control: transient per-rail
    turbulence windows and co-located tenant elephant flows (paper §2.2)."""

    turbulence_severity: float = 0.0  # 0 disables
    turbulence_seed: int = 7
    turbulence_horizon: float = 60.0
    tenant_streams: int = 0
    tenant_block: int = 64 << 20

    @classmethod
    def from_dict(cls, d: dict) -> "BackgroundSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# Engine knobs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineParams:
    """The `EngineConfig`/`HealthConfig` knobs a scenario pins down. The
    policy itself comes from the spec's ablation list.

    `wave`/`candidate_cache` expose the engine's hot-path controls: both on
    (the default) runs the vectorized wave scheduler over cached per-stage
    candidate sets; both off reproduces the pre-wave one-slice-at-a-time
    loop with bit-identical scheduling decisions (the wave-parity regression
    and `benchmarks/spray_hotpath.py` rely on that toggle). `wave_complete`
    toggles the batched completion drain the same way (off = per-completion
    scalar drain, bit-identical outcomes), and `wave_min` pins the
    scalar/wave dispatch crossover instead of letting the engine tune it
    online."""

    slice_bytes: int = 64 * 1024
    max_slices: int = 64
    max_inflight: int = 256
    gamma: float = 0.05
    reset_interval: float = 1.0
    probe_interval: float = 0.02
    retry_limit: int = 8
    wave: bool = True
    candidate_cache: bool = True
    wave_complete: bool = True
    wave_min: Union[int, None] = None
    # routes the wave chooser + completion drain through the jitted
    # fixed-shape kernels (repro.core.jit_core); bit-identical to the numpy
    # path, scalar fallback everywhere else (see EngineConfig.jit_core)
    jit_core: bool = False
    # runs the fabric event loop on the calendar queue (bucketed timestamp
    # wheel) instead of the binary heap; bit-identical pop order, O(1)
    # amortized at serving-stream scale (see EngineConfig.calendar_queue)
    calendar_queue: bool = False

    def to_engine_config(self, policy: str) -> EngineConfig:
        return EngineConfig(
            policy=policy,
            slice_bytes=self.slice_bytes,
            max_slices=self.max_slices,
            max_inflight=self.max_inflight,
            gamma=self.gamma,
            reset_interval=self.reset_interval,
            wave=self.wave,
            candidate_cache=self.candidate_cache,
            wave_complete=self.wave_complete,
            wave_min=self.wave_min,
            jit_core=self.jit_core,
            calendar_queue=self.calendar_queue,
            health=HealthConfig(
                probe_interval=self.probe_interval, retry_limit=self.retry_limit
            ),
        )

    @classmethod
    def from_dict(cls, d: dict) -> "EngineParams":
        return cls(**d)


# ---------------------------------------------------------------------------
# Expectations (the regression tier's per-scenario invariants)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Expectations:
    """What must hold for the scenario to count as passing. A value of 0
    disables the corresponding check; `ScenarioReport.violations` lists every
    breach, so benchmarks and tests share one notion of "healthy"."""

    # primary policy throughput >= factor * every baseline's (0 disables)
    tent_vs_baseline: float = 1.0
    # worst throughput-dip duration after any "fail" onset, virtual ms
    max_recovery_ms: float = 0.0
    # worst time-to-next-completion after any "fail" onset, virtual ms
    max_stall_ms: float = 0.0
    # max/mean byte ratio across the busiest node's RDMA rails (primary policy)
    max_rail_imbalance: float = 0.0
    # primary P99 latency <= factor * every baseline's P99 (0 disables)
    p99_vs_baseline: float = 0.0
    # primary P50 latency <= factor * every baseline's P50 (0 disables);
    # mice-dominated mixes use this to pin down head-of-line isolation
    p50_vs_baseline: float = 0.0
    # serving SLOs (serving workloads; evaluated against the primary policy's
    # reported extra["p90_ttft_s"] / extra["p99_ttft_s"] / extra["p99_tpot_s"])
    # primary TTFT P90 <= factor * every baseline's TTFT P90 (0 disables)
    ttft_p90_vs_baseline: float = 0.0
    # absolute virtual-seconds ceilings on the primary policy (0 disables)
    max_ttft_p99_s: float = 0.0
    max_tpot_p99_s: float = 0.0
    # no app-visible failures and no slice unaccounted for, any policy
    zero_lost_slices: bool = True
    # Monte Carlo sweep expectations (evaluated by `repro.scenarios.sweep`
    # over the seed distribution, not by the single-seed runner):
    # primary healing-time P99.9 ceiling across seeds, virtual ms (0 disables)
    healing_p999_ms: float = 0.0
    # primary throughput P50 >= factor * every baseline's P50 (0 disables)
    throughput_p50_vs_baseline: float = 0.0

    @classmethod
    def from_dict(cls, d: dict) -> "Expectations":
        return cls(**d)


# ---------------------------------------------------------------------------
# The scenario itself
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    description: str = ""
    topology: TopologyParams = dataclasses.field(default_factory=TopologyParams)
    workload: Workload = dataclasses.field(default_factory=ClosedLoopWorkload)
    faults: Tuple[FaultEvent, ...] = ()
    background: BackgroundSpec = dataclasses.field(default_factory=BackgroundSpec)
    policies: Tuple[str, ...] = ("tent", "round_robin")
    engine: EngineParams = dataclasses.field(default_factory=EngineParams)
    expectations: Expectations = dataclasses.field(default_factory=Expectations)
    seed: int = 0
    bucket: float = 0.005  # throughput-timeline bucket width (virtual s)

    @property
    def primary_policy(self) -> str:
        return self.policies[0]

    @property
    def baseline_policies(self) -> Tuple[str, ...]:
        return self.policies[1:]

    # -- round trip ----------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["workload"] = {"kind": self.workload.kind, **d["workload"]}
        return _jsonable(d)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        d = dict(d)
        wl = dict(d["workload"])
        wl_cls = WORKLOAD_KINDS[wl.pop("kind")]
        return cls(
            name=d["name"],
            description=d.get("description", ""),
            topology=TopologyParams.from_dict(d.get("topology", {})),
            workload=wl_cls.from_dict(wl),
            faults=tuple(FaultEvent.from_dict(f) for f in d.get("faults", ())),
            background=BackgroundSpec.from_dict(d.get("background", {})),
            policies=tuple(d.get("policies", ("tent", "round_robin"))),
            engine=EngineParams.from_dict(d.get("engine", {})),
            expectations=Expectations.from_dict(d.get("expectations", {})),
            seed=int(d.get("seed", 0)),
            bucket=float(d.get("bucket", 0.005)),
        )

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))


def _jsonable(obj):
    """Tuples -> lists, recursively, so to_dict() output is json.dumps-ready
    and equals json.loads(to_json()) exactly (round-trip tests rely on it)."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj
