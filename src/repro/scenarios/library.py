"""The named scenario library: the regression matrix every PR runs against.

Each entry is a small, fully deterministic instance of one hostile condition
from the paper (§2.2-§2.3, §5): link flaps, flap storms, correlated outages,
NUMA-skewed incast, heterogeneous rails, tenant contention, elephant+mice
mixes, silent degradation ramps, disaggregated prefill/decode KV shipping,
HiCache serving, and checkpoint broadcast. Sizes are scaled down (slower
virtual NICs, MB-scale blocks) so the whole matrix runs in seconds of wall
clock — the asserted quantities (policy ordering, recovery time on the
virtual clock, slice accounting, byte balance) are scale-invariant, the same
trick benchmarks/table3 uses.

Benchmarks needing full-scale variants `dataclasses.replace(...)` these specs
rather than redefining them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .spec import (
    BackgroundSpec,
    CheckpointWorkload,
    ClosedLoopWorkload,
    ClusterWorkload,
    EngineParams,
    Expectations,
    FaultEvent,
    ScenarioSpec,
    ServeWorkload,
    ServingWorkload,
    TopologyParams,
    degrade_ramp,
    engine_join,
    engine_leave,
    flap_storm,
    rail_outage,
)

# A slowed-down 2-node fabric for timeline (recovery) scenarios: completion
# density per bucket stays high while the event count stays small.
_SLOW = TopologyParams(nic_bw=1e9)
_PUMP = ClosedLoopWorkload(streams=4, blocks=(1 << 20,), iters=0, duration=0.08)


def _timeline(name: str, description: str, **kw) -> ScenarioSpec:
    kw.setdefault("topology", _SLOW)
    kw.setdefault("workload", _PUMP)
    kw.setdefault("bucket", 0.004)
    return ScenarioSpec(name=name, description=description, **kw)


SCENARIOS: Dict[str, ScenarioSpec] = {}


def _register(spec: ScenarioSpec) -> ScenarioSpec:
    assert spec.name not in SCENARIOS, f"duplicate scenario {spec.name}"
    SCENARIOS[spec.name] = spec
    return spec


_register(_timeline(
    "single_rail_flap",
    "One NIC flaps down mid-run and recovers (paper Fig. 10): the engine "
    "must mask the failure, run degraded, and reintegrate the rail.",
    faults=(FaultEvent("fail", 0, 0, at=0.025, until=0.06),),
    expectations=Expectations(
        tent_vs_baseline=1.0, max_recovery_ms=50.0, max_stall_ms=50.0,
        # Monte-Carlo tails (benchmarks/mc_sweep.py, 64+ seeds, jittered
        # onsets): measured healing P99.9 ~0.26ms, tent/rr P50 ratio ~1.18.
        healing_p999_ms=50.0, throughput_p50_vs_baseline=1.05),
))

_register(_timeline(
    "flap_storm",
    "Repeated short down/up cycles on one rail (paper §2.3 link flaps): "
    "every onset must be absorbed without app-visible failures.",
    workload=ClosedLoopWorkload(streams=4, blocks=(1 << 20,), iters=0, duration=0.1),
    faults=flap_storm(0, 0, start=0.02, flaps=3, down=0.008, up=0.012),
    expectations=Expectations(
        tent_vs_baseline=1.0, max_recovery_ms=50.0, max_stall_ms=50.0,
        # MC tails: measured healing P99.9 ~0.46ms, tent/rr P50 ratio ~1.17.
        healing_p999_ms=50.0, throughput_p50_vs_baseline=1.05),
))

_register(_timeline(
    "correlated_outage",
    "A ToR/leaf failure takes 5 of 8 rails at once: capacity halves, so the "
    "dip metric is moot — the engine must keep completing work (stall "
    "bounded) and lose nothing.",
    workload=ClosedLoopWorkload(streams=4, blocks=(1 << 20,), iters=0, duration=0.12),
    faults=rail_outage(0, (0, 1, 2, 3, 4), at=0.03, until=0.08),
    expectations=Expectations(tent_vs_baseline=1.0, max_stall_ms=50.0),
))

_register(ScenarioSpec(
    "numa_skew_incast",
    "Two sender nodes converge on one receiver node, all buffers pinned to "
    "NUMA0 (paper §2.2 skewed submission): receiver-side serialization plus "
    "cross-NUMA penalties.",
    topology=TopologyParams(n_nodes=3),
    workload=ClosedLoopWorkload(
        streams=4, blocks=(8 << 20,), iters=10,
        src_nodes=(0, 1), dst_nodes=(2,), src_numa=(0,), dst_numa=(0,)),
    expectations=Expectations(tent_vs_baseline=0.95),
))

_register(ScenarioSpec(
    "hetero_bandwidth_rails",
    "Half the rails run at 35% bandwidth (mixed NIC generations, paper "
    "§2.2): state-blind striping is dragged by the stragglers; telemetry "
    "must discover the asymmetry silently.",
    topology=TopologyParams(
        rail_bw_factors=((4, 0.35), (5, 0.35), (6, 0.35), (7, 0.35))),
    workload=ClosedLoopWorkload(streams=4, blocks=(8 << 20,), iters=12),
    expectations=Expectations(tent_vs_baseline=1.05),
))

_register(ScenarioSpec(
    "multi_tenant_contention",
    "KV shipping between GPUs while co-located tenants run elephant flows "
    "and the fabric sees turbulence windows (paper §2.2 noisy neighbours).",
    workload=ClosedLoopWorkload(
        streams=4, blocks=(8 << 20,), iters=10, endpoints="gpu"),
    background=BackgroundSpec(
        turbulence_severity=0.5, tenant_streams=2, tenant_block=32 << 20),
    expectations=Expectations(tent_vs_baseline=1.0),
))

_register(ScenarioSpec(
    "elephant_mice_mix",
    "One elephant stream and three mice streams share the rails: against a "
    "statically ranked engine (NIXL-style best-K) the mice are stuck behind "
    "elephant slices on the 'best' rail, so their P50 explodes; spraying "
    "must keep mice latency flat while moving more total bytes.",
    workload=ClosedLoopWorkload(
        streams=4, blocks=(64 << 20, 1 << 20, 1 << 20, 1 << 20), iters=10),
    policies=("tent", "static_best2"),
    expectations=Expectations(
        tent_vs_baseline=1.5, p99_vs_baseline=1.05, p50_vs_baseline=0.5),
))

_register(_timeline(
    "degrade_recover_ramp",
    "Two rails silently degrade in steps (0.7 -> 0.4 -> 0.15) then recover "
    "(paper §2.2 signal degradation): only telemetry can see it; the "
    "periodic reset must re-integrate the recovered rails.",
    workload=ClosedLoopWorkload(streams=4, blocks=(1 << 20,), iters=0, duration=0.1),
    faults=(degrade_ramp(0, 0, start=0.01, step=0.02, factors=(0.7, 0.4, 0.15))
            + degrade_ramp(0, 1, start=0.01, step=0.02, factors=(0.7, 0.4, 0.15))),
    expectations=Expectations(tent_vs_baseline=1.0),
))

_register(_timeline(
    "disagg_prefill_decode",
    "Dual-node disaggregated serving: prefill GPUs on node 0 ship KV to "
    "decode GPUs on node 1 (GPUDirect elephant flows) while a tier-1 NIC "
    "flaps — the decode side must never observe the fault.",
    workload=ClosedLoopWorkload(
        streams=4, blocks=(1 << 20,), iters=0, duration=0.08, endpoints="gpu"),
    faults=(FaultEvent("fail", 0, 1, at=0.02, until=0.05),),
    expectations=Expectations(
        tent_vs_baseline=1.0, max_recovery_ms=50.0, max_stall_ms=50.0),
))

_register(ScenarioSpec(
    "hicache_serve",
    "Multi-turn HiCache serving (Table 2 at regression scale): cached-prefix "
    "promotions from the global store node ride a slow turbulent fabric; the "
    "transfer policy is the only difference between runs.",
    topology=TopologyParams(nic_bw=2.5e9),
    workload=ServeWorkload(),
    background=BackgroundSpec(turbulence_severity=0.7),
    expectations=Expectations(tent_vs_baseline=1.0),
))

_register(ScenarioSpec(
    "checkpoint_broadcast",
    "RL weight refresh (Table 3 at regression scale): 16 ranks pull their "
    "shards from the parameter server through a turbulent fabric.",
    workload=CheckpointWorkload(nbytes=512 << 20),
    background=BackgroundSpec(turbulence_severity=0.6),
    expectations=Expectations(tent_vs_baseline=1.0),
))

# -- serving closed loop (event-driven, async transfer intents) --------------

_register(ScenarioSpec(
    "serving_closed_loop_flap",
    "HiCache serving as an event-driven closed loop under a flapping store-"
    "side NIC: concurrent requests' promotions overlap and contend on the "
    "fabric while one rail repeatedly browns out to 5% bandwidth. The "
    "engine's telemetry must route promotions around the flapping rail so "
    "TTFT P90 and the SLOs hold where blind striping is dragged down.",
    topology=TopologyParams(nic_bw=5e8),
    workload=ServingWorkload(clients=6, concurrency=3, turns=3,
                             output_tokens=8),
    # a flap expressed as repeated deep brownouts (degrade, not fail: the
    # serving timeline is too sparse for the stall/dip recovery metrics)
    faults=(FaultEvent("degrade", 1, 0, at=0.2, until=1.2, factor=0.05),
            FaultEvent("degrade", 1, 0, at=1.6, until=2.6, factor=0.05),
            FaultEvent("degrade", 1, 1, at=0.8, until=2.0, factor=0.05)),
    background=BackgroundSpec(turbulence_severity=0.7),
    expectations=Expectations(
        tent_vs_baseline=1.0, ttft_p90_vs_baseline=1.0,
        max_ttft_p99_s=1.5, max_tpot_p99_s=0.1),
))

_register(ScenarioSpec(
    "serving_pd_handoff_incast",
    "Prefill->decode disaggregation as async transfer intents: every "
    "request's KV pages ship from the prefill node to the decode node the "
    "moment its chunked prefill ends, so concurrent handoffs form a "
    "receiver-side incast on the decode node's rails while decode compute "
    "proceeds on already-landed caches.",
    topology=TopologyParams(
        nic_bw=5e8,
        rail_bw_factors=((4, 0.3), (5, 0.3), (6, 0.3), (7, 0.3))),
    workload=ServingWorkload(clients=6, concurrency=4, turns=2,
                             use_hicache=False, pd_handoff=True,
                             output_tokens=8),
    background=BackgroundSpec(turbulence_severity=0.6),
    expectations=Expectations(
        tent_vs_baseline=1.0, ttft_p90_vs_baseline=1.0,
        max_ttft_p99_s=2.5),
))

_register(ScenarioSpec(
    "serving_checkpoint_overlap",
    "Checkpoint-update-during-decode: an overlapped CheckpointEngine weight "
    "refresh (async all-rank pull) contends with live HiCache promotions "
    "mid-run. The refresh must not blow the serving SLOs, and the spraying "
    "engine must keep both flows moving where static striping serializes "
    "them behind the same rails.",
    topology=TopologyParams(nic_bw=5e8),
    workload=ServingWorkload(clients=6, concurrency=3, turns=3,
                             output_tokens=8,
                             checkpoint_nbytes=256 << 20,
                             checkpoint_updates=2),
    background=BackgroundSpec(turbulence_severity=0.6),
    expectations=Expectations(
        tent_vs_baseline=1.0, ttft_p90_vs_baseline=1.0,
        max_ttft_p99_s=1.5, max_tpot_p99_s=0.1),
))

_register(ScenarioSpec(
    "serving_production_stream",
    "Production-scale serving stream: 10^5 single-turn requests drawn from "
    "a seeded Poisson/Zipf mix hit the batched SoA stepper, with each cold "
    "prefix group's KV promoted store->GPU as one per-tick cohort batch. "
    "The byte demand runs ~1.1x the degraded fabric's cross-node capacity, "
    "so the stream is transfer-bound: the spray policy's effective "
    "bandwidth — not the compute model — sets the drain rate, TTFT tails, "
    "and makespan. Four silently derated rails (mixed NIC generations) "
    "plus brownout windows mid-run are where blind striping loses its "
    "capacity margin.",
    topology=TopologyParams(
        nic_bw=2.5e7, tcp_bw=2.5e7,
        rail_bw_factors=((4, 0.3), (5, 0.3), (6, 0.3), (7, 0.3))),
    workload=ServingWorkload(
        concurrency=512, input_tokens=128, output_tokens=16,
        chunk_tokens=256, stream_requests=100_000, arrival_rate=300.0,
        zipf_alpha=1.1, traffic_groups=512, prefix_frac=0.9375,
        stream_kv_bytes_per_token=40_000, resident_s=2.0, tick_s=0.04),
    engine=EngineParams(slice_bytes=4 << 20, max_slices=16,
                        reset_interval=30.0),
    faults=(FaultEvent("degrade", 1, 0, at=80.0, until=140.0, factor=0.1),
            FaultEvent("degrade", 1, 1, at=180.0, until=240.0, factor=0.1)),
    # measured (seeded, deterministic): tent 15947 tok/s vs rr 4098 (3.9x),
    # TTFT P90 18.1s vs 47.0s, P99 36.8s, TPOT P99 0.025s
    expectations=Expectations(
        tent_vs_baseline=2.0, ttft_p90_vs_baseline=1.0,
        max_ttft_p99_s=60.0, max_tpot_p99_s=0.05),
))

# -- hetero-fabric portability (Table 4 beyond RDMA/TCP) ---------------------

_register(ScenarioSpec(
    "mnnvl_rack_kv",
    "Rack-scale Multi-Node NVLink: cross-node GPU-to-GPU KV rides the MNNVL "
    "backend (956 GB/s, no host path) with multi-rail RDMA as the ranked "
    "fallback — the portability matrix beyond RDMA/TCP (Table 4).",
    topology=TopologyParams(nic_bw=2.5e9, has_mnnvl=True),
    workload=ClosedLoopWorkload(
        streams=4, blocks=(4 << 20,), iters=8, endpoints="gpu"),
    expectations=Expectations(tent_vs_baseline=0.95),
))

_register(ScenarioSpec(
    "ascend_ub_kv",
    "Ascend unified-bus fabric (no NVLink): cross-node GPU KV rides the UB "
    "backend; the same declarative transfers, a different interconnect — "
    "the paper's <800-LOC-per-backend portability claim (Table 4).",
    topology=TopologyParams(nic_bw=2.5e9, has_nvlink=False, has_ub=True),
    workload=ClosedLoopWorkload(
        streams=4, blocks=(4 << 20,), iters=8, endpoints="gpu"),
    expectations=Expectations(tent_vs_baseline=0.95),
))

# -- multi-engine cluster scenarios (repro.cluster control plane) ------------

# 5-node incast fabric: 3 prefill nodes, 1 decode node, 1 cache-tier node.
_INCAST = ClusterWorkload(
    pattern="kv_incast", producer_nodes=(0, 1, 2), consumer_nodes=(3,),
    contender_nodes=(4,), streams_per_engine=2, block=1 << 20,
    iters=0, duration=0.04)

_register(ScenarioSpec(
    "multi_engine_kv_incast",
    "Three prefill engines converge KV on one decode pool while a cache-tier "
    "engine's statically ranked elephants pin two receiver NICs. The "
    "receiver-side pressure is invisible to siloed per-engine telemetry "
    "until slices are already stuck behind it; only the global diffusion "
    "table (omega blend, paper §4.2) steers the spray off the contended "
    "ordinals in advance — diffusion-ON tent must beat diffusion-OFF tent, "
    "not just the baselines.",
    topology=TopologyParams(n_nodes=5, nic_bw=1.0e9),
    workload=_INCAST,
    policies=("tent+diffusion", "tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.15),
    bucket=0.004,
))

_register(ScenarioSpec(
    "multi_engine_incast_flap",
    "Same cross-engine incast, plus a decode-side NIC flap: the first "
    "engine to observe the failure gossips it, so every other engine "
    "reroutes before paying the detection latency itself — cluster-wide "
    "self-healing within the virtual 50 ms budget (paper §4.3 at cluster "
    "scope).",
    topology=TopologyParams(n_nodes=5, nic_bw=1.0e9),
    workload=dataclasses.replace(_INCAST, duration=0.06),
    faults=(FaultEvent("fail", 3, 2, at=0.02, until=0.04),),
    policies=("tent+diffusion", "tent", "round_robin"),
    # the dip metric needs a dense pre-onset completion timeline, which an
    # incast-contended closed loop does not have; time-to-next-completion
    # (stall) is the meaningful cluster recovery bound here
    expectations=Expectations(tent_vs_baseline=1.1, max_stall_ms=50.0),
    bucket=0.004,
))

_register(ScenarioSpec(
    "lossy_gossip_flap",
    "The incast flap rerun with the control-plane crutch removed: every "
    "gossip message (telemetry snapshot, failure rumor, anti-entropy "
    "digest) rides a channel that drops 20% of them and delays the rest by "
    "5 ms virtual. Rumors get lost, telemetry rounds arrive stale — yet "
    "versioned records plus anti-entropy reconciliation must still heal the "
    "explicit wire failure cluster-wide inside the paper's 50 ms budget.",
    topology=TopologyParams(n_nodes=5, nic_bw=1.0e9),
    workload=dataclasses.replace(
        _INCAST, duration=0.06, gossip_loss=0.2, gossip_link_delay=0.005),
    faults=(FaultEvent("fail", 3, 2, at=0.02, until=0.04),),
    policies=("tent+diffusion", "tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.1, max_stall_ms=50.0),
    bucket=0.004,
))

_register(ScenarioSpec(
    "partial_view_incast",
    "The cross-engine incast with partial membership views: each gossip "
    "send addresses only a fanout-2 peer sample instead of the full roster, "
    "so no engine ever holds an instantaneous global load picture. Entries "
    "accumulate across rounds inside the staleness horizon and anti-entropy "
    "fills the rumor gaps — diffusion must still pay for itself against the "
    "siloed baseline.",
    topology=TopologyParams(n_nodes=5, nic_bw=1.0e9),
    workload=dataclasses.replace(_INCAST, fanout=2),
    policies=("tent+diffusion", "tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.10),
    bucket=0.004,
))

_register(ScenarioSpec(
    "engine_churn_diffusion",
    "Membership churn mid-incast: one prefill engine deregisters at 15 ms "
    "(its control-plane state must be garbage-collected — no ghost pressure "
    "from its final published footprint) and a cold engine joins at 20 ms "
    "on a fresh node, learning the cluster's load and open rumors only "
    "through diffusion and anti-entropy. The control plane must keep "
    "beating the siloed baseline >= 1.10x straight through both events.",
    topology=TopologyParams(n_nodes=6, nic_bw=1.0e9),
    workload=dataclasses.replace(_INCAST, duration=0.05),
    faults=(engine_leave("prefill2", at=0.015),
            engine_join("prefill5", 5, at=0.02)),
    policies=("tent+diffusion", "tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.10),
    bucket=0.004,
))

_register(ScenarioSpec(
    "churn_storm",
    "Membership churn in bursts, not single events: two prefill engines "
    "deregister back-to-back mid-incast, two cold engines join moments "
    "later, one of the joiners leaves again, and a decode-side NIC flaps "
    "while the roster is still settling. Every departure must be garbage-"
    "collected without ghost pressure, every joiner must bootstrap from "
    "gossip alone, no slice may be lost on any engine (including the ones "
    "that left with slices in flight), and the wire failure must still "
    "heal inside the 50 ms virtual budget through all of it.",
    topology=TopologyParams(n_nodes=8, nic_bw=1.0e9),
    workload=dataclasses.replace(_INCAST, duration=0.05),
    faults=(
        engine_leave("prefill1", at=0.010),
        engine_leave("prefill2", at=0.012),
        engine_join("prefill5", 5, at=0.014),
        engine_join("prefill6", 6, at=0.016),
        FaultEvent("fail", 3, 1, at=0.020, until=0.035),
        engine_leave("prefill5", at=0.025),
        engine_join("prefill7", 7, at=0.030),
    ),
    policies=("tent+diffusion", "tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.0, max_stall_ms=50.0),
    bucket=0.004,
))

_register(ScenarioSpec(
    "trainer_broadcast_fanout",
    "A trainer engine fans checkpoint shards out to three serving engines "
    "that are churning KV among themselves, while a cache-tier engine's "
    "statically pinned refill elephants sit on some of the serving nodes' "
    "receiver NICs: the diffusion table lets the trainer route its "
    "broadcast around queues it has never sent a byte into.",
    topology=TopologyParams(n_nodes=5, nic_bw=1.0e9),
    workload=ClusterWorkload(
        pattern="ckpt_broadcast", producer_nodes=(0,), consumer_nodes=(1, 2, 3),
        contender_nodes=(4,), streams_per_engine=1, block=1 << 20,
        nbytes=8 << 20, iters=6),
    policies=("tent+diffusion", "tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.15),
))

_register(ScenarioSpec(
    "uniform_spray",
    "Healthy symmetric fabric, host-to-host elephants: the null case. The "
    "spray must stay balanced across rails and telemetry overhead must not "
    "cost throughput against blind striping.",
    workload=ClosedLoopWorkload(streams=4, blocks=(8 << 20,), iters=12),
    expectations=Expectations(tent_vs_baseline=0.9, max_rail_imbalance=1.35),
))


def names() -> List[str]:
    return sorted(SCENARIOS)


def get(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have {names()}") from None
