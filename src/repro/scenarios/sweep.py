"""Monte Carlo fault sweeps: vmapped distributions over a `ScenarioSpec`.

`compile_spray_program` lowers a closed-loop scenario into the fixed-shape
`SprayProgram` the fused jax core consumes (repro.core.jit_core): it builds
the scenario's engine exactly as `ScenarioRunner` would — same topology, same
heterogeneity derating, same fault program, same turbulence — probes one
representative transfer to resolve the plan's stage-0 candidate rails, and
snapshots the fabric's installed fault/degradation schedule into dense
per-rail window arrays. `MonteCarloSweep` then vmaps that program over N seed
keys with per-seed jittered fault parameters (flap onset/duration, degrade
depth/timing) and reports healing-time and throughput *distributions* —
P50/P99/P99.9 with bootstrap confidence intervals — as a `SweepReport`,
which `benchmarks/mc_sweep.py` writes as a `tent-scenario-reports/v1`
trajectory (`BENCH_mc.json`) so the existing `benchmarks.diff` gate covers
distributional health too.

The compiled model is the *skeleton* of the engine, not the engine: one plan
stage (the probe transfer's hop 0), uniform slice length, one masked retry
per slice, round-granular clock advancement. That is the deliberate trade
for whole-distribution evaluation in one jit dispatch; scenarios needing
staged hops, substitution chains, churn, or app callbacks keep the
event-driven single-seed `ScenarioRunner` path. Determinism contract (pinned
in tests/test_mc_sweep.py): same spec + seed vector => byte-identical
`SweepReport`, and every vmapped per-seed lane is exact-equal to an
independent single-seed run.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.fabric import FAR_WINDOW, Fabric
from ..core.jit_core import (
    SprayProgram,
    jax_available,
    spray_single,
    spray_sweep,
)
from .runner import PolicyReport, ScenarioReport
from .spec import ClosedLoopWorkload, ScenarioSpec, ServingWorkload

__all__ = [
    "MonteCarloSweep",
    "SweepPolicyDist",
    "SweepReport",
    "compile_spray_program",
    "sweepable_names",
    "SWEEP_POLICIES",
]


def sweepable_names() -> List[str]:
    """Library scenarios the fused model can compile: closed-loop spray and
    batched serving streams, without join/leave churn (staged hops, the
    event-driven serving executors, and churn stay on the single-seed
    `ScenarioRunner`)."""
    from .library import SCENARIOS

    return [
        name for name, spec in SCENARIOS.items()
        if (isinstance(spec.workload, ClosedLoopWorkload)
            or (isinstance(spec.workload, ServingWorkload)
                and spec.workload.stream_requests > 0))
        and not any(f.is_churn for f in spec.faults)
    ]

# Policies the fused model knows how to score. The ablation baselines beyond
# round_robin (e.g. "random") have no fused twin yet; the sweep simply skips
# them rather than inventing semantics.
SWEEP_POLICIES = ("tent", "round_robin")

# Healing times are capped here for percentile math: a seed whose fault is
# never healed (no completion after onset) must dominate every healed seed
# without poisoning the arithmetic the way inf would.
HEAL_CAP_MS = 1e9

# Bounds on the estimated round count when the workload is duration-driven:
# enough rounds to cross every fault window that matters, bounded so a
# mis-estimated service time cannot explode compile shapes.
MIN_ROUNDS = 8
MAX_ROUNDS = 512


def compile_spray_program(spec: ScenarioSpec, *,
                          rounds: Optional[int] = None) -> SprayProgram:
    """Lower `spec` to a `SprayProgram`. Closed-loop workloads and batched
    serving streams only — the sweep models the spray loop, not the
    event-driven serving/cluster executors."""
    from .workloads import _stream_endpoints

    wl = spec.workload
    if isinstance(wl, ServingWorkload) and wl.stream_requests > 0:
        if any(f.is_churn for f in spec.faults):
            raise ValueError(
                "join/leave churn cannot be compiled into a single-engine "
                "spray program")
        return _compile_serving_stream(spec, rounds=rounds)
    if not isinstance(wl, ClosedLoopWorkload):
        raise ValueError(
            f"MonteCarloSweep models closed-loop spray scenarios and "
            f"batched serving streams; {spec.name!r} runs "
            f"{type(wl).__name__} — use the event-driven ScenarioRunner "
            "for it")
    if any(f.is_churn for f in spec.faults):
        raise ValueError(
            "join/leave churn cannot be compiled into a single-engine "
            "spray program")
    from .runner import ScenarioRunner

    # Probe engine: full environment installed (rail derating, fault
    # program, turbulence), clock never stepped — so the fault windows and
    # telemetry priors snapshot below are exactly the t=0 state every
    # single-seed run starts from. Built with the tent policy so the stage
    # candidates carry tier penalties; the candidate *set* is
    # policy-independent.
    engine, _ = ScenarioRunner(spec).build_engine("tent")
    src, dst, block = _stream_endpoints(engine, wl, 0)
    b = engine.allocate_batch()
    engine.submit_transfer(
        b, [(src.segment_id, 0, dst.segment_id, 0, block)])
    tcb = engine._batches[b].transfers[0]
    sc = engine._stage_cands(tcb, 0)
    if not sc.paths:
        raise ValueError(
            f"{spec.name!r}: probe transfer resolved no stage-0 candidates")

    n_slices = max(1, min(spec.engine.max_slices,
                          math.ceil(block / spec.engine.slice_bytes)))
    length = float(block) / n_slices
    wave = wl.streams * max(1, wl.batch_size) * n_slices

    if rounds is None:
        if wl.iters > 0:
            rounds = wl.iters
        else:
            # duration-driven: rounds to cover the declared horizon at the
            # aggregate nominal rate, with 20% headroom for faults
            agg = float(np.sum(np.where(np.isfinite(sc.penalty),
                                        sc.bandwidth, 0.0)))
            round_time = wave * length / max(agg, 1.0)
            rounds = int(np.clip(
                math.ceil(wl.duration / max(round_time, 1e-9) * 1.2),
                MIN_ROUNDS, MAX_ROUNDS))

    return _finish_program(spec, engine, sc, rounds=int(rounds),
                           wave=int(wave), length=length)


def _compile_serving_stream(spec: ScenarioSpec, *,
                            rounds: Optional[int] = None) -> SprayProgram:
    """Lower a batched serving-stream scenario: the spray workload is the
    per-tick cold-cohort promotion batches (store DRAM -> serving GPU HBM),
    so the probe transfer is one mean-sized nonzero cohort and each round
    models one cohort tick. Compute phases (prefill/decode) are outside the
    fused model on purpose — they are policy-invariant, so the transfer
    distribution is the part worth sweeping."""
    from ..core import Location, MemoryKind
    from .runner import ScenarioRunner
    from .traffic import TrafficSpec, promotion_bytes

    wl = spec.workload
    engine, _ = ScenarioRunner(spec).build_engine("tent")
    stream = TrafficSpec(
        requests=wl.stream_requests, arrival_rate=wl.arrival_rate,
        zipf_alpha=wl.zipf_alpha, groups=wl.traffic_groups,
        input_tokens=wl.input_tokens, output_tokens=wl.output_tokens,
    ).generate()
    promo = promotion_bytes(
        stream, prefix_frac=wl.prefix_frac,
        kv_bytes_per_token=wl.stream_kv_bytes_per_token,
        resident_s=wl.resident_s)
    # the batched stepper's tick grouping: one promotion batch per tick
    # with at least one cold request in it
    tick_ids = np.floor(stream.arrival / wl.tick_s).astype(np.int64)
    cohorts = np.zeros(int(tick_ids[-1]) + 1)
    np.add.at(cohorts, tick_ids, promo)
    nonzero = cohorts[cohorts > 0]
    if nonzero.size == 0:
        raise ValueError(
            f"{spec.name!r}: the stream promotes no bytes (every prefix "
            "group stays resident) — nothing for the sweep to model")
    block = int(nonzero.mean())

    numa = engine.topology.spec.node.gpu_numa(0)
    src = engine.register_segment(
        Location(node=wl.store_node, kind=MemoryKind.HOST_DRAM,
                 device=0, numa=0),
        block, name="sweep-probe-store", materialize=False)
    dst = engine.register_segment(
        Location(node=wl.gpu_node, kind=MemoryKind.DEVICE_HBM,
                 device=0, numa=numa),
        block, name="sweep-probe-gpu", materialize=False)
    b = engine.allocate_batch()
    engine.submit_transfer(
        b, [(src.segment_id, 0, dst.segment_id, 0, block)])
    tcb = engine._batches[b].transfers[0]
    sc = engine._stage_cands(tcb, 0)
    if not sc.paths:
        raise ValueError(
            f"{spec.name!r}: probe transfer resolved no stage-0 candidates")

    n_slices = max(1, min(spec.engine.max_slices,
                          math.ceil(block / spec.engine.slice_bytes)))
    if rounds is None:
        rounds = int(np.clip(nonzero.size, MIN_ROUNDS, MAX_ROUNDS))
    return _finish_program(spec, engine, sc, rounds=int(rounds),
                           wave=int(n_slices),
                           length=float(block) / n_slices)


def _finish_program(spec: ScenarioSpec, engine, sc, *, rounds: int,
                    wave: int, length: float) -> SprayProgram:
    """Snapshot the probe engine's candidate rails, telemetry priors, and
    installed fault/degradation schedule into the fixed-shape program."""
    D = len(sc.paths)
    slots = sc.local_slot
    store = engine.store
    bw_src = np.empty(D)
    bw_dst = np.empty(D)
    latency = np.empty(D)
    for i, p in enumerate(sc.paths):
        bw_src[i] = p.local.bandwidth * p.bw_factor
        bw_dst[i] = (p.remote.bandwidth * p.bw_factor
                     if p.remote is not None else np.inf)
        latency[i] = p.local.base_latency + sc.extra_latency[i]

    fw = engine.fabric.fault_window_arrays()
    row = {int(lid): k for k, lid in enumerate(fw["link_ids"])}
    kf = fw["fail_start"].shape[1]
    kd = fw["deg_start"].shape[1]
    # fail windows: union of the src and dst legs (either side down kills
    # the transfer); degradations stay per side (the fabric takes the min
    # of the two sides' effective bandwidths)
    fail_start = np.full((D, 2 * kf), FAR_WINDOW)
    fail_end = np.full((D, 2 * kf), FAR_WINDOW)
    degs_start = np.full((D, kd), FAR_WINDOW)
    degs_end = np.full((D, kd), FAR_WINDOW)
    degs_factor = np.ones((D, kd))
    degd_start = np.full((D, kd), FAR_WINDOW)
    degd_end = np.full((D, kd), FAR_WINDOW)
    degd_factor = np.ones((D, kd))
    for i, (lid, rid) in enumerate(zip(sc.local_links, sc.remote_links)):
        r = row[lid]
        fail_start[i, :kf] = fw["fail_start"][r]
        fail_end[i, :kf] = fw["fail_end"][r]
        degs_start[i] = fw["deg_start"][r]
        degs_end[i] = fw["deg_end"][r]
        degs_factor[i] = fw["deg_factor"][r]
        if rid is not None:
            rr = row[rid]
            fail_start[i, kf:] = fw["fail_start"][rr]
            fail_end[i, kf:] = fw["fail_end"][rr]
            degd_start[i] = fw["deg_start"][rr]
            degd_end[i] = fw["deg_end"][rr]
            degd_factor[i] = fw["deg_factor"][rr]

    return SprayProgram(
        n_rails=D,
        rounds=int(rounds),
        wave=int(wave),
        length=length,
        gamma=spec.engine.gamma,
        detect=Fabric.FAIL_DETECT_LATENCY,
        jitter=engine.fabric.links[sc.local_links[0]].jitter,
        bw_score=np.asarray(sc.bandwidth, dtype=np.float64),
        bw_src=bw_src,
        bw_dst=bw_dst,
        penalty=np.asarray(sc.penalty, dtype=np.float64),
        latency=latency,
        beta0=store.beta0_arr[slots].astype(np.float64),
        beta1=store.beta1_arr[slots].astype(np.float64),
        ewma_alpha=store.ewma_alpha_arr[slots].astype(np.float64),
        beta0_alpha=store.beta0_alpha_arr[slots].astype(np.float64),
        fail_start=fail_start,
        fail_end=fail_end,
        degs_start=degs_start,
        degs_end=degs_end,
        degs_factor=degs_factor,
        degd_start=degd_start,
        degd_end=degd_end,
        degd_factor=degd_factor,
    )


# ---------------------------------------------------------------------------
# Distributions and the report
# ---------------------------------------------------------------------------

_BOOTSTRAP_B = 200


def _percentiles(vals: np.ndarray) -> Tuple[float, float, float]:
    return (float(np.percentile(vals, 50)),
            float(np.percentile(vals, 99)),
            float(np.percentile(vals, 99.9)))


def _bootstrap_ci(vals: np.ndarray, q: float,
                  rng: np.random.Generator) -> Tuple[float, float]:
    """Seeded percentile-bootstrap 95% CI of the q-th percentile."""
    n = vals.shape[0]
    idx = rng.integers(0, n, size=(_BOOTSTRAP_B, n))
    stats = np.percentile(vals[idx], q, axis=1)
    return (float(np.percentile(stats, 2.5)),
            float(np.percentile(stats, 97.5)))


def _healing_ms(healing_s: np.ndarray) -> np.ndarray:
    """Per-seed healing times in virtual ms; -1 = scenario had no fault
    onset before that seed's makespan; never-healed seeds cap at
    HEAL_CAP_MS."""
    out = np.where(healing_s < 0.0, -1.0,
                   np.minimum(healing_s * 1e3, HEAL_CAP_MS))
    return out.astype(np.float64)


@dataclasses.dataclass(frozen=True)
class SweepPolicyDist:
    """One policy's per-seed metric vectors plus their summary stats."""

    policy: str
    healing_ms: Tuple[float, ...]  # -1 = no fault onset for that seed
    throughput: Tuple[float, ...]
    bytes_ok: Tuple[float, ...]
    lost: Tuple[float, ...]
    makespan: Tuple[float, ...]
    summary: Dict[str, float]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _summarize(policy: str, res: Dict[str, np.ndarray],
               base_seed: int) -> SweepPolicyDist:
    rng = np.random.default_rng(base_seed * 9176 + 11)
    heal = _healing_ms(res["healing_s"])
    healed = heal[heal >= 0.0]
    summary: Dict[str, float] = {}
    if healed.size:
        p50, p99, p999 = _percentiles(healed)
        summary["healing_p50_ms"] = p50
        summary["healing_p99_ms"] = p99
        summary["healing_p999_ms"] = p999
        lo, hi = _bootstrap_ci(healed, 50, rng)
        summary["healing_p50_ci_lo"], summary["healing_p50_ci_hi"] = lo, hi
        lo, hi = _bootstrap_ci(healed, 99.9, rng)
        summary["healing_p999_ci_lo"], summary["healing_p999_ci_hi"] = lo, hi
    else:
        for k in ("healing_p50_ms", "healing_p99_ms", "healing_p999_ms",
                  "healing_p50_ci_lo", "healing_p50_ci_hi",
                  "healing_p999_ci_lo", "healing_p999_ci_hi"):
            summary[k] = -1.0
    thr = res["throughput"]
    summary["throughput_p50"] = float(np.percentile(thr, 50))
    summary["throughput_p01"] = float(np.percentile(thr, 1))
    lo, hi = _bootstrap_ci(thr, 50, rng)
    summary["throughput_p50_ci_lo"], summary["throughput_p50_ci_hi"] = lo, hi
    summary["lost_total"] = float(np.sum(res["lost"]))
    return SweepPolicyDist(
        policy=policy,
        healing_ms=tuple(float(v) for v in heal),
        throughput=tuple(float(v) for v in thr),
        bytes_ok=tuple(float(v) for v in res["bytes_ok"]),
        lost=tuple(float(v) for v in res["lost"]),
        makespan=tuple(float(v) for v in res["makespan"]),
        summary=summary,
    )


@dataclasses.dataclass(frozen=True)
class SweepReport:
    """The distributional analogue of `ScenarioReport`: per-policy per-seed
    vectors + summaries, scenario-level violations evaluated against the
    spec's sweep expectations (`healing_p999_ms`,
    `throughput_p50_vs_baseline`)."""

    scenario: str
    n_seeds: int
    base_seed: int
    fault_jitter: float
    rounds: int
    wave: int
    policies: Dict[str, SweepPolicyDist]
    violations: Tuple[str, ...]
    spec: dict

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario,
            "ok": self.ok,
            "n_seeds": self.n_seeds,
            "base_seed": self.base_seed,
            "fault_jitter": self.fault_jitter,
            "rounds": self.rounds,
            "wave": self.wave,
            "violations": list(self.violations),
            "policies": {p: d.to_dict() for p, d in self.policies.items()},
            "spec": self.spec,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    def to_scenario_report(self) -> ScenarioReport:
        """Project the distribution into the `tent-scenario-reports/v1`
        shape `benchmarks.diff` gates: throughput = the policy's P50 across
        seeds, recovery/stall = healing P50/P99.9 ms, per-seed spread in
        the latency percentiles and the full summary in `extra`."""
        policies: Dict[str, PolicyReport] = {}
        for name, d in self.policies.items():
            mk = np.asarray(d.makespan)
            policies[name] = PolicyReport(
                policy=name,
                ok=True,
                bytes_total=int(np.percentile(np.asarray(d.bytes_ok), 50)),
                makespan=float(np.percentile(mk, 50)),
                throughput=d.summary["throughput_p50"],
                requests=self.n_seeds,
                latency_p50=float(np.percentile(mk, 50)),
                latency_p90=float(np.percentile(mk, 90)),
                latency_p99=float(np.percentile(mk, 99)),
                retries=0,
                exclusions=0,
                readmissions=0,
                substitutions=0,
                batches_failed=0,
                lost_slices=int(np.percentile(np.asarray(d.lost), 50)),
                rail_imbalance=0.0,
                recovery_ms=d.summary["healing_p50_ms"],
                stall_ms=d.summary["healing_p999_ms"],
                bytes_by_rail={},
                buckets_gbps=[],
                extra=dict(d.summary),
            )
        spec = dict(self.spec)
        spec["mc"] = {"n_seeds": self.n_seeds, "base_seed": self.base_seed,
                      "fault_jitter": self.fault_jitter,
                      "rounds": self.rounds, "wave": self.wave}
        return ScenarioReport(
            scenario=f"{self.scenario}::mc",
            policies=policies,
            violations=list(self.violations),
            spec=spec,
        )


# ---------------------------------------------------------------------------
# The sweep driver
# ---------------------------------------------------------------------------


class MonteCarloSweep:
    """Vmap one scenario over `n_seeds` fault draws.

    `fault_jitter` scales the per-seed perturbation of every declared fault
    window (onset and duration) and degradation depth: 0 replays the exact
    declared schedule N times (only transfer-level service jitter varies
    per seed), 0.25 (default) explores +-25% around it. Seeds derive from
    `fold_in(PRNGKey(base_seed), i)`, so the distribution is a pure
    function of (spec, n_seeds, base_seed, fault_jitter).
    """

    def __init__(self, spec: ScenarioSpec, *, n_seeds: int = 64,
                 fault_jitter: float = 0.25,
                 base_seed: Optional[int] = None,
                 rounds: Optional[int] = None,
                 policies: Optional[Sequence[str]] = None):
        if not jax_available():  # pragma: no cover - jax is baked in
            raise RuntimeError("MonteCarloSweep requires jax")
        if n_seeds < 1:
            raise ValueError("n_seeds must be >= 1")
        self.spec = spec
        self.n_seeds = int(n_seeds)
        self.fault_jitter = float(fault_jitter)
        self.base_seed = spec.seed if base_seed is None else int(base_seed)
        wanted = tuple(policies if policies is not None else spec.policies)
        self.policies = tuple(p for p in wanted if p in SWEEP_POLICIES)
        if not self.policies:
            raise ValueError(
                f"none of {wanted!r} has a fused sweep model "
                f"(supported: {SWEEP_POLICIES})")
        self.program = compile_spray_program(spec, rounds=rounds)

    def run(self) -> SweepReport:
        dists = {
            pol: _summarize(
                pol,
                spray_sweep(self.program, self.n_seeds,
                            base_seed=self.base_seed, policy=pol,
                            fault_jitter=self.fault_jitter),
                self.base_seed)
            for pol in self.policies
        }
        return SweepReport(
            scenario=self.spec.name,
            n_seeds=self.n_seeds,
            base_seed=self.base_seed,
            fault_jitter=self.fault_jitter,
            rounds=self.program.rounds,
            wave=self.program.wave,
            policies=dists,
            violations=tuple(self._violations(dists)),
            spec=self.spec.to_dict(),
        )

    def run_single(self, seed_index: int,
                   policy: str = "tent") -> Tuple[float, ...]:
        """One independently-jitted seed, for exact-parity pinning against
        the matching vmapped lane: `(throughput, healing_s, bytes_ok,
        lost, makespan)`."""
        return spray_single(
            self.program, base_seed=self.base_seed, seed_index=seed_index,
            policy=policy, fault_jitter=self.fault_jitter)

    def _violations(self, dists: Dict[str, SweepPolicyDist]) -> List[str]:
        exp = self.spec.expectations
        primary = self.policies[0]
        out: List[str] = []
        prim = dists[primary]
        if exp.healing_p999_ms > 0:
            p999 = prim.summary["healing_p999_ms"]
            if p999 < 0:
                out.append(
                    f"{primary}: healing_p999_ms expected <= "
                    f"{exp.healing_p999_ms:.1f}ms but no seed saw a fault "
                    "onset before its makespan")
            elif p999 > exp.healing_p999_ms:
                out.append(
                    f"{primary}: healing P99.9 {p999:.2f}ms exceeds "
                    f"{exp.healing_p999_ms:.1f}ms over "
                    f"{self.n_seeds} seeds")
        if exp.throughput_p50_vs_baseline > 0:
            p50 = prim.summary["throughput_p50"]
            for pol, d in dists.items():
                if pol == primary:
                    continue
                floor = exp.throughput_p50_vs_baseline * \
                    d.summary["throughput_p50"]
                if p50 < floor:
                    out.append(
                        f"{primary}: throughput P50 {p50 / 1e9:.3f}GB/s < "
                        f"{exp.throughput_p50_vs_baseline:.2f}x {pol} "
                        f"({d.summary['throughput_p50'] / 1e9:.3f}GB/s)")
        return out
