"""granite-34b [dense] — llama-arch code model, MQA (kv=1) [arXiv:2405.04324]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    source="arXiv:2405.04324",
)

SMOKE = CONFIG.with_(
    name="granite-34b-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=1, d_ff=512, vocab_size=1024,
)
