"""qwen2-0.5b [dense] — GQA with QKV bias [arXiv:2407.10671]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)

SMOKE = CONFIG.with_(
    name="qwen2-0.5b-smoke", num_layers=2, d_model=224, num_heads=4,
    num_kv_heads=2, d_ff=448, vocab_size=1024,
)
