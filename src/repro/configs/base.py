"""Model/workload configuration system.

Every assigned architecture gets one module in this package defining a
`CONFIG` (the exact published dimensions, source cited) and a `SMOKE`
variant (2 layers, d_model<=512, <=4 experts) for CPU tests. Workload input
shapes are defined here as well; the launcher resolves (arch, shape) pairs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

ARCH_IDS = [
    "qwen2_5_3b",
    "seamless_m4t_medium",
    "chameleon_34b",
    "hymba_1_5b",
    "dbrx_132b",
    "granite_34b",
    "qwen2_0_5b",
    "deepseek_7b",
    "mamba2_370m",
    "qwen3_moe_235b_a22b",
]

# canonical dashed ids (CLI --arch) -> module name
ARCH_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ARCH_ALIASES.update({a: a for a in ARCH_IDS})
# spec-sheet names
ARCH_ALIASES.update(
    {
        "qwen2.5-3b": "qwen2_5_3b",
        "seamless-m4t-medium": "seamless_m4t_medium",
        "chameleon-34b": "chameleon_34b",
        "hymba-1.5b": "hymba_1_5b",
        "dbrx-132b": "dbrx_132b",
        "granite-34b": "granite_34b",
        "qwen2-0.5b": "qwen2_0_5b",
        "deepseek-7b": "deepseek_7b",
        "mamba2-370m": "mamba2_370m",
        "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    }
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (parallel attn + SSM heads, Hymba-style)
    hybrid: bool = False
    # encoder-decoder (audio backbone)
    encoder_layers: int = 0
    # attention variant
    sliding_window: int = 0  # 0 = full causal; >0 = sliding-window (serving)
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # execution
    use_pallas: bool = False  # TPU kernels (validated in interpret mode)
    remat: str = "full"  # none | full  (training activation checkpointing)
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        if self.num_heads == 0:  # attention-free
            return 0
        return self.head_dim or self.d_model // self.num_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for MODEL_FLOPS = 6 N D) ----------------------
    def param_count(self, *, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, K, Hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += D * V
        per_layer = 0
        if self.arch_type != "ssm":
            attn = D * H * Hd + 2 * D * K * Hd + H * Hd * D
            if self.qkv_bias:
                attn += (H + 2 * K) * Hd
            per_layer += attn
        if self.num_experts > 0:
            e = self.experts_per_token if active_only else self.num_experts
            per_layer += D * self.num_experts  # router (always resident)
            per_layer += e * (3 * D * F)
        elif self.arch_type != "ssm":
            per_layer += 3 * D * F
        if self.arch_type in ("ssm", "hybrid") or self.ssm_state > 0:
            di, N, nh = self.ssm_d_inner, self.ssm_state, self.ssm_nheads
            ssm = D * (2 * di + 2 * N + nh) + di * D  # in/out proj (+B,C,dt)
            ssm += self.ssm_conv * (di + 2 * N) + nh * 2  # conv + A,D params
            per_layer += ssm
        n += L * per_layer
        n += 2 * D * L  # norms
        if self.is_encdec:
            # encoder layers: self-attn + ffn; plus decoder cross-attn
            enc = self.encoder_layers * (D * H * Hd * 2 + 2 * D * K * Hd + 3 * D * F + 2 * D)
            xattn = L * (D * H * Hd + 2 * D * K * Hd + H * Hd * D + D)
            n += enc + xattn
        return n


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Serving window used when a full-attention arch runs long_500k (DESIGN.md
# §Arch-applicability: the sub-quadratic carve-out).
LONG_CONTEXT_WINDOW = 8_192


def get_config(arch: str) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch)
    if mod_name is None:
        raise ValueError(f"unknown arch {arch!r}; known: {sorted(ARCH_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch)
    if mod_name is None:
        raise ValueError(f"unknown arch {arch!r}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt the model config to the workload shape (serving windows)."""
    if shape.kind == "decode" and shape.seq_len > 100_000:
        if cfg.arch_type == "ssm":
            return cfg  # attention-free: natively O(1)-state decode
        if cfg.sliding_window == 0 or cfg.sliding_window > LONG_CONTEXT_WINDOW:
            return cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg
