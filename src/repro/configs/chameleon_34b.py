"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

Backbone only: the VQ-VAE image tokenizer is a stub; image patches arrive as
ordinary token ids interleaved with text (early fusion), so input_specs()
provides an int32 token stream over the unified 65536 vocab.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    source="arXiv:2405.09818",
)

SMOKE = CONFIG.with_(
    name="chameleon-34b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, d_ff=512, vocab_size=1024,
)
