"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676].

Hymba fuses attention heads and SSM heads *in parallel* within each layer;
most attention is sliding-window. Meta-tokens are omitted (noted in
DESIGN.md) — they do not change the data-movement or sharding structure.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    hybrid=True,
    sliding_window=2048,
    source="arXiv:2411.13676",
)

SMOKE = CONFIG.with_(
    name="hymba-1.5b-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, d_ff=512, vocab_size=1024, ssm_state=16,
    sliding_window=128,
)
