from .base import (
    ARCH_ALIASES,
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    config_for_shape,
    get_config,
    get_smoke_config,
)

__all__ = [
    "ARCH_ALIASES", "ARCH_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "config_for_shape", "get_config", "get_smoke_config",
]
