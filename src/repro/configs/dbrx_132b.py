"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    rope_theta=5e5,
    source="hf:databricks/dbrx-base",
)

SMOKE = CONFIG.with_(
    name="dbrx-132b-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, d_ff=256, vocab_size=1024, num_experts=4,
    experts_per_token=2,
)
