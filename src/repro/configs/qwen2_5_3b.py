"""qwen2.5-3b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B (family card)",
)

SMOKE = CONFIG.with_(
    name="qwen2.5-3b-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, d_ff=512, vocab_size=1024,
)
