"""deepseek-7b [dense] — llama-arch, full MHA (kv=32) [arXiv:2401.02954]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    arch_type="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    source="arXiv:2401.02954",
)

SMOKE = CONFIG.with_(
    name="deepseek-7b-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=1024,
)
