"""seamless-m4t-medium [audio] — enc-dec multimodal backbone [arXiv:2308.11596].

Backbone only: the mel-spectrogram + conv feature extractor frontend is a
stub; input_specs() provides precomputed frame embeddings (B, S_enc, D).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,  # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    source="arXiv:2308.11596",
)

SMOKE = CONFIG.with_(
    name="seamless-m4t-medium-smoke", num_layers=2, encoder_layers=2,
    d_model=256, num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=1024,
)
