"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, fine-grained
[hf:Qwen/Qwen3-30B-A3B family]. This is the paper's own evaluation model
(Tables 2 and 3: Qwen3-235B-A22B-Instruct-2507)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,  # per-expert ffn width (fine-grained experts)
    vocab_size=151936,
    head_dim=128,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B (family card)",
)

SMOKE = CONFIG.with_(
    name="qwen3-moe-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=1024, head_dim=64,
    num_experts=4, experts_per_token=2,
)
