"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

SMOKE = CONFIG.with_(
    name="mamba2-370m-smoke", num_layers=2, d_model=256, vocab_size=1024,
    ssm_state=32,
)
