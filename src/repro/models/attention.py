"""Grouped-query attention: full/sliding-window prefill and cached decode.

Pure-jnp paths (XLA) are the default — they are what the multi-pod dry-run
lowers. When `cfg.use_pallas` is set, the prefill path dispatches to the
Pallas flash-attention kernel (TPU target, validated in interpret mode).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,S,H,Hd], k: [B,T,K,Hd] -> scores [B,K,G,S,T] with H = K*G."""
    B, S, H, Hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, Hd)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) / (Hd ** 0.5)


def _gqa_combine(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: [B,K,G,S,T], v: [B,T,K,Hd] -> [B,S,H,Hd]."""
    B, K, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, K * G, v.shape[-1])


def _expand_kv(k: jax.Array, G: int) -> jax.Array:
    """(B, T, K, Hd) -> (B, T, K*G, Hd). A broadcast XLA fuses into the dot;
    it puts attention in plain-MHA form so the *combined* head dim shards
    over the model mesh axis even when kv_heads < mesh (GQA/MQA)."""
    if G == 1:
        return k
    B, T, K, Hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, T, K, G, Hd)).reshape(B, T, K * G, Hd)


def attend_full(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Self-attention over equal-length q/k (train & prefill).

    window > 0 applies sliding-window masking (each query sees the last
    `window` keys, inclusive).
    """
    from ..sharding.ctx import constrain

    B, S, H, Hd = q.shape
    T = k.shape[1]
    G = H // k.shape[2]
    k = constrain(_expand_kv(k, G), "bshd")
    v = constrain(_expand_kv(v, G), "bshd")
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / (Hd ** 0.5)
    scores = constrain(scores, "bhst")
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    probs = constrain(probs, "bhst")
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def attend_cached(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid: jax.Array,
) -> jax.Array:
    """Single-step decode: q [B,1,H,Hd] against a (possibly ring-buffer)
    KV cache [B,W,K,Hd]; `valid` [W] or [B,W] marks live slots."""
    scores = _gqa_scores(q, k_cache).astype(jnp.float32)  # [B,K,G,1,W]
    if valid.ndim == 1:
        vmask = valid[None, None, None, None, :]
    else:
        vmask = valid[:, None, None, None, :]
    scores = jnp.where(vmask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_combine(probs, v_cache)


def attend_cross(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Bidirectional cross-attention (decoder -> encoder memory)."""
    scores = _gqa_scores(q, k).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_combine(probs, v)


CHUNKED_THRESHOLD = 2048
CHUNK_Q = 512


def attend_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = CHUNK_Q,
) -> jax.Array:
    """Flash-style q-chunked attention in pure jnp: scores materialize only
    per (chunk x S) block, and each chunk is rematerialized in the backward
    pass, so peak memory is O(B*H*chunk*S) instead of O(B*H*S^2). This is
    the XLA path the dry-run lowers; on real TPUs `use_pallas` swaps in the
    Pallas kernel with the same math."""
    B, S, H, Hd = q.shape
    assert S % chunk == 0, f"seq {S} % chunk {chunk}"
    nc = S // chunk

    from ..sharding.ctx import constrain

    # Sliding-window locality: a q-chunk at offset o only sees keys in
    # [o - window + 1, o + chunk), so slice k/v to a window-aligned span
    # instead of attending across all S keys (16x waste for 2k windows on
    # 32k sequences — see EXPERIMENTS.md §Perf, hymba prefill iteration).
    span = S
    if window > 0:
        span = min(S, chunk + window)
        span = ((span + chunk - 1) // chunk) * chunk  # keep spans aligned

    @jax.checkpoint
    def block(q_blk, offset):
        q_blk = constrain(q_blk, "bshd")
        if span < S:
            start = jnp.clip(offset + chunk - span, 0, S - span)
            k_blk = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            out = attend_full(
                k=k_blk, v=v_blk, q=q_blk, causal=causal, window=window,
                q_offset=offset - start,
            )
        else:
            out = attend_full(q_blk, k, v, causal=causal, window=window, q_offset=offset)
        return constrain(out, "bshd")

    qb = q.reshape(B, nc, chunk, H, Hd).transpose(1, 0, 2, 3, 4)

    def body(_, inp):
        q_blk, i = inp
        return None, block(q_blk, i * chunk)

    _, out = jax.lax.scan(body, None, (qb, jnp.arange(nc)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Hd)


def prefill_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0, use_pallas: bool = False
) -> jax.Array:
    if use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, causal=True, window=window)
    S = q.shape[1]
    if S > CHUNKED_THRESHOLD and S % CHUNK_Q == 0:
        return attend_chunked(q, k, v, causal=True, window=window)
    return attend_full(q, k, v, causal=True, window=window)


def cache_update(
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Write one step's K/V at `pos` (ring-buffer when window>0). Returns
    (k_cache, v_cache, valid-slot mask [W])."""
    W = k_cache.shape[1]
    slot = (pos % W if window > 0 else pos).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    valid = jnp.arange(W) <= pos  # before wrap; after wrap every slot is live
    valid = jnp.where(pos >= W, jnp.ones((W,), bool), valid)
    return k_cache, v_cache, valid
