"""Mixture-of-Experts FFN: top-k routing with two dispatch paths.

`moe_ffn_dense` is the readable oracle (computes every expert on every
token, then masks) — used for smoke-scale correctness tests only.

`moe_ffn_sorted` is the production path: sort-based gather/scatter dispatch
into per-expert capacity buckets (Megablocks-style but with static shapes),
so expert FLOPs are proportional to *active* experts, and the expert
dimension shards cleanly over the `model` mesh axis (expert parallelism —
the all-to-all the paper's EP workloads generate comes out of GSPMD here).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.ctx import constrain

# jax.shard_map landed in 0.6 (with check_vma); older installs only have
# jax.experimental.shard_map.shard_map (with check_rep).
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is not None:
    _SHARD_MAP_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_KW = {"check_rep": False}


def route(
    x: jax.Array, router_w: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """x: (T, D); router_w: (D, E). Returns (weights (T,k), idx (T,k), aux).

    Softmax-then-topk with renormalization; aux carries the load-balance
    loss (Switch-style) and router z-loss.
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    E = router_w.shape[1]
    # load-balance: E * sum_e (fraction of tokens to e) * (mean prob of e)
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1)  # (T, E)
    load = one_hot.mean(0)
    importance = probs.mean(0)
    lb_loss = E * jnp.sum(load * importance)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return weights, idx, {"lb_loss": lb_loss, "z_loss": z_loss}


def _expert_ffn(xe: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """xe: (E, C, D); weights: (E, D, F) / (E, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)


def moe_ffn_sorted(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,  # (T, D)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = max(1, int(T * k / E * cfg.moe_capacity_factor))
    weights, idx, aux = route(x, p["router"], k)

    flat_e = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok = order // k
    # rank of each pair within its expert group
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - group_start[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)  # overflow -> sentinel

    # gather tokens into (E, C, D) buckets; sentinel row is zeros
    table = jnp.full((E * C + 1,), T, dtype=jnp.int32)
    table = table.at[slot].set(jnp.where(keep, tok, T).astype(jnp.int32))
    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    xe = constrain(x_pad[table[: E * C]].reshape(E, C, D), "ecd")

    ye = constrain(_expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"]), "ecd")  # (E, C, D)

    # scatter back with combine weights (dropped pairs contribute zero)
    ye_flat = jnp.concatenate([ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
    contrib = ye_flat[slot] * keep[:, None]
    w_sorted = weights.reshape(-1)[order].astype(contrib.dtype)
    out = jnp.zeros((T, D), dtype=x.dtype).at[tok].add(contrib * w_sorted[:, None])
    dropped = (~keep).sum()
    aux = dict(aux, dropped=dropped)
    return out, aux


def _bucketize_local(
    x: jax.Array,  # (T, D) local tokens
    idx: jax.Array,  # (T, k) global expert choices
    weights: jax.Array,  # (T, k)
    *,
    e_lo: jax.Array,  # traced: this rank's first expert
    n_local: int,  # static: experts per rank
    capacity: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sort-based bucketing restricted to experts [e_lo, e_lo + n_local).
    Returns (xe (E_loc, C, D), slot, tok, w_sorted) for the scatter-back."""
    T, D = x.shape
    k = idx.shape[1]
    E_loc = n_local
    flat = idx.reshape(-1)
    local = jnp.where((flat >= e_lo) & (flat < e_lo + E_loc), flat - e_lo, E_loc)
    order = jnp.argsort(local, stable=True)
    sorted_e = local[order]
    tok = order // k
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E_loc + 1), side="left")
    rank = jnp.arange(T * k) - group_start[jnp.clip(sorted_e, 0, E_loc)]
    keep = (sorted_e < E_loc) & (rank < capacity)
    slot = jnp.where(keep, sorted_e * capacity + rank, E_loc * capacity)
    table = jnp.full((E_loc * capacity + 1,), T, dtype=jnp.int32)
    table = table.at[slot].set(jnp.where(keep, tok, T).astype(jnp.int32))
    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    xe = x_pad[table[: E_loc * capacity]].reshape(E_loc, capacity, D)
    w_sorted = jnp.where(keep, weights.reshape(-1)[order], 0.0)
    return xe, slot, tok, w_sorted


def moe_ffn_ep(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,  # (T, D) globally; rows sharded over the batch axes
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expert-parallel MoE FFN via shard_map.

    Tokens never leave their data shard: activations are replicated over the
    `model` axis anyway (batch-sharded), so every model-rank routes the same
    local tokens, computes only its E/`model` experts, and one psum over
    `model` combines partial outputs. Expert weights are FSDP-sharded over
    `data` and explicitly all-gathered per layer. Collectives per layer:
    3 weight all-gathers + 1 (T_local, D) psum — versus the global-gather
    dispatch's full-(T, D) all-reduces (see EXPERIMENTS.md §Perf)."""
    from jax.sharding import PartitionSpec as P

    from ..sharding.ctx import _cur

    ctx = _cur()
    if ctx is None or not ctx["enabled"] or ctx["model"] is None:
        return moe_ffn_sorted(cfg, p, x)
    mesh = ctx["mesh"]
    b = ctx["batch"]
    baxes = b if isinstance(b, tuple) else ((b,) if b else ())
    msize = mesh.shape["model"]
    E, k = cfg.num_experts, cfg.experts_per_token
    if E % msize != 0:
        return moe_ffn_sorted(cfg, p, x)
    E_loc = E // msize
    T = x.shape[0]
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    if T % bsize != 0:
        return moe_ffn_sorted(cfg, p, x)
    T_loc = T // bsize
    C = max(1, int(T_loc * k / E * cfg.moe_capacity_factor))
    # weight FSDP axis: (E, D, F) sharded (model, data, None); (E, F, D)
    # sharded (model, None, data) per sharding.rules
    d_data = cfg.d_model % mesh.shape.get("data", 1) == 0

    def local_fn(x_l, router, wg, wu, wd):
        if d_data and "data" in mesh.shape and mesh.shape["data"] > 1:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        r = jax.lax.axis_index("model")
        weights, idx, aux = route(x_l, router, k)
        xe, slot, tok, w_sorted = _bucketize_local(
            x_l, idx, weights, e_lo=r * E_loc, n_local=E_loc, capacity=C
        )
        ye = _expert_ffn(xe, wg, wu, wd)  # (E_loc, C, D)
        ye_flat = jnp.concatenate(
            [ye.reshape(E_loc * C, x_l.shape[1]), jnp.zeros((1, x_l.shape[1]), ye.dtype)], axis=0
        )
        contrib = ye_flat[slot] * w_sorted[:, None].astype(ye.dtype)
        partial = jnp.zeros_like(x_l).at[tok].add(contrib)
        out = jax.lax.psum(partial, "model")
        lb = jax.lax.pmean(aux["lb_loss"], baxes) if baxes else aux["lb_loss"]
        zl = jax.lax.pmean(aux["z_loss"], baxes) if baxes else aux["z_loss"]
        return out, lb, zl

    bspec = P(b if b else None, None)
    out, lb, zl = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            bspec,  # x rows over batch axes, replicated over model
            P(None, None),  # router replicated
            P("model", "data" if d_data else None, None),
            P("model", "data" if d_data else None, None),
            P("model", None, "data" if d_data else None),
        ),
        out_specs=(bspec, P(), P()),
        **_SHARD_MAP_KW,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, {"lb_loss": lb, "z_loss": zl, "dropped": jnp.zeros((), jnp.int32)}


def moe_ffn_dense(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,  # (T, D)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Oracle: every expert computes every token; combine masks select."""
    T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    weights, idx, aux = route(x, p["router"], k)
    xe = jnp.broadcast_to(x[None], (E, T, D))
    ye = _expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"])  # (E, T, D)
    combine = jnp.zeros((T, E), dtype=jnp.float32)
    combine = combine.at[jnp.arange(T)[:, None], idx].add(weights)
    out = jnp.einsum("te,etd->td", combine.astype(x.dtype), ye)
    return out, aux


def moe_param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": (D, E),
        "w_gate": (E, D, F),
        "w_up": (E, D, F),
        "w_down": (E, F, D),
    }
