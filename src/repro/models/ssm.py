"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

`ssd_chunked` is the chunked train/prefill form (quadratic intra-chunk,
linear inter-chunk recurrence); `ssd_recurrent_ref` is the step-by-step
oracle used by tests; `ssd_step` is the O(1) decode update. The depthwise
causal conv is expressed as a sum of shifts (kernel size 4), which XLA fuses
cleanly and which keeps the decode conv-buffer logic transparent.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.ctx import constrain


def segsum(a: jax.Array) -> jax.Array:
    """a: (..., T). Returns (..., T, T) with out[i, j] = sum_{k=j+1..i} a_k
    for i >= j, -inf above the diagonal."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    return jnp.where(mask, out, -jnp.inf)


def _interchunk_step(carry, inp):
    """Inter-chunk SSD recurrence: state_{c+1} = state_c * decay_c + states_c.

    Inside the compiled scan body, `prev * dec + st_c` gets contracted into
    a single-rounded fma, so the jitted recurrence drifts one ulp from an
    unfused (numpy-style) evaluation. `one` is traced and always exactly
    1.0 (dec = exp(...) > 0); dividing the product by it is exact but makes
    the add's operand a division result, which is not a contraction
    candidate (same guard as scheduler's EWMA scan, PR 8).
    """
    st_c, dec_c = inp  # (b,h,p,n), (b,h)
    prev = carry
    dec = dec_c[..., None, None].astype(carry.dtype)
    one = jnp.where(dec >= 0, 1.0, 2.0)
    new = (prev * dec) / one + st_c
    return new, prev


def ssd_chunked(
    x: jax.Array,  # (b, s, h, p) — pre-multiplied by dt
    a: jax.Array,  # (b, s, h)    — dt * A (negative log-decay increments)
    B: jax.Array,  # (b, s, n)
    C: jax.Array,  # (b, s, n)
    *,
    chunk: int,
    initial_state: jax.Array | None = None,  # (b, h, p, n)
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    if use_pallas:
        from repro.kernels.ssd_scan.ops import ssd_chunked as ssd_kernel

        return ssd_kernel(x, a, B, C, chunk=chunk, initial_state=initial_state)
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        # pad with identity steps (x=0, B=0, a=0): state passes through
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, st = ssd_chunked(x, a, B, C, chunk=chunk, initial_state=initial_state)
        return y[:, :s], st
    c = s // chunk
    xc = x.reshape(b, c, chunk, h, p)
    ac = a.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    Bc = B.reshape(b, c, chunk, n)
    Cc = C.reshape(b, c, chunk, n)

    a_cs = jnp.cumsum(ac, axis=-1)  # (b,h,c,l)
    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(segsum(ac))  # (b,h,c,l,l)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)
    # 2. per-chunk end states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)  # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])  # (b,h,c)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), dtype=x.dtype)

    states_t = states.transpose(1, 0, 2, 3, 4)  # (c,b,h,p,n)
    decay_t = chunk_decay.transpose(2, 0, 1)  # (c,b,h)
    final_state, states_prev = jax.lax.scan(
        _interchunk_step, initial_state.astype(jnp.float32),
        (states_t.astype(jnp.float32), decay_t)
    )
    states_prev = states_prev.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)
    # 4. state -> output contribution
    state_decay_out = jnp.exp(a_cs)  # (b,h,c,l)
    Y_off = jnp.einsum(
        "bcln,bchpn,bhcl->bclhp", Cc, states_prev.astype(x.dtype), state_decay_out.astype(x.dtype)
    )
    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, final_state.astype(x.dtype)


def ssd_recurrent_ref(
    x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
    initial_state: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step oracle: h_t = exp(a_t) h_{t-1} + B_t x_t; y_t = C_t h_t."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    state0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(carry, t_in):
        x_t, a_t, B_t, C_t = t_in
        st = carry * jnp.exp(a_t).astype(jnp.float32)[..., None, None]
        st = st + jnp.einsum("bhp,bn->bhpn", x_t.astype(jnp.float32), B_t.astype(jnp.float32))
        y_t = jnp.einsum("bhpn,bn->bhp", st, C_t.astype(jnp.float32))
        return st, y_t

    xs = (
        x.transpose(1, 0, 2, 3),
        a.transpose(1, 0, 2),
        B.transpose(1, 0, 2),
        C.transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)


def ssd_step(
    state: jax.Array,  # (b, h, p, n) fp32
    x_t: jax.Array,  # (b, h, p) — pre-multiplied by dt
    a_t: jax.Array,  # (b, h)    — dt * A
    B_t: jax.Array,  # (b, n)
    C_t: jax.Array,  # (b, n)
) -> Tuple[jax.Array, jax.Array]:
    state = state * jnp.exp(a_t.astype(jnp.float32))[..., None, None]
    state = state + jnp.einsum("bhp,bn->bhpn", x_t.astype(jnp.float32), B_t.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
    return state, y


# ---------------------------------------------------------------------------
# Full Mamba2 mixer (in_proj -> conv -> SSD -> gate -> norm -> out_proj)
# ---------------------------------------------------------------------------

def mixer_param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    di, N, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * N
    return {
        "ssm_in": (cfg.d_model, 2 * di + 2 * N + nh),
        "ssm_conv_w": (cfg.ssm_conv, conv_dim),
        "ssm_conv_b": (conv_dim,),
        "ssm_dt_bias": (nh,),
        "ssm_A_log": (nh,),
        "ssm_D": (nh,),
        "ssm_norm": (di,),
        "ssm_out": (di, cfg.d_model),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    assert dt.shape[-1] == nh
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv as a sum of shifts. xBC: (b, s, c); w: (k, c)."""
    k = w.shape[0]
    out = jnp.zeros_like(xBC)
    for i in range(k):
        shift = k - 1 - i
        shifted = jnp.pad(xBC, ((0, 0), (shift, 0), (0, 0)))[:, : xBC.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu(out + b)


def mamba2_mixer(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
    *, initial_state: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Train/prefill mixer. x: (b, s, D) -> (y (b, s, D), final_state,
    conv_tail (b, conv-1, conv_dim) — the decode conv buffer)."""
    b, s, _ = x.shape
    di, N, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["ssm_in"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    z = constrain(z, "bsf")
    xBC = constrain(xBC, "bsf")
    tail = cfg.ssm_conv - 1
    pad_raw = jnp.pad(xBC, ((0, 0), (tail, 0), (0, 0)))
    conv_tail = pad_raw[:, pad_raw.shape[1] - tail :, :]
    xBC = _causal_conv(xBC, p["ssm_conv_w"], p["ssm_conv_b"])
    xs = xBC[..., :di].reshape(b, s, nh, hd)
    B = xBC[..., di : di + N]
    C = xBC[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["ssm_dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["ssm_A_log"].astype(jnp.float32))
    a = (dt * A).astype(x.dtype)  # (b,s,nh)
    x_dt = xs * dt.astype(x.dtype)[..., None]
    y, final_state = ssd_chunked(x_dt, a, B, C, chunk=cfg.ssm_chunk,
                                 initial_state=initial_state,
                                 use_pallas=cfg.use_pallas)
    y = y + xs * p["ssm_D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    from .common import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["ssm_out"]), final_state, conv_tail


def mamba2_mixer_step(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
    conv_buf: jax.Array, state: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode mixer. x: (b, 1, D); conv_buf: (b, k-1, conv_dim);
    state: (b, nh, hd, N) fp32. Returns (y (b,1,D), conv_buf', state')."""
    b = x.shape[0]
    di, N, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["ssm_in"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = xBC[:, 0]  # (b, conv_dim)
    window = jnp.concatenate([conv_buf.astype(xBC.dtype), xBC[:, None, :]], axis=1)  # (b, k, c)
    conv = jnp.einsum("bkc,kc->bc", window, p["ssm_conv_w"]) + p["ssm_conv_b"]
    conv = jax.nn.silu(conv)
    new_buf = window[:, 1:].astype(conv_buf.dtype)
    xs = conv[:, :di].reshape(b, nh, hd)
    B = conv[:, di : di + N]
    C = conv[:, di + N :]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["ssm_dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["ssm_A_log"].astype(jnp.float32))
    a_t = dt1 * A  # (b, nh)
    x_dt = xs * dt1.astype(xs.dtype)[..., None]
    state, y = ssd_step(state, x_dt, a_t, B, C)
    y = y.astype(x.dtype) + xs * p["ssm_D"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, di)
    from .common import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["ssm_out"]), new_buf, state
