"""Composable model definition covering all six assigned families.

One functional implementation parameterized by ModelConfig:
  dense / vlm      -> GQA attention + SwiGLU FFN decoder
  moe              -> GQA attention + top-k expert FFN (sorted dispatch)
  ssm              -> Mamba2 SSD mixer blocks (attention-free)
  hybrid           -> parallel attention + SSM heads per layer + FFN
  audio (enc-dec)  -> bidirectional encoder over frame embeddings + causal
                      decoder with cross-attention

Layers are stacked [L, ...] and applied with `jax.lax.scan`, keeping HLO
size depth-independent (88- and 94-layer configs compile quickly even on a
512-device dry-run mesh). Entry points:

  init_params / param_shapes      parameters (concrete / abstract)
  forward                         causal LM forward (train & prefill)
  loss_fn                         token CE + MoE aux losses
  init_cache / cache_shapes       decode caches (concrete / abstract)
  decode_step                     single-token serve step
  encode                          audio encoder (enc-dec only)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import moe as moe_lib
from . import ssm as ssm_lib
from .attention import (
    attend_cached,
    attend_cross,
    cache_update,
    prefill_attention,
)
from .common import apply_rope, cross_entropy, dense_init, embed_init, rms_norm, rope_angles
from ..sharding.ctx import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    D, H, K, Hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": (D, H * Hd),
        "wk": (D, K * Hd),
        "wv": (D, K * Hd),
        "wo": (H * Hd, D),
    }
    if cfg.qkv_bias:
        s.update({"bq": (H * Hd,), "bk": (K * Hd,), "bv": (K * Hd,)})
    return s


def _layer_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    D = cfg.d_model
    s: Dict[str, tuple] = {"ln1": (D,)}
    if cfg.arch_type == "ssm":
        s.update(ssm_lib.mixer_param_shapes(cfg))
        return s
    s.update(_attn_shapes(cfg))
    if cfg.hybrid:
        s.update(ssm_lib.mixer_param_shapes(cfg))
    s["ln2"] = (D,)
    if cfg.num_experts > 0:
        s.update(moe_lib.moe_param_shapes(cfg))
    else:
        s.update({"w_gate": (D, cfg.d_ff), "w_up": (D, cfg.d_ff), "w_down": (cfg.d_ff, D)})
    if cfg.is_encdec:
        s.update({"lnx": (D,)})
        s.update({f"x{k}": v for k, v in _attn_shapes(cfg).items() if not k.startswith("b")})
    return s


def _encoder_layer_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    D = cfg.d_model
    s: Dict[str, tuple] = {"ln1": (D,), "ln2": (D,)}
    s.update(_attn_shapes(cfg))
    s.update({"w_gate": (D, cfg.d_ff), "w_up": (D, cfg.d_ff), "w_down": (cfg.d_ff, D)})
    return s


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    out: Dict[str, Any] = {
        "embed": (V, D),
        "final_norm": (D,),
        "layers": {k: (L,) + v for k, v in _layer_shapes(cfg).items()},
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = (D, V)
    if cfg.is_encdec:
        Le = cfg.encoder_layers
        out["encoder"] = {
            "layers": {k: (Le,) + v for k, v in _encoder_layer_shapes(cfg).items()},
            "final_norm": (D,),
        }
    return out


def _init_from_shapes(shapes: Dict[str, Any], key: jax.Array, dtype) -> Params:
    leaves, treedef = jax.tree_util.tree_flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))

    def mk(shape: tuple, k: jax.Array) -> jax.Array:
        if len(shape) == 1:
            return jnp.zeros(shape, dtype)
        fan_in = shape[-2]
        return dense_init(k, fan_in, shape, dtype)

    return jax.tree_util.tree_unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.bfloat16) -> Params:
    shapes = param_shapes(cfg)
    k_embed, k_rest, k_special = jax.random.split(key, 3)
    params = _init_from_shapes(shapes, k_rest, dtype)
    params["embed"] = embed_init(k_embed, shapes["embed"], dtype)
    lp = params["layers"]
    L = cfg.num_layers
    # norm weights -> ones; biases -> zeros
    for name in ("ln1", "ln2", "lnx"):
        if name in lp:
            lp[name] = jnp.ones_like(lp[name])
    for name in ("bq", "bk", "bv"):
        if name in lp:
            lp[name] = jnp.zeros_like(lp[name])
    params["final_norm"] = jnp.ones_like(params["final_norm"])
    if cfg.is_encdec:
        enc = params["encoder"]
        enc["final_norm"] = jnp.ones_like(enc["final_norm"])
        for name in ("ln1", "ln2"):
            enc["layers"][name] = jnp.ones_like(enc["layers"][name])
    # SSM special initializations (Mamba2 defaults)
    if "ssm_A_log" in lp:
        nh = cfg.ssm_nheads
        a0 = jnp.tile(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32))[None], (L, 1))
        lp["ssm_A_log"] = a0.astype(dtype)
        lp["ssm_D"] = jnp.ones((L, nh), dtype=dtype)
        lp["ssm_dt_bias"] = jnp.full((L, nh), -2.0, dtype=dtype)  # softplus ~ 0.12
        lp["ssm_norm"] = jnp.ones_like(lp["ssm_norm"])
        lp["ssm_conv_w"] = (
            jax.random.normal(k_special, lp["ssm_conv_w"].shape, jnp.float32) * 0.1
        ).astype(dtype)
        lp["ssm_conv_b"] = jnp.zeros_like(lp["ssm_conv_b"])
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct pytree (no allocation) — dry-run currency."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s, dtype),
        param_shapes(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )


# ---------------------------------------------------------------------------
# Forward (train & prefill)
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, lp, h, positions, prefix=""):
    B, S, _ = h.shape
    H, K, Hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", h, lp[prefix + "wq"])
    k = jnp.einsum("bsd,de->bse", h, lp[prefix + "wk"])
    v = jnp.einsum("bsd,de->bse", h, lp[prefix + "wv"])
    if cfg.qkv_bias and prefix == "":
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = constrain(q.reshape(B, S, H, Hd), "bshd")
    k = constrain(k.reshape(B, S, K, Hd), "bshd")
    v = constrain(v.reshape(B, S, K, Hd), "bshd")
    if positions is not None:
        cos, sin = rope_angles(positions, Hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _project_q(cfg: ModelConfig, w, h):
    B, S, _ = h.shape
    H, Hd = cfg.num_heads, cfg.resolved_head_dim
    return jnp.einsum("bsd,de->bse", h, w).reshape(B, S, H, Hd)


def _ring_cache(k: jax.Array, window: int) -> jax.Array:
    """Arrange the last `window` keys/values into decode ring-buffer order:
    absolute position p lands at slot p % window. k: (B, S, K, Hd)."""
    S = k.shape[1]
    if S <= window:
        pad = window - S
        return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    last = k[:, S - window :]
    slots = (jnp.arange(S - window, S) % window)
    out = jnp.zeros((k.shape[0], window) + k.shape[2:], k.dtype)
    return out.at[:, slots].set(last)


def _decoder_layer_train(cfg: ModelConfig, lp, x, enc_out, positions, collect_cache=False):
    aux = {}
    cache_out = {}
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.arch_type == "ssm":
        y, st, conv_tail = ssm_lib.mamba2_mixer(cfg, lp, h)
        if collect_cache:
            cache_out = {"ssm_state": st.astype(jnp.float32), "conv_buf": conv_tail}
        return x + y, aux, cache_out
    q, k, v = _project_qkv(cfg, lp, h, positions)
    a = prefill_attention(q, k, v, window=cfg.sliding_window, use_pallas=cfg.use_pallas)
    a = constrain(a, "bshd")
    attn = jnp.einsum("bse,ed->bsd", a.reshape(a.shape[0], a.shape[1], -1), lp["wo"])
    if collect_cache:
        if cfg.sliding_window > 0:
            cache_out["k"] = constrain(_ring_cache(k, cfg.sliding_window), "cache_kv")
            cache_out["v"] = constrain(_ring_cache(v, cfg.sliding_window), "cache_kv")
        else:
            # explicit reshard into decode-cache layout here, so the cache's
            # length-sharding can't propagate back into the attention loop
            cache_out["k"] = constrain(k, "cache_kv")
            cache_out["v"] = constrain(v, "cache_kv")
    mixed = attn
    if cfg.hybrid:
        y, st, conv_tail = ssm_lib.mamba2_mixer(cfg, lp, h)
        if collect_cache:
            cache_out["ssm_state"] = st.astype(jnp.float32)
            cache_out["conv_buf"] = conv_tail
        mixed = 0.5 * (attn + y)  # Hymba-style parallel head fusion
    x = x + mixed
    if cfg.is_encdec and enc_out is not None:
        hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
        qx = _project_q(cfg, lp["xwq"], hx)
        kx = jnp.einsum("bsd,de->bse", enc_out, lp["xwk"])
        vx = jnp.einsum("bsd,de->bse", enc_out, lp["xwv"])
        K, Hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kx = kx.reshape(enc_out.shape[0], enc_out.shape[1], K, Hd)
        vx = vx.reshape(enc_out.shape[0], enc_out.shape[1], K, Hd)
        xattn = attend_cross(qx, kx, vx)
        x = x + jnp.einsum("bse,ed->bsd", xattn.reshape(x.shape[0], x.shape[1], -1), lp["xwo"])
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.num_experts > 0:
        T = h2.shape[0] * h2.shape[1]
        # expert-parallel path under a mesh; local sorted dispatch otherwise
        y, moe_aux = moe_lib.moe_ffn_ep(cfg, lp, h2.reshape(T, -1))
        y = y.reshape(h2.shape)
        aux = {k: moe_aux[k] for k in ("lb_loss", "z_loss")}
    else:
        from .common import swiglu

        y = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return constrain(x + y, "bsd"), aux, cache_out


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings (B, S, D)."""
    enc = params["encoder"]

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, lp, h, jnp.arange(h.shape[1]))
        from .attention import attend_full

        a = attend_full(q, k, v, causal=False)
        x = x + jnp.einsum("bse,ed->bsd", a.reshape(x.shape[0], x.shape[1], -1), lp["wo"])
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        from .common import swiglu

        x = x + swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
        return x, None

    x, _ = jax.lax.scan(body, frames, enc["layers"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    enc_frames: Optional[jax.Array] = None,
    remat: Optional[bool] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal forward. tokens: (B, S) int32 -> logits (B, S, V) fp32 + aux."""
    B, S = tokens.shape
    x = constrain(params["embed"][tokens], "bsd")
    positions = jnp.arange(S)
    enc_out = None
    if cfg.is_encdec:
        assert enc_frames is not None, "enc-dec arch requires enc_frames"
        enc_out = encode(cfg, params, enc_frames)

    layer = functools.partial(_decoder_layer_train, cfg)
    use_remat = cfg.remat == "full" if remat is None else remat
    if use_remat:
        layer = jax.checkpoint(layer, static_argnums=())

    def body(carry, lp):
        x, lb, zl = carry
        x, aux, _ = layer(lp, x, enc_out, positions)
        lb = lb + aux.get("lb_loss", 0.0)
        zl = zl + aux.get("z_loss", 0.0)
        return (x, lb, zl), None

    zero = jnp.zeros((), jnp.float32)
    (x, lb, zl), _ = jax.lax.scan(body, (x, zero, zero), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = constrain(logits, "logits")
    denom = max(cfg.num_layers, 1)
    return logits, {"lb_loss": lb / denom, "z_loss": zl / denom}


def prefill_forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    enc_frames: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Serving prefill: one parallel pass over the prompt that RETURNS the
    decode cache (per-layer K/V in ring order / SSD states / conv tails).
    This is what the prefill_32k dry-run shape lowers — the cache output is
    the PD-disaggregation elephant flow."""
    B, S = tokens.shape
    x = constrain(params["embed"][tokens], "bsd")
    positions = jnp.arange(S)
    enc_out = None
    if cfg.is_encdec:
        assert enc_frames is not None
        enc_out = encode(cfg, params, enc_frames)

    def body(x, lp):
        x, _, cache = _decoder_layer_train(cfg, lp, x, enc_out, positions, collect_cache=True)
        return x, cache

    x, cache = jax.lax.scan(body, x, params["layers"])
    if cfg.is_encdec and enc_out is not None:
        K, Hd = cfg.num_kv_heads, cfg.resolved_head_dim
        lp = params["layers"]
        enc_len = enc_out.shape[1]
        cache["enc_k"] = jnp.einsum("bsd,lde->lbse", enc_out, lp["xwk"]).reshape(
            cfg.num_layers, B, enc_len, K, Hd
        )
        cache["enc_v"] = jnp.einsum("bsd,lde->lbse", enc_out, lp["xwv"]).reshape(
            cfg.num_layers, B, enc_len, K, Hd
        )
    x_last = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x_last, params["embed"]).astype(jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x_last, head).astype(jnp.float32)
    logits = constrain(logits, "logits")
    return logits[:, 0], cache


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]):
    logits, aux = forward(cfg, params, batch["tokens"], enc_frames=batch.get("enc_frames"))
    ce = cross_entropy(logits, batch["targets"])
    loss = ce
    if cfg.num_experts > 0:
        loss = loss + 0.01 * aux["lb_loss"] + 0.001 * aux["z_loss"]
    return loss, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def _cache_struct(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int, dtype=jnp.bfloat16
) -> Dict[str, tuple]:
    L, K, Hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    s: Dict[str, Any] = {}
    if cfg.arch_type != "ssm":
        s["k"] = ((L, batch, W, K, Hd), dtype)
        s["v"] = ((L, batch, W, K, Hd), dtype)
    if cfg.arch_type == "ssm" or cfg.hybrid:
        di, N = cfg.ssm_d_inner, cfg.ssm_state
        s["ssm_state"] = ((L, batch, cfg.ssm_nheads, cfg.ssm_headdim, N), jnp.float32)
        s["conv_buf"] = ((L, batch, cfg.ssm_conv - 1, di + 2 * N), dtype)
    if cfg.is_encdec:
        s["enc_k"] = ((L, batch, enc_len, K, Hd), dtype)
        s["enc_v"] = ((L, batch, enc_len, K, Hd), dtype)
    return s


def cache_shapes(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    return {
        k: jax.ShapeDtypeStruct(shape, dt)
        for k, (shape, dt) in _cache_struct(cfg, batch, max_len, enc_len, dtype).items()
    }


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    return {
        k: jnp.zeros(shape, dt)
        for k, (shape, dt) in _cache_struct(cfg, batch, max_len, enc_len, dtype).items()
    }


def _decoder_layer_step(cfg: ModelConfig, lp, x, cache_l, pos):
    """One layer, one token. x: (B, 1, D). cache_l: per-layer cache dict."""
    new_cache = {}
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.arch_type == "ssm":
        y, new_buf, new_state = ssm_lib.mamba2_mixer_step(
            cfg, lp, h, cache_l["conv_buf"], cache_l["ssm_state"]
        )
        new_cache["conv_buf"], new_cache["ssm_state"] = new_buf, new_state
        return x + y, new_cache
    positions = jnp.full((1,), pos)
    q, k, v = _project_qkv(cfg, lp, h, positions)
    k_cache, v_cache, valid = cache_update(
        cache_l["k"], cache_l["v"], k, v, pos, window=cfg.sliding_window
    )
    new_cache["k"], new_cache["v"] = k_cache, v_cache
    a = attend_cached(q, k_cache, v_cache, valid)
    attn = jnp.einsum("bse,ed->bsd", a.reshape(x.shape[0], 1, -1), lp["wo"])
    mixed = attn
    if cfg.hybrid:
        y, new_buf, new_state = ssm_lib.mamba2_mixer_step(
            cfg, lp, h, cache_l["conv_buf"], cache_l["ssm_state"]
        )
        new_cache["conv_buf"], new_cache["ssm_state"] = new_buf, new_state
        mixed = 0.5 * (attn + y)
    x = x + mixed
    if cfg.is_encdec:
        hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
        qx = _project_q(cfg, lp["xwq"], hx)
        xa = attend_cross(qx, cache_l["enc_k"], cache_l["enc_v"])
        x = x + jnp.einsum("bse,ed->bsd", xa.reshape(x.shape[0], 1, -1), lp["xwo"])
        new_cache["enc_k"], new_cache["enc_v"] = cache_l["enc_k"], cache_l["enc_v"]
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.num_experts > 0:
        T = h2.shape[0]
        y, _ = moe_lib.moe_ffn_sorted(cfg, lp, h2.reshape(T, -1))
        y = y.reshape(h2.shape)
    else:
        from .common import swiglu

        y = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x + y, new_cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Dict[str, jax.Array],
    token: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # scalar int32 (synchronized batch decode)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    pos = jnp.asarray(pos, jnp.int32)
    x = constrain(params["embed"][token], "bsd")

    def body(x, inp):
        lp, cache_l = inp
        x, new_cache = _decoder_layer_step(cfg, lp, x, cache_l, pos)
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = constrain(logits, "logits")
    return logits[:, 0], new_cache


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    max_len: int,
    *,
    enc_frames: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run the full prompt through the model and build a decode cache by
    replaying tokens through decode_step's cache layout. For full-attention
    archs this populates K/V; for SSM it folds the prompt into the state.

    This is the *functional* prefill used by tests and the serving example;
    the dry-run lowers `forward` for prefill shapes (cache construction is
    measured by decode shapes)."""
    B, S = tokens.shape
    enc_len = enc_frames.shape[1] if enc_frames is not None else 0
    cache = init_cache(cfg, B, max_len, enc_len, dtype=params["embed"].dtype)
    if cfg.is_encdec and enc_frames is not None:
        enc_out = encode(cfg, params, enc_frames)
        K, Hd = cfg.num_kv_heads, cfg.resolved_head_dim
        lp = params["layers"]
        ek = jnp.einsum("bsd,lde->lbse", enc_out, lp["xwk"]).reshape(
            cfg.num_layers, B, enc_len, K, Hd
        )
        ev = jnp.einsum("bsd,lde->lbse", enc_out, lp["xwv"]).reshape(
            cfg.num_layers, B, enc_len, K, Hd
        )
        cache["enc_k"], cache["enc_v"] = ek, ev

    def step(carry, t):
        cache, last = carry
        logits, cache = decode_step(cfg, params, cache, tokens[:, t][:, None], t)
        return (cache, logits), None

    (cache, last_logits), _ = jax.lax.scan(
        step, (cache, jnp.zeros((B, cfg.vocab_size), jnp.float32)), jnp.arange(S)
    )
    return last_logits, cache
