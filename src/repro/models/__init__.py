from .model import (
    abstract_params,
    cache_shapes,
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_shapes,
    prefill,
    prefill_forward,
)

__all__ = [
    "abstract_params", "cache_shapes", "decode_step", "encode", "forward",
    "init_cache", "init_params", "loss_fn", "param_shapes", "prefill",
    "prefill_forward",
]
