"""Shared model components: norms, RoPE, initializers, cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: [...]; returns cos/sin of shape [..., head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, Hd]; cos/sin: [..., S, Hd//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def dense_init(key: jax.Array, fan_in: int, shape: tuple, dtype=jnp.bfloat16) -> jax.Array:
    scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, shape: tuple, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, shape, dtype=jnp.float32) * 0.02).astype(dtype)


def cross_entropy(logits: jax.Array, targets: jax.Array, *, ignore_id: int = -1) -> jax.Array:
    """Mean token-level CE in fp32. logits [..., V], targets [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (targets != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    from ..sharding.ctx import constrain

    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    if h.ndim == 3:
        h = constrain(h, "bsf")
    return jnp.einsum("...f,fd->...d", h, w_down)
