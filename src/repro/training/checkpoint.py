"""Checkpointing: pytree <-> flat tensor table <-> disk / TENT segments.

`flatten_state` produces the named-tensor table that both the disk format
and the TENT checkpoint engine operate on — a checkpoint *is* a set of
segments, which is exactly how the paper's RL weight-update pipeline views
it (Moonshot Checkpoint Engine §5.1.2)."""
from __future__ import annotations

import io
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def flatten_state(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = prefix + "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def unflatten_like(tree: Any, table: Dict[str, np.ndarray], prefix: str = "") -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = prefix + "/".join(_path_str(p) for p in path)
        arr = table[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(path: str, params: Any, opt_state: Any | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    table = flatten_state(params, "params/")
    if opt_state is not None:
        table.update(flatten_state(opt_state, "opt/"))
    # bf16 isn't npz-native; view as uint16 with a dtype side-channel
    packed = {}
    for k, v in table.items():
        if v.dtype.name == "bfloat16":
            packed[k] = v.view(np.uint16)
            packed[k + "::dtype"] = np.asarray("bfloat16")
        else:
            packed[k] = v
    np.savez(path, **packed)


def load_checkpoint(path: str, params_like: Any, opt_like: Any | None = None):
    import jax.numpy as jnp

    raw = np.load(path, allow_pickle=False)
    table: Dict[str, np.ndarray] = {}
    for k in raw.files:
        if k.endswith("::dtype"):
            continue
        v = raw[k]
        if k + "::dtype" in raw.files:
            v = v.view(jnp.bfloat16)
        table[k] = v
    params = unflatten_like(params_like, table, "params/")
    if opt_like is not None:
        opt = unflatten_like(opt_like, table, "opt/")
        return params, opt
    return params
