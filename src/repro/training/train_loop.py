"""Single-host training loop used by examples and smoke-scale runs.

The production multi-pod path lowers the same `make_train_step` under the
mesh + sharding rules (see launch/dryrun.py); this loop drives it on
whatever devices exist.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import init_params, loss_fn
from .data import DataConfig, SyntheticTokens
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        params, opt_state, opt_aux = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **aux, **opt_aux}

    return train_step


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps: int
    tokens_per_sec: float


def train(
    cfg: ModelConfig,
    *,
    steps: int = 50,
    batch_size: int = 4,
    seq_len: int = 128,
    seed: int = 0,
    opt_cfg: Optional[AdamWConfig] = None,
    log_every: int = 10,
    log: Callable[[str], None] = print,
) -> TrainResult:
    opt_cfg = opt_cfg or AdamWConfig(warmup_steps=max(steps // 10, 1), total_steps=steps)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq_len, batch_size, seed=seed))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    t0 = time.perf_counter()
    it = iter(data)
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if cfg.is_encdec:
            batch["enc_frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (batch_size, seq_len // 4, cfg.d_model), jnp.bfloat16
            )
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            log(f"step {step:4d}  loss {loss:.4f}  gnorm {float(metrics['grad_norm']):.3f}")
    dt = time.perf_counter() - t0
    return TrainResult(losses=losses, steps=steps, tokens_per_sec=steps * batch_size * seq_len / dt)
