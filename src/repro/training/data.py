"""Synthetic data pipeline: deterministic, shardable token streams.

Generates Zipf-distributed "documents" joined by EOS, packed into fixed
(batch, seq) examples. Deterministic per (seed, shard, step) so multi-host
training is reproducible and each data-parallel rank reads disjoint streams
without coordination — the moral equivalent of a deterministic tfds pipeline
at laptop scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int  # per-shard batch
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 0
    zipf_a: float = 1.2


class SyntheticTokens:
    """Iterator of {"tokens": (B, S) int32, "targets": (B, S) int32}."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards

    def example(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.shard
        )
        B, S = cfg.batch_size, cfg.seq_len
        stream = np.empty((B, S + 1), dtype=np.int64)
        for b in range(B):
            toks = []
            while len(toks) < S + 1:
                n = max(8, int(rng.exponential(cfg.mean_doc_len)))
                doc = rng.zipf(cfg.zipf_a, size=n) % (cfg.vocab_size - 1) + 1
                toks.extend(doc.tolist())
                toks.append(cfg.eos_id)
            stream[b] = np.asarray(toks[: S + 1])
        return {
            "tokens": stream[:, :-1].astype(np.int32),
            "targets": stream[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.example(step)
            step += 1
