from .checkpoint import flatten_state, load_checkpoint, save_checkpoint, unflatten_like
from .data import DataConfig, SyntheticTokens
from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from .train_loop import TrainResult, make_train_step, train

__all__ = [
    "flatten_state", "load_checkpoint", "save_checkpoint", "unflatten_like",
    "DataConfig", "SyntheticTokens", "AdamWConfig", "adamw_update",
    "init_opt_state", "lr_schedule", "TrainResult", "make_train_step", "train",
]
