"""AdamW in pure JAX (no optax dependency). Moments in fp32, params in the
model dtype (bf16 for production configs); decoupled weight decay; global
grad-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, params: Any, grads: Any, state: Dict[str, Any]
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
