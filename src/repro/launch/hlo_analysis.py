"""Loop-aware HLO accounting — the dry-run "profiler".

`compiled.cost_analysis()` on the CPU backend counts each while-loop body
ONCE, so a 94-layer `lax.scan` model under-reports FLOPs/bytes/collectives
by ~94x (verified by microbenchmark). This module re-derives per-step,
per-device totals from the optimized HLO text:

  * parse every computation block, building a symbol table (value ->
    result type) so operand shapes can be resolved;
  * find `while` ops, read the trip count from the loop condition's s32
    bound constant, and propagate multipliers (nested loops multiply);
  * FLOPs: 2 * prod(result_dims) * prod(lhs contracting dims) per dot,
    scaled by the loop multiplier (convolutions are absent in these models);
  * bytes: operand + result bytes of top-level ops at fusion boundaries
    (a proxy for HBM traffic, the same convention cost_analysis uses);
  * collective bytes: result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, scaled.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$"
)
_REF_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dtype]
    return elems_total, bytes_total


def _first_shape(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    rest: str  # args + attrs


@dataclasses.dataclass
class _Comp:
    name: str
    ops: List[_Op]
    symbols: Dict[str, str]  # value name -> result type


def _parse_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = _Comp(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = _Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.symbols[op.name] = op.result_type
        else:
            # parameters: "%p.1 = s32[] parameter(0)" matches _OP_RE; tuples
            # and odd forms that don't are rare and skippable.
            pass
    return comps


def _trip_count(cond: _Comp) -> int:
    consts = {}
    for op in cond.ops:
        if op.opcode == "constant" and op.result_type.replace(" ", "").startswith("s32[]"):
            m = re.match(r"\(?(-?\d+)", op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    positive = [v for v in consts.values() if v > 0]
    if len(positive) == 1:
        return positive[0]
    # look at compare/fusion ops touching a constant
    for op in cond.ops:
        if op.opcode in ("compare", "fusion"):
            for name, val in consts.items():
                if val > 0 and ("%" + name) in op.rest:
                    return val
            m = re.search(r"constant\((\d+)\)", op.rest)
            if m:
                return int(m.group(1))
    if positive:
        return max(positive)
    return 1


def _dot_flops(op: _Op, symbols: Dict[str, str]) -> float:
    result_elems, _ = _shape_elems_bytes(op.result_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    refs = _REF_RE.findall(op.rest.split("metadata")[0])
    lhs_shape: List[int] = []
    for r in refs:
        if r in symbols:
            lhs_shape = _first_shape(symbols[r])
            break
    if m is None or not lhs_shape:
        return 2.0 * result_elems
    contract = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_shape):
            contract *= lhs_shape[idx]
    return 2.0 * result_elems * contract


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "while",
    "conditional", "call",
}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    loops: Dict[str, int] = dataclasses.field(default_factory=dict)
    dot_count: float = 0.0

    def add_collective(self, base: str, nbytes: float, mult: float) -> None:
        d = self.collectives.setdefault(base, {"count": 0.0, "bytes": 0.0})
        d["count"] += mult
        d["bytes"] += nbytes * mult
        self.collective_bytes += nbytes * mult


def analyze_hlo(hlo: str) -> HloStats:
    comps = _parse_computations(hlo)
    stats = HloStats()
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    entry = m.group(1) if m else None
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
    if entry is None:
        return stats

    def operand_bytes_list(op: _Op, symbols: Dict[str, str]) -> List[int]:
        out = []
        args = op.rest.split("), ")[0]
        for r in _REF_RE.findall(args):
            t = symbols.get(r)
            if t is not None:
                _, b = _shape_elems_bytes(t)
                out.append(b)
        return out

    def op_traffic(op: _Op, symbols: Dict[str, str]) -> float:
        """Result + operand bytes; dynamic-update-slice (and fusions rooted
        in one) update in place on TPU, so the aliased full buffer is not
        traffic — only the updated slice moves (~= the smaller operands)."""
        _, rbytes = _shape_elems_bytes(op.result_type)
        ops_b = operand_bytes_list(op, symbols)
        is_dus = "dynamic-update-slice" in op.opcode or (
            "dynamic_update_slice" in op.rest or "dynamic-update-slice" in op.name
        )
        if is_dus and ops_b and rbytes == max(ops_b):
            small = sum(ops_b) - max(ops_b)
            return 2.0 * small  # read update + write slice in place
        return rbytes + sum(ops_b)

    def walk(comp_name: str, mult: float, count_bytes: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            code = op.opcode
            if code == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb and mb.group(1) in comps:
                    stats.loops[mb.group(1)] = trips
                    walk(mb.group(1), mult * trips, count_bytes)
                continue
            base = None
            for c in _COLLECTIVES:
                if code == c or code == c + "-start":
                    base = c
                    break
            if base is not None:
                _, nbytes = _shape_elems_bytes(op.result_type)
                stats.add_collective(base, nbytes, mult)
            if code == "dot":
                stats.flops += _dot_flops(op, comp.symbols) * mult
                stats.dot_count += mult
            if count_bytes and code not in _SKIP_BYTES_OPS:
                stats.bytes += op_traffic(op, comp.symbols) * mult
            if code in ("fusion", "call", "conditional", "map", "reduce", "sort"):
                for attr in ("calls", "to_apply", "branch_computations"):
                    for name in re.findall(attr + r"=\{?%?([\w.\-]+)", op.rest):
                        if name in comps and name != comp_name:
                            walk(name, mult, False)

    walk(entry, 1.0, True)
    return stats
