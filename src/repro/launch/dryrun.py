import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split  — the two lines above MUST precede any jax-importing module:
# jax locks the device count at first backend initialization.
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ARCH_IDS, InputShape, ModelConfig, config_for_shape, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_terms
from repro.models import abstract_params, cache_shapes, decode_step, loss_fn, param_shapes, prefill_forward
from repro.models.model import forward
from repro.sharding.ctx import activation_sharding
from repro.sharding.rules import (
    batch_axes,
    batch_spec,
    cache_partition_specs,
    opt_state_specs,
    param_partition_specs,
)
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

"""Multi-pod dry-run: prove that every (architecture x input shape) lowers
and compiles on the production meshes (16x16 single-pod and 2x16x16
multi-pod), with no device allocation (ShapeDtypeStruct inputs only), and
extract memory/cost/collective analyses for EXPERIMENTS.md.

Decode shapes lower `serve_step` (one token against a seq_len KV cache);
prefill lowers the cache-producing `prefill_forward`; train lowers a full
AdamW `train_step`. long_500k uses the sliding-window serving variant for
full-attention archs (see DESIGN.md §Arch-applicability).
"""


def _spec_tree(tree: Any, mesh, specs: Any):
    """ShapeDtypeStructs with shardings attached."""
    return jax.tree_util.tree_map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree,
        specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def _abstract_opt_state(params: Any) -> Any:
    return {
        "m": jax.tree_util.tree_map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
        "v": jax.tree_util.tree_map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(
    arch: str, shape_name: str, mesh, *, fsdp: bool = True
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
    allocation) for every model input of the (arch, shape) workload."""
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    shapes = param_shapes(cfg)
    pspecs = param_partition_specs(cfg, mesh, shapes, fsdp=fsdp)
    params = _spec_tree(abstract_params(cfg), mesh, pspecs)
    bsh = NamedSharding(mesh, _divisible_batch_spec(mesh, shape.global_batch))
    rep = NamedSharding(mesh, P())
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {"cfg": cfg, "params": params, "pspecs": pspecs}
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh),
        }
        if cfg.is_encdec:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, S // 4, cfg.d_model), jnp.bfloat16, sharding=bsh
            )
        out["batch"] = batch
        out["opt_state"] = _spec_tree(
            _abstract_opt_state(params), mesh, opt_state_specs(pspecs)
        )
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh)
        if cfg.is_encdec:
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (B, S // 4, cfg.d_model), jnp.bfloat16, sharding=bsh
            )
    else:  # decode
        enc_len = 1024 if cfg.is_encdec else 0
        cshapes = cache_shapes(cfg, B, S, enc_len)
        cspecs = cache_partition_specs(cfg, mesh, cshapes)
        out["cache"] = _spec_tree(cshapes, mesh, cspecs)
        out["cspecs"] = cspecs
        out["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=bsh)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
    return out


def _divisible_batch_spec(mesh, B: int) -> P:
    """Batch over (pod, data), dropping trailing axes until B divides."""
    axes = list(batch_axes(mesh))
    while axes:
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if B % total == 0:
            return P(tuple(axes) if len(axes) > 1 else axes[0])
        axes.pop(0)
    return P()


def _lower(arch: str, shape_name: str, mesh, *, fsdp: bool = True, act_constraints: bool = True):
    shape = INPUT_SHAPES[shape_name]
    spec = input_specs(arch, shape_name, mesh, fsdp=fsdp)
    cfg: ModelConfig = spec["cfg"]
    pspecs = spec["pspecs"]
    psh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )
    rep = NamedSharding(mesh, P())
    with mesh, activation_sharding(mesh, enabled=act_constraints):
        if shape.kind == "train":
            opt_cfg = AdamWConfig()

            def train_step(params, opt_state, batch):
                (loss, aux), grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, batch), has_aux=True
                )(params)
                params, opt_state, opt_aux = adamw_update(opt_cfg, params, grads, opt_state)
                return params, opt_state, {"loss": loss, **aux, **opt_aux}

            osh = _spec_tree_shardings(spec["opt_state"], mesh)
            fn = jax.jit(
                train_step,
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(spec["params"], spec["opt_state"], spec["batch"])
        elif shape.kind == "prefill":
            enc_len = shape.seq_len // 4 if cfg.is_encdec else 0
            cshapes = cache_shapes(cfg, shape.global_batch, shape.seq_len, enc_len)
            cspecs = cache_partition_specs(cfg, mesh, cshapes)
            csh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
            if cfg.is_encdec:
                lowered = jax.jit(
                    lambda p, t, e: prefill_forward(cfg, p, t, enc_frames=e),
                    out_shardings=(None, csh),
                ).lower(spec["params"], spec["tokens"], spec["enc_frames"])
            else:
                lowered = jax.jit(
                    lambda p, t: prefill_forward(cfg, p, t),
                    out_shardings=(None, csh),
                ).lower(spec["params"], spec["tokens"])
        else:
            csh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), spec["cspecs"],
                is_leaf=lambda x: isinstance(x, P),
            )

            def serve_step(params, cache, token, pos):
                return decode_step(cfg, params, cache, token, pos)

            fn = jax.jit(serve_step, out_shardings=(None, csh), donate_argnums=(1,))
            lowered = fn.lower(spec["params"], spec["cache"], spec["token"], spec["pos"])
    return cfg, lowered


def _spec_tree_shardings(tree: Any, mesh):
    return jax.tree_util.tree_map(lambda sds: sds.sharding, tree)


def _model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_case(arch: str, shape_name: str, *, multi_pod: bool, fsdp: bool = True,
             act_constraints: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    shape = INPUT_SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind,
    }
    cfg0 = get_config(arch)
    if shape.kind == "decode" and shape.seq_len > 100_000 and cfg0.is_encdec:
        # enc-dec long-context decode is exercised via sliding window too
        pass
    rec["act_constraints"] = act_constraints
    t0 = time.time()
    cfg, lowered = _lower(arch, shape_name, mesh, fsdp=fsdp, act_constraints=act_constraints)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    # --- memory analysis (proves it fits) ---
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_size_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = {"error": str(e)[:200]}
    # analytic bytes/device (params + opt + cache), always available
    rec["analytic_bytes_per_device"] = _analytic_bytes(arch, shape_name, mesh)
    # --- cost analysis (FLOPs/bytes for the roofline) ---
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:
        cost = {}
        rec["cost_error"] = str(e)[:200]
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)  # loop-aware accounting (see hlo_analysis.py)
    roof = analyze_terms(
        flops=stats.flops, hbm=stats.bytes, coll=stats.collective_bytes,
        chips=chips, model_flops=_model_flops(cfg, shape),
    )
    rec["roofline"] = roof.to_dict()
    rec["collectives"] = stats.collectives
    rec["loops"] = stats.loops
    rec["cost_analysis_raw"] = {
        "flops": float(cost.get("flops", 0.0) or 0.0),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0) or 0.0),
    }
    rec["hlo_bytes"] = len(hlo)
    return rec


def _analytic_bytes(arch: str, shape_name: str, mesh) -> int:
    """Parameter/optimizer/cache bytes per device implied by the shardings."""
    import numpy as np

    spec = input_specs(arch, shape_name, mesh)
    total = 0

    def add(tree):
        nonlocal total
        for sds in jax.tree_util.tree_leaves(tree):
            shard_elems = np.prod(sds.sharding.shard_shape(sds.shape)) if sds.shape else 1
            total += int(shard_elems) * sds.dtype.itemsize

    add(spec["params"])
    if "opt_state" in spec:
        add(spec["opt_state"])
    if "cache" in spec:
        add(spec["cache"])
    return total


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES), help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh (512 chips)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else [a.replace("_", "-") for a in ARCH_IDS]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_case(arch, shape, multi_pod=mp)
                    r = rec["roofline"]
                    print(
                        f"OK   {tag}: lower {rec['lower_s']}s compile {rec['compile_s']}s | "
                        f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
                        f"collective {r['collective_s']:.3e}s -> {r['bottleneck']} | "
                        f"useful {r['useful_ratio']:.2f} | "
                        f"bytes/dev {rec['analytic_bytes_per_device']/2**30:.2f} GiB"
                    )
                    print(f"     memory_analysis: {rec['memory_analysis']}")
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": traceback.format_exc()[-2000:]}
                    print(f"FAIL {tag}: {e}")
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
