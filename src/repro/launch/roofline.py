"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (per step, per chip):

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

`compiled.cost_analysis()` reports the per-chip SPMD program's flops/bytes.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO and
sum the result-shape bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute. Hardware constants: TPU v5e — 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (conservative single-link serialization)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one shaped tensor, e.g. bf16[16,4096]{1,0} or f32[] or u32[2]{0:T(128)}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, Dict[str, float]]]:
    """Sum result-shape bytes of every collective op in the HLO module.

    Returns (total_bytes, per-op {count, bytes})."""
    per_op: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "bytes": 0} for op in _COLLECTIVES
    }
    total = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        # normalize fused/start variants: all-gather-start, all-reduce-start...
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        nbytes = _shape_bytes(type_str)
        per_op[base]["count"] += 1
        per_op[base]["bytes"] += nbytes
        total += nbytes
    return total, per_op


@dataclasses.dataclass
class Roofline:
    flops: float  # per chip
    hbm_bytes: float  # per chip
    coll_bytes: float  # per chip
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float  # 6 N D (or 6 N_active D)
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze_terms(
    *,
    flops: float,
    hbm: float,
    coll: float,
    chips: int,
    model_flops: float,
) -> Roofline:
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops * chips
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(coll),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
    )


def analyze(cost: Dict[str, float], hlo_text: str, *, chips: int, model_flops: float) -> Roofline:
    """Legacy path: raw cost_analysis values (loop bodies counted once)."""
    coll, _ = collective_bytes(hlo_text)
    return analyze_terms(
        flops=float(cost.get("flops", 0.0) or 0.0),
        hbm=float(cost.get("bytes accessed", 0.0) or 0.0),
        coll=float(coll),
        chips=chips,
        model_flops=model_flops,
    )
