"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; callers control when devices are materialized.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ("data", "model"); 2 pods = 512 chips with a
    leading "pod" axis. TPU v5e-256 pod topology."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — used by examples and smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
