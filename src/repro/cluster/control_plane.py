"""Cluster control plane: multiple TENT engines on one shared fabric.

The paper's deployment model is one engine process per serving role —
prefill pool, decode pool, cache tier, trainer — all moving data over the
same physical interconnects. `TentCluster` materializes that: one
`Topology` + `Fabric` (one virtual clock), one `TentEngine` per
`EngineRole`, each owning a disjoint node subset, wired together by the two
cluster services that dissolve the communication silos:

  * `GlobalLoadTable` — periodic telemetry diffusion feeding every engine's
    `TelemetryStore.global_load`, so the dormant omega term of Eq. 1
    finally sees other engines' traffic (paper §4.2);
  * `ClusterMembership` — failure-rumor gossip, so one engine's exclusion
    reroutes every engine's slices before they each pay the detection
    latency themselves (paper §4.3 at cluster scope).

Both services are enabled by `ClusterParams.diffusion`; with it off the
engines still share the wire (and contend on it) but observe each other only
through their own telemetry — the siloed baseline the paper argues against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

from ..core.engine import EngineConfig, TentEngine
from ..core.fabric import Fabric
from ..core.topology import FabricSpec, Topology
from .diffusion import GlobalLoadTable
from .membership import ClusterMembership


@dataclasses.dataclass(frozen=True)
class EngineRole:
    """One engine process: a name, the node subset it owns, its policy."""

    name: str
    nodes: Tuple[int, ...]
    policy: str = "tent"

    def __post_init__(self) -> None:
        if not self.nodes:
            raise ValueError(f"role {self.name!r} owns no nodes")


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """Control-plane knobs shared by all engines of one cluster."""

    diffusion: bool = True  # master switch for both cluster services
    global_weight: float = 0.6  # omega handed to every engine when on
    diffusion_period: float = 0.001  # seconds between telemetry exchanges
    diffusion_staleness: float = 0.02  # table entries older than this are dropped
    gossip_delay: float = 0.0005  # rumor propagation latency

    def __post_init__(self) -> None:
        if self.diffusion_period > 0 and self.diffusion_staleness < self.diffusion_period:
            # delivery is one period stale by construction; a smaller
            # staleness horizon would silently drop every table entry
            raise ValueError(
                f"diffusion_staleness ({self.diffusion_staleness}) must be >= "
                f"diffusion_period ({self.diffusion_period})")


class TentCluster:
    """N engines, one fabric, one virtual clock, two cluster services."""

    def __init__(
        self,
        spec: FabricSpec,
        roles: Sequence[EngineRole],
        *,
        engine_config: Optional[EngineConfig] = None,
        params: Optional[ClusterParams] = None,
        seed: int = 0,
    ):
        self.params = params or ClusterParams()
        self.topology = Topology(spec)
        self.fabric = Fabric(self.topology, seed=seed)
        self.roles = tuple(roles)
        self._validate_roles(self.roles, spec.n_nodes)
        base = engine_config or EngineConfig()
        omega = self.params.global_weight if self.params.diffusion else 0.0
        self.engines: Dict[str, TentEngine] = {}
        self._node_owner: Dict[int, str] = {}
        for role in self.roles:
            cfg = dataclasses.replace(
                base, policy=role.policy, global_diffusion_weight=omega)
            self.engines[role.name] = TentEngine(
                topology=self.topology, fabric=self.fabric,
                config=cfg, seed=seed, name=role.name,
            )
            for n in role.nodes:
                self._node_owner[n] = role.name
        self.diffusion: Optional[GlobalLoadTable] = None
        self.membership: Optional[ClusterMembership] = None
        if self.params.diffusion:
            self.diffusion = GlobalLoadTable(
                self.fabric, self.engines,
                period=self.params.diffusion_period,
                staleness=self.params.diffusion_staleness,
            )
            self.membership = ClusterMembership(
                self.fabric, self.engines,
                gossip_delay=self.params.gossip_delay,
            )

    @staticmethod
    def _validate_roles(roles: Sequence[EngineRole], n_nodes: int) -> None:
        names = [r.name for r in roles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate role names in {names}")
        owned: Dict[int, str] = {}
        for r in roles:
            for n in r.nodes:
                if not 0 <= n < n_nodes:
                    raise ValueError(
                        f"role {r.name!r} claims node {n} outside the "
                        f"{n_nodes}-node fabric")
                if n in owned:
                    raise ValueError(
                        f"node {n} owned by both {owned[n]!r} and {r.name!r}")
                owned[n] = r.name

    # ------------------------------------------------------------------ access
    def engine(self, name: str) -> TentEngine:
        return self.engines[name]

    def engine_for_node(self, node: int) -> TentEngine:
        return self.engines[self._node_owner[node]]

    @property
    def now(self) -> float:
        return self.fabric.now

    @property
    def busy(self) -> bool:
        return any(e.open_batches > 0 for e in self.engines.values())

    # ------------------------------------------------------------------ drive
    def start(self) -> None:
        """Arm the diffusion timer. Call after the first submissions; the
        timer keeps itself armed while any engine has open work."""
        if self.diffusion is not None:
            self.diffusion.arm()

    def step(self) -> bool:
        return self.fabric.step()

    def run_until_idle(self) -> None:
        self.fabric.run_until_idle()

    # ------------------------------------------------------------------ audit
    def audit(
        self, *, ignore: Optional[Dict[str, Iterable[int]]] = None
    ) -> Dict[str, Dict[str, int]]:
        """Per-engine slice accounting plus a merged `total` entry. The
        zero-lost-slice invariant must hold on *every* engine of the
        cluster, not just in aggregate."""
        ignore = ignore or {}
        out: Dict[str, Dict[str, int]] = {}
        total = {"batches_done": 0, "batches_failed": 0, "batches_open": 0,
                 "slices_outstanding": 0}
        for name, e in self.engines.items():
            a = e.audit(ignore=tuple(ignore.get(name, ())))
            out[name] = a
            for k in total:
                total[k] += a[k]
        out["total"] = total
        return out

    # ------------------------------------------------------------------ stats
    def counters(self) -> Dict[str, int]:
        """Cluster-wide resilience/scheduling counters, summed over engines."""
        out = {
            "retries": sum(e.slices_retried for e in self.engines.values()),
            "exclusions": sum(e.health.exclusions for e in self.engines.values()),
            "readmissions": sum(e.health.readmissions for e in self.engines.values()),
            "substitutions": sum(e.backend_substitutions for e in self.engines.values()),
            "diffusion_rounds": self.diffusion.rounds if self.diffusion else 0,
            "rumors_sent": self.membership.rumors_sent if self.membership else 0,
            "rumors_applied": self.membership.rumors_applied if self.membership else 0,
        }
        return out
