"""Cluster control plane: multiple TENT engines on one shared fabric.

The paper's deployment model is one engine process per serving role —
prefill pool, decode pool, cache tier, trainer — all moving data over the
same physical interconnects. `TentCluster` materializes that: one
`Topology` + `Fabric` (one virtual clock), one `TentEngine` per
`EngineRole`, each owning a disjoint node subset, wired together by the two
cluster services that dissolve the communication silos:

  * `GlobalLoadTable` — periodic telemetry diffusion feeding every engine's
    `TelemetryStore.global_load`, so the dormant omega term of Eq. 1
    finally sees other engines' traffic (paper §4.2);
  * `ClusterMembership` — failure-rumor gossip, so one engine's exclusion
    reroutes every engine's slices before they each pay the detection
    latency themselves (paper §4.3 at cluster scope).

Both services are enabled by `ClusterParams.diffusion`; with it off the
engines still share the wire (and contend on it) but observe each other only
through their own telemetry — the siloed baseline the paper argues against.

Control-plane realism: every cross-engine message rides one `GossipChannel`
(per-message loss probability `gossip_loss`, delivery delay
`gossip_link_delay`, seeded RNG) and addresses the peers in the sender's
`PeerSampler` view (`fanout` > 0 gives partial membership views); anti-
entropy reconciliation rides the diffusion cadence and closes whatever gaps
loss, delay, small fanout, or membership churn open. At loss 0 / delay 0 /
full views the channel is a pass-through and the cluster behaves exactly
like PR 2's idealized broadcast, bit for bit.

Membership churn: `add_engine` / `remove_engine` change the cluster mid-run.
A joiner starts with an empty load table and rumor replica (no instant
global knowledge — anti-entropy fills it in); a leaver's telemetry entries
and rumor replica are garbage-collected immediately on every peer so its
final published footprint cannot linger as ghost pressure. Departed engines
keep draining their in-flight slices on the data plane and stay visible to
`audit()`/`counters()` — the zero-lost-slice invariant covers engines that
left.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

from ..core.engine import EngineConfig, TentEngine
from ..obs import events as OBS
from ..core.fabric import Fabric, FabricConfig
from ..core.topology import FabricSpec, Topology
from .diffusion import GlobalLoadTable
from .gossip import GossipChannel, PeerSampler
from .membership import ClusterMembership


@dataclasses.dataclass(frozen=True)
class EngineRole:
    """One engine process: a name, the node subset it owns, its policy."""

    name: str
    nodes: Tuple[int, ...]
    policy: str = "tent"

    def __post_init__(self) -> None:
        if not self.nodes:
            raise ValueError(f"role {self.name!r} owns no nodes")


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """Control-plane knobs shared by all engines of one cluster."""

    diffusion: bool = True  # master switch for both cluster services
    global_weight: float = 0.6  # omega handed to every engine when on
    diffusion_period: float = 0.001  # seconds between telemetry exchanges
    diffusion_staleness: float = 0.02  # table entries older than this are dropped
    gossip_delay: float = 0.0005  # rumor propagation latency
    # control-plane link model (0/0/0 = PR 2's idealized lossless broadcast)
    gossip_loss: float = 0.0  # per-message drop probability
    gossip_link_delay: float = 0.0  # per-message delivery delay (virtual s)
    fanout: int = 0  # peers addressed per gossip send; <=0 = everyone

    def __post_init__(self) -> None:
        if self.diffusion_period > 0 and self.diffusion_staleness < self.diffusion_period:
            # delivery is one period stale by construction; a smaller
            # staleness horizon would silently drop every table entry
            raise ValueError(
                f"diffusion_staleness ({self.diffusion_staleness}) must be >= "
                f"diffusion_period ({self.diffusion_period})")
        if not 0.0 <= self.gossip_loss < 1.0:
            raise ValueError(f"gossip_loss must be in [0, 1), got {self.gossip_loss}")
        if self.gossip_link_delay < 0:
            raise ValueError(
                f"gossip_link_delay must be >= 0, got {self.gossip_link_delay}")
        if self.gossip_link_delay > 0 and self.diffusion_period > 0 and (
                self.gossip_link_delay + self.diffusion_period > self.diffusion_staleness):
            # a snapshot ages one period before it ships plus the link delay
            # in flight; past the horizon every delivery would arrive dead
            raise ValueError(
                f"gossip_link_delay ({self.gossip_link_delay}) + diffusion_period "
                f"({self.diffusion_period}) must be <= diffusion_staleness "
                f"({self.diffusion_staleness}) or every telemetry delivery arrives stale")


class TentCluster:
    """N engines, one fabric, one virtual clock, two cluster services."""

    def __init__(
        self,
        spec: FabricSpec,
        roles: Sequence[EngineRole],
        *,
        engine_config: Optional[EngineConfig] = None,
        params: Optional[ClusterParams] = None,
        seed: int = 0,
    ):
        self.params = params or ClusterParams()
        self.topology = Topology(spec)
        self._base_config = engine_config or EngineConfig()
        self.fabric = Fabric(
            self.topology, seed=seed,
            config=FabricConfig(event_queue="calendar")
            if self._base_config.calendar_queue else None)
        self.seed = seed
        self.roles = tuple(roles)
        self._validate_roles(self.roles, spec.n_nodes)
        self.engines: Dict[str, TentEngine] = {}
        self.departed: Dict[str, TentEngine] = {}
        self.joins = 0
        self.leaves = 0
        # flight recorder (repro.obs); attach_recorder fans it out to the
        # fabric, every engine (joiners included), and the membership layer
        self._rec = None
        self._node_owner: Dict[int, str] = {}
        for role in self.roles:
            self.engines[role.name] = self._build_engine(role)
            for n in role.nodes:
                self._node_owner[n] = role.name
        self.channel: Optional[GossipChannel] = None
        self.sampler: Optional[PeerSampler] = None
        self.diffusion: Optional[GlobalLoadTable] = None
        self.membership: Optional[ClusterMembership] = None
        if self.params.diffusion:
            # one channel + one roster shared by both services, seeded apart
            # from the fabric so control-plane loss never perturbs data-plane
            # jitter draws
            self.channel = GossipChannel(
                self.fabric, loss=self.params.gossip_loss,
                delay=self.params.gossip_link_delay, seed=seed * 7919 + 101)
            self.sampler = PeerSampler(
                fanout=self.params.fanout, seed=seed * 7919 + 202)
            self.diffusion = GlobalLoadTable(
                self.fabric, self.engines,
                period=self.params.diffusion_period,
                staleness=self.params.diffusion_staleness,
                channel=self.channel, sampler=self.sampler,
            )
            self.membership = ClusterMembership(
                self.fabric, self.engines,
                gossip_delay=self.params.gossip_delay,
                channel=self.channel, sampler=self.sampler,
            )
            # anti-entropy reconciliation rides the telemetry cadence
            self.diffusion.on_round = self.membership.run_anti_entropy

    def attach_recorder(self, rec) -> None:
        """Attach one shared `repro.obs.FlightRecorder` to every layer of
        the cluster: fabric fault events, each engine's scheduling and
        health events, and the membership gossip. Engines joining later are
        attached automatically in `add_engine`."""
        self._rec = rec
        self.fabric.attach_recorder(rec)
        for engine in self._all_engines().values():
            engine.attach_recorder(rec)
        if self.membership is not None:
            self.membership.attach_recorder(rec)

    def register_metrics(self, reg) -> None:
        """Expose the cluster's control-plane and scheduling counters on a
        `repro.obs.MetricsRegistry` as one lazy gauge group (a single
        `counters()` snapshot per collection)."""
        def _collect() -> Dict[str, float]:
            c = self.counters()
            out = {"engines": float(len(self.engines))}
            for key in ("diffusion_rounds", "rumors_sent", "rumors_applied",
                        "gossip_msgs", "gossip_dropped",
                        "anti_entropy_repairs", "engines_joined",
                        "engines_left", "slices_issued", "waves",
                        "completions_drained", "completion_batches"):
                out[key] = float(c[key])
            return out
        reg.gauge_group(_collect)

    def _build_engine(self, role: EngineRole) -> TentEngine:
        omega = self.params.global_weight if self.params.diffusion else 0.0
        cfg = dataclasses.replace(
            self._base_config, policy=role.policy, global_diffusion_weight=omega)
        return TentEngine(
            topology=self.topology, fabric=self.fabric,
            config=cfg, seed=self.seed, name=role.name,
        )

    @staticmethod
    def _validate_roles(roles: Sequence[EngineRole], n_nodes: int) -> None:
        names = [r.name for r in roles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate role names in {names}")
        owned: Dict[int, str] = {}
        for r in roles:
            for n in r.nodes:
                if not 0 <= n < n_nodes:
                    raise ValueError(
                        f"role {r.name!r} claims node {n} outside the "
                        f"{n_nodes}-node fabric")
                if n in owned:
                    raise ValueError(
                        f"node {n} owned by both {owned[n]!r} and {r.name!r}")
                owned[n] = r.name

    # ------------------------------------------------------------------ churn
    def add_engine(
        self, name: str, nodes: Tuple[int, ...], *, policy: str = "tent"
    ) -> TentEngine:
        """An engine joins the running cluster, owning `nodes` (which must be
        free — never owned, or released by a departed engine). It starts
        cold: empty telemetry table, empty rumor replica, no knowledge of
        open exclusions — the control plane's anti-entropy and the next
        diffusion rounds bring it up to speed, exactly like a process joining
        a real deployment."""
        if name in self.engines or name in self.departed:
            raise ValueError(f"engine name {name!r} already used in this cluster")
        role = EngineRole(name, tuple(nodes), policy)
        for n in role.nodes:
            if not 0 <= n < self.topology.spec.n_nodes:
                raise ValueError(
                    f"role {name!r} claims node {n} outside the "
                    f"{self.topology.spec.n_nodes}-node fabric")
            if n in self._node_owner:
                raise ValueError(
                    f"node {n} owned by both {self._node_owner[n]!r} and {name!r}")
        engine = self._build_engine(role)
        self.engines[name] = engine
        self.roles = self.roles + (role,)
        for n in role.nodes:
            self._node_owner[n] = name
        if self.diffusion is not None:
            self.diffusion.attach(name)
            # the timer may have quiesced while the cluster was idle before
            # this join; re-arm so the joiner actually gets diffusion rounds
            # and anti-entropy (arm is idempotent, and the next tick disarms
            # again if nobody has open work)
            self.diffusion.arm()
        if self.membership is not None:
            self.membership.join(name, engine)
        self.joins += 1
        if self._rec is not None:
            engine.attach_recorder(self._rec)
            self._rec.append(OBS.ENGINE_JOIN, self.fabric.now, {
                "engine": name, "nodes": list(role.nodes)})
        return engine

    def remove_engine(self, name: str) -> TentEngine:
        """An engine leaves the running cluster: its telemetry entries are
        garbage-collected from every peer's table immediately (no ghost
        pressure until the staleness horizon), its rumor replica and health
        hooks are dropped, and its nodes are released. The engine object
        itself keeps draining any in-flight slices on the shared fabric and
        remains part of `audit()` — leaving is a control-plane event, not an
        amnesty for lost slices."""
        engine = self.engines.pop(name, None)
        if engine is None:
            raise KeyError(f"no active engine {name!r} to remove")
        self.departed[name] = engine
        self.roles = tuple(r for r in self.roles if r.name != name)
        for n in [n for n, owner in self._node_owner.items() if owner == name]:
            del self._node_owner[n]
        if self.diffusion is not None:
            self.diffusion.forget(name)
        if self.membership is not None:
            self.membership.leave(name, engine)
        # the leaver forgets the cluster too: its diffused view is void
        engine.store.clear_global()
        self.leaves += 1
        if self._rec is not None:
            self._rec.append(OBS.ENGINE_LEAVE, self.fabric.now,
                             {"engine": name})
        return engine

    # ------------------------------------------------------------------ access
    def engine(self, name: str) -> TentEngine:
        return self.engines[name]

    def engine_for_node(self, node: int) -> TentEngine:
        return self.engines[self._node_owner[node]]

    @property
    def now(self) -> float:
        return self.fabric.now

    @property
    def busy(self) -> bool:
        return any(e.open_batches > 0 for e in self.engines.values())

    def _all_engines(self) -> Dict[str, TentEngine]:
        out = dict(self.engines)
        out.update(self.departed)
        return out

    # ------------------------------------------------------------------ drive
    def start(self) -> None:
        """Arm the diffusion timer. Call after the first submissions; the
        timer keeps itself armed while any engine has open work."""
        if self.diffusion is not None:
            self.diffusion.arm()

    def step(self) -> bool:
        return self.fabric.step()

    def run_until_idle(self) -> None:
        self.fabric.run_until_idle()

    # ------------------------------------------------------------------ audit
    def audit(
        self, *, ignore: Optional[Dict[str, Iterable[int]]] = None
    ) -> Dict[str, Dict[str, int]]:
        """Per-engine slice accounting plus a merged `total` entry. The
        zero-lost-slice invariant must hold on *every* engine of the
        cluster — including engines that departed mid-run, whose in-flight
        batches still drain on the shared fabric."""
        ignore = ignore or {}
        out: Dict[str, Dict[str, int]] = {}
        total = {"batches_done": 0, "batches_failed": 0, "batches_open": 0,
                 "slices_outstanding": 0}
        for name, e in self._all_engines().items():
            a = e.audit(ignore=tuple(ignore.get(name, ())))
            out[name] = a
            for k in total:
                total[k] += a[k]
        out["total"] = total
        return out

    # ------------------------------------------------------------------ stats
    def counters(self) -> Dict[str, int]:
        """Cluster-wide resilience/scheduling counters, summed over all
        engines that ever served (active + departed), plus the control
        plane's gossip accounting."""
        engines = self._all_engines().values()
        out = {
            "retries": sum(e.slices_retried for e in engines),
            "exclusions": sum(e.health.exclusions for e in engines),
            "readmissions": sum(e.health.readmissions for e in engines),
            "substitutions": sum(e.backend_substitutions for e in engines),
            "slices_issued": sum(e.slices_issued for e in engines),
            "waves": sum(e.waves for e in engines),
            "completions_drained": sum(e.completions_drained for e in engines),
            "completion_batches": sum(e.completion_batches for e in engines),
            "diffusion_rounds": self.diffusion.rounds if self.diffusion else 0,
            "rumors_sent": self.membership.rumors_sent if self.membership else 0,
            "rumors_applied": self.membership.rumors_applied if self.membership else 0,
            "gossip_msgs": self.channel.sent if self.channel else 0,
            "gossip_dropped": self.channel.dropped if self.channel else 0,
            "anti_entropy_repairs": (
                self.membership.anti_entropy_repairs if self.membership else 0),
            "engines_joined": self.joins,
            "engines_left": self.leaves,
        }
        return out
