"""Cluster membership and failure-rumor propagation over a lossy channel.

One engine's data-plane observation — an explicit wire failure or an
implicitly detected straggler — soft-excludes the suspect link(s) locally
(paper §4.3). On a multi-engine fabric that observation is worth much more:
every peer that would route a slice over the same endpoint is about to pay
`FAIL_DETECT_LATENCY` plus retries to rediscover it. `ClusterMembership`
subscribes to each engine's `HealthMonitor` exclusion/readmission hooks and
gossips the event to the peers in the origin's current membership view, so
the cluster reroutes off a dying link within one rumor hop of the first
observation — and re-integrates it the moment the observing engine's prober
readmits it.

Unlike PR 2's zero-loss broadcast, rumors now travel as individual
`GossipChannel` messages that can be dropped or delayed, and with fanout-k
partial views a rumor doesn't even *address* every peer. Three mechanisms
keep the cluster consistent anyway:

  * versioned rumor records — every exclude/readmit event carries a
    monotonically increasing version; each engine holds a replica map
    (link -> latest record) and applies a record only when it is newer than
    what the replica holds, so reordered or duplicate deliveries are inert;
  * anti-entropy reconciliation — piggybacked on the diffusion cadence, each
    engine pushes its full replica to one rotating partner per round; a peer
    that missed a rumor (loss, partial view, or having joined after the
    fact) converges within a few rounds;
  * churn GC — `leave()` drops the departed engine's replica, unhooks its
    health callbacks and removes it from the roster, so no rumor state
    accumulates for engines that no longer exist (rumors it *originated*
    remain valid facts about links and stay in the survivors' replicas).

Rumor application cannot echo by construction: rumors are applied through
`HealthMonitor.apply_remote` (non-explicit exclude / non-verified readmit),
and the health hooks fire only for explicit failures / probe-verified
readmissions.

Lifecycle: an exclusion rumor for a link suppresses repeats for
`rumor_refresh` seconds (one outage, one rumor), then later explicit
observations re-gossip — so a rumor that never got closed (the origin's
prober stopped, or a blind reset readmitted locally without gossip) cannot
permanently silence future failure news for that link. Any engine's
probe-verified readmission closes the rumor cluster-wide. A peer whose
periodic blind reset readmitted a rumored link locally diverges from the
replica *state* only, never the replica *record* — anti-entropy will not
re-impose the exclusion (same version, no new information), exactly the
PR 2 semantics.
"""
from __future__ import annotations

import itertools
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

from ..obs import events as OBS
from .gossip import GossipChannel, PeerSampler

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from ..core.engine import TentEngine
    from ..core.fabric import Fabric

# one rumor record: (version, excluded?) for a link
Record = Tuple[int, bool]


class ClusterMembership:
    """Churning membership + versioned exclusion/readmission gossip."""

    def __init__(
        self,
        fabric: "Fabric",
        engines: Dict[str, "TentEngine"],
        *,
        gossip_delay: float = 0.0005,
        rumor_refresh: float = 0.05,
        channel: Optional[GossipChannel] = None,
        sampler: Optional[PeerSampler] = None,
    ):
        self.fabric = fabric
        self.engines = engines  # live view: TentCluster mutates it on churn
        self.gossip_delay = gossip_delay
        self.rumor_refresh = rumor_refresh
        self.channel = channel or GossipChannel(fabric)
        self.sampler = sampler or PeerSampler()
        self.rumors_sent = 0
        self.rumors_applied = 0
        self.anti_entropy_repairs = 0
        self.joins = 0
        self.leaves = 0
        # flight recorder (repro.obs); None = tracing off
        self._rec = None
        # Open rumors: link -> virtual time the exclusion rumor went out.
        # Closed by any probe-verified readmission (blind periodic resets
        # never gossip), and refreshable after `rumor_refresh` so a rumor
        # nobody managed to close cannot suppress future failure news.
        self._rumored: Dict[int, float] = {}
        # Per-engine rumor replicas: name -> {link_id: (version, excluded)}.
        # The version clock is global to the (simulated) cluster; records
        # only ever move forward, so replicas converge under any delivery
        # order anti-entropy and the lossy channel produce.
        self._vclock = itertools.count(1)
        self._state: Dict[str, Dict[int, Record]] = {}
        for name, e in engines.items():
            self._enroll(name, e)

    def attach_recorder(self, rec) -> None:
        self._rec = rec

    def members(self) -> List[str]:
        return sorted(self._state)

    # ------------------------------------------------------------------ churn
    def _enroll(self, name: str, engine: "TentEngine") -> None:
        self._state[name] = {}
        self.sampler.add(name)
        engine.health.on_exclude = self._hook(name, exclude=True)
        engine.health.on_readmit = self._hook(name, exclude=False)

    def join(self, name: str, engine: "TentEngine") -> None:
        """A new engine joined mid-run. It starts with an empty replica and
        no knowledge of open rumors — anti-entropy pushes from established
        members bring it up to date over the next rounds (partial membership
        by construction: there is no instant-bootstrap side channel)."""
        self._enroll(name, engine)
        self.joins += 1

    def leave(self, name: str, engine: "TentEngine") -> None:
        """An engine departed: unhook its health callbacks, GC its replica,
        drop it from the roster. In-flight messages addressed to it are
        dropped on delivery (`_receive` checks the roster)."""
        engine.health.on_exclude = None
        engine.health.on_readmit = None
        self.sampler.remove(name)
        self._state.pop(name, None)
        self.leaves += 1

    # ------------------------------------------------------------------ gossip
    def _hook(self, origin: str, *, exclude: bool):
        def fire(link_id: int) -> None:
            if exclude:
                last = self._rumored.get(link_id)
                if last is not None and self.fabric.now - last < self.rumor_refresh:
                    return  # this outage is already rumored cluster-wide
                self._rumored[link_id] = self.fabric.now
            elif link_id not in self._rumored:
                return  # local-only readmission of a never-rumored link
            else:
                del self._rumored[link_id]
            self.rumors_sent += 1
            version = next(self._vclock)
            replica = self._state.get(origin)
            if replica is not None:
                replica[link_id] = (version, exclude)
            # view() may sample (fanout-k partial views use seeded RNG), so
            # it must be called exactly once per rumor — the recorder reads
            # the same materialized list the send loop walks
            peers = list(self.sampler.view(origin))
            for peer in peers:
                self.channel.send(
                    lambda peer=peer: self._receive(peer, link_id, version, exclude),
                    extra_delay=self.gossip_delay,
                )
            rec = self._rec
            if rec is not None:
                rec.append(OBS.RUMOR_SENT, self.fabric.now, {
                    "engine": origin, "link": link_id, "version": version,
                    "exclude": exclude, "peers": len(peers)})

        return fire

    def _receive(self, peer: str, link_id: int, version: int, exclude: bool) -> bool:
        """One rumor record arrived at `peer` (directly or inside an
        anti-entropy digest). Version gating makes duplicates and reordered
        deliveries inert; only genuinely new records touch the peer's health
        (non-explicit / non-verified, so application never echoes)."""
        replica = self._state.get(peer)
        if replica is None:
            return False  # peer departed while the message was in flight
        cur = replica.get(link_id)
        if cur is not None and cur[0] >= version:
            return False  # stale or duplicate: the replica already knows more
        replica[link_id] = (version, exclude)
        engine = self.engines.get(peer)
        if engine is not None and engine.health.apply_remote(link_id, excluded=exclude):
            self.rumors_applied += 1
            rec = self._rec
            if rec is not None:
                rec.append(OBS.RUMOR_RECV, self.fabric.now, {
                    "engine": peer, "link": link_id, "version": version,
                    "exclude": exclude})
        return True

    # ------------------------------------------------------------- anti-entropy
    def run_anti_entropy(self) -> None:
        """One reconciliation round (piggybacked on the diffusion cadence):
        every member pushes its full replica to one rotating partner as a
        single channel message. Records the partner already holds are inert,
        so with a clean channel and full views this is a no-op; under loss,
        delay, partial views, or after a join it is what closes the gaps.
        Digests ride with the same `gossip_delay` as direct rumors, so a
        digest can never outrun the rumor it repairs."""
        rec = self._rec
        if rec is not None:
            rec.append(OBS.ANTI_ENTROPY, self.fabric.now,
                       {"members": len(self._state)})
        for name in list(self._state):
            replica = self._state.get(name)
            if not replica:
                continue  # nothing to reconcile from this member
            partner = self.sampler.anti_entropy_partner(name)
            if partner is None:
                continue
            digest = dict(replica)  # snapshot: in-flight mutation safe
            self.channel.send(
                lambda partner=partner, digest=digest: self._merge(partner, digest),
                extra_delay=self.gossip_delay,
            )

    def _merge(self, peer: str, digest: Dict[int, Record]) -> None:
        for link_id, (version, exclude) in digest.items():
            if self._receive(peer, link_id, version, exclude):
                self.anti_entropy_repairs += 1
