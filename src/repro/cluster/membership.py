"""Cluster membership and failure-rumor propagation.

One engine's data-plane observation — an explicit wire failure or an
implicitly detected straggler — soft-excludes the suspect link(s) locally
(paper §4.3). On a multi-engine fabric that observation is worth much more:
every peer that would route a slice over the same endpoint is about to pay
`FAIL_DETECT_LATENCY` plus retries to rediscover it. `ClusterMembership`
subscribes to each engine's `HealthMonitor` exclusion/readmission hooks and
gossips the event to all other members after a small propagation delay, so
the whole cluster reroutes off a dying link within one rumor hop of the
first observation — and re-integrates it the moment the observing engine's
prober readmits it.

Rumor application cannot echo by construction: rumors are applied through
non-explicit `exclude` and non-verified `readmit`, and the health hooks fire
only for explicit failures / probe-verified readmissions.

Lifecycle: an exclusion rumor for a link suppresses repeats for
`rumor_refresh` seconds (one outage, one rumor), then later explicit
observations re-gossip — so a rumor that never got closed (the origin's
prober stopped, or a blind reset readmitted locally without gossip) cannot
permanently silence future failure news for that link. Any engine's
probe-verified readmission closes the rumor cluster-wide.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from ..core.engine import TentEngine
    from ..core.fabric import Fabric


class ClusterMembership:
    """Static membership + exclusion/readmission gossip between engines."""

    def __init__(
        self,
        fabric: "Fabric",
        engines: Dict[str, "TentEngine"],
        *,
        gossip_delay: float = 0.0005,
        rumor_refresh: float = 0.05,
    ):
        self.fabric = fabric
        self.engines = engines
        self.gossip_delay = gossip_delay
        self.rumor_refresh = rumor_refresh
        self.rumors_sent = 0
        self.rumors_applied = 0
        # Open rumors: link -> virtual time the exclusion rumor went out.
        # Closed by any probe-verified readmission (blind periodic resets
        # never gossip), and refreshable after `rumor_refresh` so a rumor
        # nobody managed to close cannot suppress future failure news.
        self._rumored: Dict[int, float] = {}
        for name, e in engines.items():
            e.health.on_exclude = self._hook(name, exclude=True)
            e.health.on_readmit = self._hook(name, exclude=False)

    def members(self) -> List[str]:
        return sorted(self.engines)

    # ------------------------------------------------------------------ gossip
    def _hook(self, origin: str, *, exclude: bool):
        def fire(link_id: int) -> None:
            if exclude:
                last = self._rumored.get(link_id)
                if last is not None and self.fabric.now - last < self.rumor_refresh:
                    return  # this outage is already rumored cluster-wide
                self._rumored[link_id] = self.fabric.now
            elif link_id not in self._rumored:
                return  # local-only readmission of a never-rumored link
            else:
                del self._rumored[link_id]
            self.rumors_sent += 1
            self.fabric.call_after(
                self.gossip_delay,
                lambda: self._apply(origin, link_id, exclude),
            )

        return fire

    def _apply(self, origin: str, link_id: int, exclude: bool) -> None:
        # non-explicit exclude / non-verified readmit: never re-fires hooks;
        # only count applications that actually changed a peer's state
        for name, e in self.engines.items():
            if name == origin:
                continue
            if exclude:
                changed = e.health.exclude(link_id)
            else:
                changed = e.health.readmit(link_id)
            if changed:
                self.rumors_applied += 1
