"""Global load diffusion service (paper §4.2).

In the paper every TENT engine process periodically publishes its per-NIC
queue depths to a shared-memory table and blends a global load factor into
Eq. 1 with weight omega. This module is that table for the simulated
cluster: each diffusion round it collects every engine's telemetry snapshot
(local queues plus remote-endpoint charges, `TelemetryStore.snapshot`) and
writes into each engine's `store.global_load` the sum of *other* engines'
footprints. Delivery is deliberately one round stale — a round first
diffuses the previous round's snapshots, then publishes fresh ones — and
snapshots older than `staleness` are dropped entirely, so the scheduler only
ever acts on the kind of aged information a real shared-memory table holds.

The timer rides the shared fabric's virtual clock and disarms itself when no
engine has open work, so idle clusters quiesce and `run_until_idle` halts.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Tuple

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from ..core.engine import TentEngine
    from ..core.fabric import Fabric


class GlobalLoadTable:
    """Periodic cross-engine telemetry exchange on one shared fabric."""

    def __init__(
        self,
        fabric: "Fabric",
        engines: Dict[str, "TentEngine"],
        *,
        period: float = 0.001,
        staleness: float = 0.02,
    ):
        self.fabric = fabric
        self.engines = engines
        self.period = period
        self.staleness = staleness
        self.rounds = 0
        self._armed = False
        # engine name -> (publish time, {link_id: queued bytes})
        self._snapshots: Dict[str, Tuple[float, Dict[int, int]]] = {}

    # ------------------------------------------------------------------ timer
    def arm(self) -> None:
        """Start (or keep) the diffusion timer. Idempotent; call after
        submitting work. The timer re-arms itself while any engine is busy."""
        if self._armed or self.period <= 0:
            return
        self._armed = True
        self.fabric.call_after(self.period, self._tick)

    def _tick(self) -> None:
        self._armed = False
        self.diffuse()  # deliver LAST round's snapshots: one-period staleness
        self.publish()
        self.rounds += 1
        if any(e.open_batches > 0 for e in self.engines.values()):
            self.arm()

    # ------------------------------------------------------------------ table
    def publish(self) -> None:
        """Every engine writes its current footprint into the table."""
        now = self.fabric.now
        for name, e in self.engines.items():
            self._snapshots[name] = (now, e.store.snapshot())

    def diffuse(self) -> None:
        """Every engine reads the sum of *other* engines' fresh entries."""
        now = self.fabric.now
        for name, e in self.engines.items():
            agg: Dict[int, int] = {}
            for other, (t, snap) in self._snapshots.items():
                if other == name or (now - t) > self.staleness:
                    continue
                for lid, q in snap.items():
                    agg[lid] = agg.get(lid, 0) + q
            e.store.global_load = agg
