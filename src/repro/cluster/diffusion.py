"""Global load diffusion service (paper §4.2) over a modeled gossip channel.

In the paper every TENT engine process periodically publishes its per-NIC
queue depths and blends a global load factor into Eq. 1 with weight omega.
This module is that exchange for the simulated cluster — but unlike PR 2's
shared-memory table, delivery is now *messaging*: each diffusion round every
engine's snapshot (local queues plus remote-endpoint charges,
`TelemetryStore.snapshot`) is sent to the peers in its current membership
view as individual `GossipChannel` messages, each of which can be dropped or
delayed. Every engine keeps its own receive table (sender -> timestamped
snapshot); each round it re-derives `store.global_load` from the entries
that are still inside the staleness horizon, so a dropped or late round
degrades the view gracefully instead of corrupting it. Delivery remains one
round stale by construction — a round first ships the previous round's
snapshots, then captures fresh ones — and with a zero-loss/zero-delay
channel and full views this reduces exactly to PR 2's table.

Both ends of the exchange lean on the array-backed telemetry store: the
per-round `snapshot()` capture is one vectorized scan of the queue array
(not a per-link Python loop), and the `apply_global` delivery installs the
aggregated view as the sparse dict the omega blend reads once per wave —
see `repro.core.telemetry` for the array/dict split rationale.

The timer rides the shared fabric's virtual clock and disarms itself when no
engine has open work, so idle clusters quiesce and `run_until_idle` halts.
Engines can join (`attach`) and leave (`forget`) mid-run: a departed
engine's table entries are garbage-collected immediately on every peer, so
its final published footprint cannot linger as ghost pressure.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Dict, Optional, Tuple

from .gossip import GossipChannel, PeerSampler

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from ..core.engine import TentEngine
    from ..core.fabric import Fabric


class GlobalLoadTable:
    """Periodic cross-engine telemetry exchange over the gossip channel."""

    def __init__(
        self,
        fabric: "Fabric",
        engines: Dict[str, "TentEngine"],
        *,
        period: float = 0.001,
        staleness: float = 0.02,
        channel: Optional[GossipChannel] = None,
        sampler: Optional[PeerSampler] = None,
    ):
        self.fabric = fabric
        self.engines = engines  # live view: TentCluster mutates it on churn
        self.period = period
        self.staleness = staleness
        self.channel = channel or GossipChannel(fabric)
        self.sampler = sampler or PeerSampler()
        for name in engines:
            self.sampler.add(name)
        self.rounds = 0
        self._armed = False
        # a hook the cluster uses to piggyback anti-entropy on the cadence
        self.on_round: Optional[Callable[[], None]] = None
        # engine name -> (publish time, {link_id: queued bytes}) captured at
        # the END of the previous round — what this round ships (one-round
        # staleness by construction)
        self._outbox: Dict[str, Tuple[float, Dict[int, int]]] = {}
        # receiver name -> sender name -> (publish time, snapshot): each
        # engine's own partial, possibly stale view of the cluster's load
        self._tables: Dict[str, Dict[str, Tuple[float, Dict[int, int]]]] = {
            name: {} for name in engines
        }

    # ------------------------------------------------------------------ timer
    def arm(self) -> None:
        """Start (or keep) the diffusion timer. Idempotent; call after
        submitting work. The timer re-arms itself while any engine is busy."""
        if self._armed or self.period <= 0:
            return
        self._armed = True
        self.fabric.call_after(self.period, self._tick)

    def _tick(self) -> None:
        self._armed = False
        self.diffuse()  # ship LAST round's snapshots: one-period staleness
        self.publish()
        self.rounds += 1
        if self.on_round is not None:
            self.on_round()
        if any(e.open_batches > 0 for e in self.engines.values()):
            self.arm()

    # ------------------------------------------------------------------ churn
    def attach(self, name: str) -> None:
        """An engine joined: give it an empty receive table and a roster slot.
        Its view of the cluster fills in over the next rounds — partial
        knowledge by construction, no instant global bootstrap."""
        self._tables.setdefault(name, {})
        self.sampler.add(name)

    def forget(self, name: str) -> None:
        """An engine left: GC its outbox, roster slot, receive table, and —
        the part peers would otherwise only fix at the staleness horizon —
        its entries in every other engine's table, then re-derive each
        peer's global load so no ghost pressure survives the departure."""
        self.sampler.remove(name)
        self._outbox.pop(name, None)
        self._tables.pop(name, None)
        for table in self._tables.values():
            table.pop(name, None)
        for peer in self._tables:
            eng = self.engines.get(peer)
            if eng is not None:
                eng.store.apply_global(self._aggregate(peer))

    # ------------------------------------------------------------------ table
    def publish(self) -> None:
        """Every live engine captures its current footprint into the outbox
        (shipped next round)."""
        now = self.fabric.now
        for name, e in self.engines.items():
            self._outbox[name] = (now, e.store.snapshot())

    def diffuse(self) -> None:
        """Ship the outbox: one channel message per (sender, view-peer) pair,
        then re-derive every engine's global load from whatever its table
        holds. With loss or delay on the channel some tables now miss this
        round — their engines keep scheduling on the freshest entries they
        do have, inside the staleness horizon."""
        for sender, (t, snap) in self._outbox.items():
            if sender not in self._tables:
                continue  # departed between publish and diffuse
            for peer in self.sampler.view(sender):
                self.channel.send(
                    lambda peer=peer, sender=sender, t=t, snap=snap:
                        self._receive(peer, sender, t, snap))
        now = self.fabric.now
        for name, e in self.engines.items():
            e.store.apply_global(self._aggregate(name, prune_before=now - self.staleness))

    def _receive(self, receiver: str, sender: str, t: float, snap: Dict[int, int]) -> None:
        """One snapshot message arrived (possibly late, possibly after the
        sender or receiver departed). Late entries still land in the table —
        the staleness horizon decides at read time whether they count."""
        table = self._tables.get(receiver)
        if table is None or sender not in self._tables:
            return  # receiver or sender no longer a member: drop on the floor
        prev = table.get(sender)
        if prev is not None and prev[0] > t:
            return  # a fresher snapshot already arrived (reordered delivery)
        table[sender] = (t, snap)

    def _aggregate(
        self, name: str, *, prune_before: Optional[float] = None
    ) -> Dict[int, int]:
        """Sum of *other* engines' in-horizon footprints from `name`'s own
        receive table; entries past the horizon are dropped (and pruned, so
        tables stay bounded under long runs)."""
        now = self.fabric.now
        table = self._tables.get(name, {})
        if prune_before is not None:
            for sender in [s for s, (t, _) in table.items() if t < prune_before]:
                del table[sender]
        agg: Dict[int, int] = {}
        for sender, (t, snap) in table.items():
            if sender == name or (now - t) > self.staleness:
                continue
            for lid, q in snap.items():
                agg[lid] = agg.get(lid, 0) + q
        return agg
