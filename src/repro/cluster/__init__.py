"""Cluster control plane: multi-engine TENT with global telemetry diffusion
and failure-rumor gossip on one shared fabric (see README.md here)."""
from .control_plane import ClusterParams, EngineRole, TentCluster
from .diffusion import GlobalLoadTable
from .membership import ClusterMembership

__all__ = [
    "ClusterParams", "EngineRole", "TentCluster",
    "GlobalLoadTable", "ClusterMembership",
]
