"""Cluster control plane: multi-engine TENT with global telemetry diffusion
and failure-rumor gossip over a modeled lossy/delayed channel, partial
membership views, and engine join/leave churn (see README.md here)."""
from .control_plane import ClusterParams, EngineRole, TentCluster
from .diffusion import GlobalLoadTable
from .gossip import GossipChannel, PeerSampler
from .membership import ClusterMembership

__all__ = [
    "ClusterParams", "EngineRole", "TentCluster",
    "GlobalLoadTable", "ClusterMembership", "GossipChannel", "PeerSampler",
]
