"""Modeled control-plane links: lossy/delayed gossip + partial peer views.

PR 2's cluster services delivered telemetry and rumors over an idealized
zero-loss broadcast — every message arrived, instantly, at every peer. Real
control planes run over the same imperfect network as the data plane: gossip
datagrams get dropped, delivery lags the send, and no engine holds an
instantaneous global membership view. The paper's sub-50 ms self-healing
claim (§4.2/§4.3) only counts if it survives that, so this module models it:

  * `GossipChannel` — every control-plane message (telemetry snapshot, rumor,
    anti-entropy digest) passes through one channel with a per-message loss
    probability and a delivery delay on the shared virtual clock. The RNG is
    seeded and private to the channel, so lossy runs are exactly reproducible
    and — critically — a zero-loss, zero-delay channel performs *no* RNG
    draws and schedules the *same* events as PR 2's direct delivery, keeping
    the existing multi-engine results bit-for-bit.
  * `PeerSampler` — fanout-k partial membership views: instead of addressing
    every peer, a sender gossips to a k-sized sample of the live roster
    (resampled per send, seeded). Gaps that loss or small fanout leave behind
    are closed by anti-entropy reconciliation (see membership.py), and the
    roster itself churns as engines join and leave mid-run.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np


class GossipChannel:
    """One lossy, delayed control-plane link shared by all cluster services.

    `send` either drops the message (probability `loss`), delivers it
    synchronously (total delay zero — the PR 2-compatible fast path), or
    schedules delivery `delay + extra_delay` ahead on the fabric's virtual
    clock. Messages are independent: two sends may be dropped, reordered
    only by their delays, or arrive after the state they carry went stale —
    exactly the hazards the staleness horizon and anti-entropy exist for.
    """

    def __init__(self, fabric, *, loss: float = 0.0, delay: float = 0.0, seed: int = 0):
        if not 0.0 <= loss < 1.0:
            raise ValueError(f"gossip loss must be in [0, 1), got {loss}")
        if delay < 0:
            raise ValueError(f"gossip delay must be >= 0, got {delay}")
        self.fabric = fabric
        self.loss = loss
        self.delay = delay
        # private seeded RNG: control-plane loss never perturbs data-plane
        # jitter streams, so a lossy run is as reproducible as a clean one
        self._rng = np.random.default_rng(seed)
        self.sent = 0
        self.dropped = 0
        self.delivered = 0

    def send(self, deliver: Callable[[], None], *, extra_delay: float = 0.0) -> bool:
        """Queue one message; returns False when the channel dropped it.
        Zero total delay delivers synchronously (no event, no RNG draw when
        loss is zero): the idealized PR 2 control plane is the special case
        loss=0/delay=0 of this one."""
        self.sent += 1
        if self.loss > 0.0 and self._rng.random() < self.loss:
            self.dropped += 1
            return False
        total = self.delay + extra_delay
        if total <= 0.0:
            self.delivered += 1
            deliver()
        else:
            def _arrive() -> None:
                self.delivered += 1
                deliver()

            self.fabric.call_after(total, _arrive)
        return True


class PeerSampler:
    """Fanout-k partial membership views over a churning roster.

    `fanout <= 0` (the default) means full views — every send addresses every
    live peer, PR 2's broadcast. A positive fanout samples that many peers
    per send from the sender's current roster (seeded RNG, insertion-ordered,
    so runs are deterministic); `anti_entropy_partner` rotates round-robin so
    reconciliation coverage is uniform without consuming randomness."""

    def __init__(self, *, fanout: int = 0, seed: int = 0):
        self.fanout = fanout
        self._rng = np.random.default_rng(seed)
        self._members: List[str] = []
        self._ae_cursor = 0

    # ------------------------------------------------------------------ roster
    def add(self, name: str) -> None:
        if name not in self._members:
            self._members.append(name)

    def remove(self, name: str) -> None:
        if name in self._members:
            self._members.remove(name)

    def members(self) -> Tuple[str, ...]:
        return tuple(self._members)

    # ------------------------------------------------------------------ views
    def peers_of(self, name: str) -> Tuple[str, ...]:
        """The full live roster minus the asker — what a zero-fanout view is."""
        return tuple(m for m in self._members if m != name)

    def view(self, name: str) -> Tuple[str, ...]:
        """The sender's current partial view: fanout-k peers sampled without
        replacement, or everyone when fanout is off / covers the roster. The
        full-view path performs no RNG draws (bit-for-bit with PR 2)."""
        others = self.peers_of(name)
        if self.fanout <= 0 or self.fanout >= len(others):
            return others
        idx = self._rng.choice(len(others), size=self.fanout, replace=False)
        return tuple(others[i] for i in sorted(idx))

    def anti_entropy_partner(self, name: str) -> Optional[str]:
        """Deterministic rotating partner for state reconciliation; None when
        the asker is the only live member."""
        others = self.peers_of(name)
        if not others:
            return None
        partner = others[self._ae_cursor % len(others)]
        self._ae_cursor += 1
        return partner
