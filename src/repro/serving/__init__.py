from .checkpoint_engine import CheckpointEngine, UpdateResult
from .disagg import DisaggregatedServer, monolithic_generate
from .hicache import FetchResult, HiCache
from .kvcache import PagePool, kv_bytes_per_token, make_cpu_pool, make_disk_pool, make_gpu_pool
from .perf_model import PerfModel, from_roofline, from_table2
from .serve_sim import Request, RequestTable, ServeSimConfig, ServeStats, ServingSimulator
from .sketch import P2Quantile, PercentileSketch

__all__ = [
    "CheckpointEngine", "UpdateResult", "DisaggregatedServer",
    "monolithic_generate", "FetchResult", "HiCache", "PagePool",
    "kv_bytes_per_token", "make_cpu_pool", "make_disk_pool", "make_gpu_pool",
    "PerfModel", "from_roofline", "from_table2", "ServeSimConfig",
    "ServeStats", "ServingSimulator", "Request", "RequestTable",
    "P2Quantile", "PercentileSketch",
]
