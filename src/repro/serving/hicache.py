"""HiCache-style multi-tier KV hierarchy over TENT (paper §5.1.1).

RadixAttention-flavored prefix reuse: cached KV pages are indexed by the
hash-chain of the token prefix they cover. `fetch_prefix` returns the longest
cached prefix and *promotes* its pages to the GPU tier — every promotion and
eviction is a declarative TENT batch transfer, so the transfer engine (not
this cache) decides rails, slicing, staging, and failover. Swapping the
engine's policy between "tent" and "round_robin"/"pinned" is exactly the
Table-2 ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ModelConfig
from ..core import TentEngine
from .kvcache import Page, PagePool, kv_bytes_per_token

TIERS = ("gpu", "cpu", "disk")


def _hash_chain(prev: int, chunk: Tuple[int, ...]) -> int:
    h = prev
    for t in chunk:
        h = (h * 1_000_003 + int(t) + 1) & 0xFFFFFFFFFFFF
    return h


@dataclasses.dataclass
class CacheEntry:
    key: int
    tier: str
    page: Page
    last_used: int
    token_count: int
    # reference count of in-flight operations holding this entry's page (an
    # async promotion whose bytes are still on the wire, a fetch chain being
    # assembled): pinned entries are never eviction victims, so an overlapping
    # demotion can never free or delete a page another request still needs.
    pins: int = 0


@dataclasses.dataclass
class FetchResult:
    prefix_tokens: int  # tokens served from cache
    pages: List[Page]
    promoted_pages: int
    transfer_seconds: float  # virtual fabric time spent promoting
    bytes_moved: int


class HiCache:
    """Three-tier KV cache (GPU / CPU / disk) with LRU demotion."""

    def __init__(
        self,
        engine: TentEngine,
        cfg: ModelConfig,
        *,
        gpu_pool: PagePool,
        cpu_pool: PagePool,
        disk_pool: Optional[PagePool] = None,
        page_tokens: int = 64,
    ):
        self.engine = engine
        self.cfg = cfg
        self.page_tokens = page_tokens
        self.page_bytes = kv_bytes_per_token(cfg) * page_tokens
        self.pools: Dict[str, Optional[PagePool]] = {
            "gpu": gpu_pool, "cpu": cpu_pool, "disk": disk_pool,
        }
        self.index: Dict[int, CacheEntry] = {}
        self._clock = 0
        # stats
        self.hits = self.misses = 0
        self.bytes_promoted = 0
        self.bytes_demoted = 0

    # ------------------------------------------------------------- helpers
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _prefix_keys(self, tokens: Sequence[int]) -> List[int]:
        keys = []
        h = 0
        n_pages = len(tokens) // self.page_tokens
        for i in range(n_pages):
            chunk = tuple(tokens[i * self.page_tokens : (i + 1) * self.page_tokens])
            h = _hash_chain(h, chunk)
            keys.append(h)
        return keys

    def _transfer_pages(self, moves: List[Tuple[Page, Page]]) -> float:
        """One declarative batch for all page moves; returns virtual seconds."""
        if not moves:
            return 0.0
        t0 = self.engine.fabric.now
        batch = self.engine.allocate_batch()
        self.engine.submit_transfer(
            batch,
            [
                (src.pool.segment.segment_id, src.offset,
                 dst.pool.segment.segment_id, dst.offset, src.nbytes)
                for src, dst in moves
            ],
        )
        res = self.engine.wait(batch)
        assert res.ok, res.error
        return self.engine.fabric.now - t0

    def _victim(self, tier: str, pinned: frozenset) -> CacheEntry:
        victims = [
            e for e in self.index.values()
            if e.tier == tier and e.key not in pinned and e.pins == 0
        ]
        if not victims:
            raise RuntimeError(f"{tier} pool too small for working set")
        return min(victims, key=lambda e: e.last_used)

    def _make_room(self, tier: str, pages_needed: int, pinned: frozenset = frozenset()) -> float:
        """LRU-demote entries out of `tier` until pages_needed fit. Entries in
        `pinned` (e.g. the prefix chain being fetched) or with a nonzero pin
        count are never victims."""
        pool = self.pools[tier]
        secs = 0.0
        assert pool is not None
        while pool.free_pages < pages_needed:
            secs += self._demote(self._victim(tier, pinned), pinned)
        return secs

    def _next_tier(self, tier: str) -> Optional[str]:
        i = TIERS.index(tier)
        for t in TIERS[i + 1 :]:
            if self.pools.get(t) is not None:
                return t
        return None

    def _demote(self, entry: CacheEntry, pinned: frozenset = frozenset()) -> float:
        # `pinned` must ride along: making room in the next tier for this
        # victim may itself evict — without the set, a nested eviction could
        # free or delete an entry of the very chain being fetched.
        dst_tier = self._next_tier(entry.tier)
        if dst_tier is None:
            self.pools[entry.tier].free(entry.page)
            del self.index[entry.key]
            return 0.0
        dst_pool = self.pools[dst_tier]
        secs = self._make_room(dst_tier, 1, pinned)
        dst_page = dst_pool.alloc()
        assert dst_page is not None
        secs += self._transfer_pages([(entry.page, dst_page)])
        self.bytes_demoted += entry.page.nbytes
        self.pools[entry.tier].free(entry.page)
        entry.page, entry.tier = dst_page, dst_tier
        return secs

    def _plan_room(
        self, tier: str, pages_needed: int, pinned: frozenset,
        moves: List[Tuple[Page, Page]],
    ) -> None:
        """Async-mode room making: select LRU victims (cascading down the
        hierarchy), rebind their pages *now* and append the wire moves to
        `moves` for one deferred declarative batch. All index/pool bookkeeping
        is synchronous at submit time; only the wire time is asynchronous, so
        overlapping requests always see a consistent cache."""
        pool = self.pools[tier]
        assert pool is not None
        while pool.free_pages < pages_needed:
            victim = self._victim(tier, pinned)
            dst_tier = self._next_tier(tier)
            if dst_tier is None:
                pool.free(victim.page)
                del self.index[victim.key]
                continue
            self._plan_room(dst_tier, 1, pinned, moves)
            dst = self.pools[dst_tier].alloc()
            assert dst is not None
            moves.append((victim.page, dst))
            self.bytes_demoted += victim.page.nbytes
            pool.free(victim.page)
            victim.page, victim.tier = dst, dst_tier

    # ------------------------------------------------------------- API
    def fetch_prefix(self, tokens: Sequence[int]) -> FetchResult:
        """Longest cached prefix, promoted to GPU. The promotion transfer is
        the latency-critical elephant flow of Table 2."""
        keys = self._prefix_keys(tokens)
        chain: List[CacheEntry] = []
        for k in keys:
            e = self.index.get(k)
            if e is None:
                break
            chain.append(e)
        if not chain:
            self.misses += 1
            return FetchResult(0, [], 0, 0.0, 0)
        self.hits += 1
        now = self._tick()
        for e in chain:
            e.last_used = now
        pinned = frozenset(e.key for e in chain)
        moves: List[Tuple[Page, Page]] = []
        new_pages: List[Tuple[CacheEntry, Page]] = []
        promoted = 0
        room_secs = 0.0
        need = sum(1 for e in chain if e.tier != "gpu")
        if need:
            room_secs += self._make_room("gpu", need, pinned)
        for e in chain:
            if e.tier != "gpu":
                dst = self.pools["gpu"].alloc()
                assert dst is not None
                moves.append((e.page, dst))
                new_pages.append((e, dst))
                promoted += 1
        secs = self._transfer_pages(moves) + room_secs
        for e, dst in new_pages:
            self.pools[e.tier].free(e.page)
            e.page, e.tier = dst, "gpu"
        nbytes = promoted * self.page_bytes
        self.bytes_promoted += nbytes
        return FetchResult(
            prefix_tokens=len(chain) * self.page_tokens,
            pages=[e.page for e in chain],
            promoted_pages=promoted,
            transfer_seconds=secs,
            bytes_moved=nbytes,
        )

    def fetch_prefix_async(
        self, tokens: Sequence[int], on_done: Callable[[FetchResult], None]
    ) -> None:
        """Non-blocking `fetch_prefix`: the promotion (plus any demotions it
        forces) is submitted as one declarative batch whose completion
        callback delivers the `FetchResult` — the caller's virtual clock only
        advances when the fabric does, so concurrent requests' promotions
        genuinely overlap and contend. Cache bookkeeping (index rebinds, page
        alloc/free) happens synchronously at submit; the chain stays pinned
        until the bytes land."""
        keys = self._prefix_keys(tokens)
        chain: List[CacheEntry] = []
        for k in keys:
            e = self.index.get(k)
            if e is None:
                break
            chain.append(e)
        if not chain:
            self.misses += 1
            on_done(FetchResult(0, [], 0, 0.0, 0))
            return
        self.hits += 1
        now = self._tick()
        for e in chain:
            e.last_used = now
        pinned = frozenset(e.key for e in chain)
        moves: List[Tuple[Page, Page]] = []
        need = [e for e in chain if e.tier != "gpu"]
        if need:
            self._plan_room("gpu", len(need), pinned, moves)
        for e in need:
            dst = self.pools["gpu"].alloc()
            assert dst is not None
            moves.append((e.page, dst))
            self.pools[e.tier].free(e.page)
            e.page, e.tier = dst, "gpu"
        nbytes = len(need) * self.page_bytes
        self.bytes_promoted += nbytes
        result = FetchResult(
            prefix_tokens=len(chain) * self.page_tokens,
            pages=[e.page for e in chain],
            promoted_pages=len(need),
            transfer_seconds=0.0,
            bytes_moved=nbytes,
        )
        if not moves:
            on_done(result)
            return
        for e in chain:
            e.pins += 1
        t0 = self.engine.fabric.now
        batch = self.engine.allocate_batch()
        self.engine.submit_transfer(
            batch,
            [
                (src.pool.segment.segment_id, src.offset,
                 dst.pool.segment.segment_id, dst.offset, src.nbytes)
                for src, dst in moves
            ],
        )

        def _landed(res):
            assert res.ok, res.error
            for e in chain:
                e.pins -= 1
            on_done(dataclasses.replace(
                result, transfer_seconds=self.engine.fabric.now - t0))

        self.engine.on_batch_done(batch, _landed)

    def insert_async(
        self, tokens: Sequence[int],
        on_done: Optional[Callable[[float], None]] = None,
    ) -> None:
        """Non-blocking `insert`: demotions forced by making room ship as one
        batch; new entries are indexed immediately (their KV was just computed
        on the GPU, no wire move needed). `on_done` receives the demotion
        transfer seconds once the evicted bytes land."""
        keys = self._prefix_keys(tokens)
        now = self._tick()
        moves: List[Tuple[Page, Page]] = []
        for k in keys:
            if k in self.index:
                self.index[k].last_used = now
                continue
            self._plan_room("gpu", 1, frozenset(), moves)
            page = self.pools["gpu"].alloc()
            assert page is not None
            self.index[k] = CacheEntry(
                key=k, tier="gpu", page=page, last_used=now,
                token_count=self.page_tokens,
            )
        if not moves:
            if on_done is not None:
                on_done(0.0)
            return
        t0 = self.engine.fabric.now
        batch = self.engine.allocate_batch()
        self.engine.submit_transfer(
            batch,
            [
                (src.pool.segment.segment_id, src.offset,
                 dst.pool.segment.segment_id, dst.offset, src.nbytes)
                for src, dst in moves
            ],
        )

        def _landed(res):
            assert res.ok, res.error
            if on_done is not None:
                on_done(self.engine.fabric.now - t0)

        self.engine.on_batch_done(batch, _landed)

    def insert(self, tokens: Sequence[int], payload: Optional[np.ndarray] = None) -> float:
        """Insert KV pages for `tokens` into the GPU tier (post-prefill).
        Returns virtual seconds spent making room (demotions)."""
        keys = self._prefix_keys(tokens)
        now = self._tick()
        secs = 0.0
        for i, k in enumerate(keys):
            if k in self.index:
                self.index[k].last_used = now
                continue
            secs += self._make_room("gpu", 1)
            page = self.pools["gpu"].alloc()
            assert page is not None
            if payload is not None:
                page.pool.write_page(
                    page,
                    payload[i * self.page_bytes : (i + 1) * self.page_bytes],
                )
            self.index[k] = CacheEntry(
                key=k, tier="gpu", page=page, last_used=now, token_count=self.page_tokens
            )
        return secs

    def tier_counts(self) -> Dict[str, int]:
        out = {t: 0 for t in TIERS}
        for e in self.index.values():
            out[e.tier] += 1
        return out
