"""Paged KV cache bookkeeping over TENT segments.

A *page* holds `page_tokens` tokens' worth of K/V for every layer of one
model. Pools exist per tier (GPU HBM / CPU DRAM / disk); each pool is one
registered TENT segment plus a free-list, so moving a page between tiers is
exactly one declarative transfer — the engine decides rails/slices/staging.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..core import Location, MemoryKind, TentEngine
from ..core.segments import Segment


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """2 (K and V) x layers x kv_heads x head_dim x 2 bytes (bf16)."""
    if cfg.attention_free:
        return 0
    return 2 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim * 2


@dataclasses.dataclass
class Page:
    page_id: int
    pool: "PagePool"
    offset: int  # byte offset within the pool segment

    @property
    def nbytes(self) -> int:
        return self.pool.page_bytes


class PagePool:
    """Fixed-size page allocator over one TENT segment."""

    def __init__(
        self,
        engine: TentEngine,
        location: Location,
        *,
        page_bytes: int,
        num_pages: int,
        name: str = "",
        materialize: bool = True,
    ):
        self.engine = engine
        self.page_bytes = page_bytes
        self.num_pages = num_pages
        self.segment: Segment = engine.register_segment(
            location, page_bytes * num_pages, name=name or f"kvpool@{location.node}",
            materialize=materialize,
        )
        self._free: List[int] = list(range(num_pages))
        self._allocated: Dict[int, int] = {}  # slot -> page_id of the live Page
        self._next_id = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[Page]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._next_id += 1
        self._allocated[slot] = self._next_id
        return Page(page_id=self._next_id, pool=self, offset=slot * self.page_bytes)

    def free(self, page: Page) -> None:
        """Return a page to the free list. Double-frees and foreign-pool pages
        raise instead of silently corrupting the free list (a corrupted list
        hands the same slot to two allocations)."""
        if page.pool is not self:
            raise ValueError(
                f"page {page.page_id} belongs to {page.pool.segment.name!r}, "
                f"not {self.segment.name!r}")
        slot = page.offset // self.page_bytes
        live = self._allocated.get(slot)
        if live != page.page_id:
            raise ValueError(
                f"double free of slot {slot} in {self.segment.name!r} "
                f"(page {page.page_id}, live page {live})")
        del self._allocated[slot]
        self._free.append(slot)

    # raw access used by tests / the real-compute example
    def read_page(self, page: Page) -> np.ndarray:
        return self.segment.read(page.offset, self.page_bytes)

    def write_page(self, page: Page, data: np.ndarray) -> None:
        assert data.size == self.page_bytes
        self.segment.write(page.offset, data)


def make_gpu_pool(engine: TentEngine, node: int, gpu: int, *, page_bytes: int, num_pages: int, materialize: bool = True) -> PagePool:
    spec = engine.topology.spec
    loc = Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu, numa=spec.node.gpu_numa(gpu))
    return PagePool(engine, loc, page_bytes=page_bytes, num_pages=num_pages, name=f"gpu{gpu}@n{node}", materialize=materialize)


def make_cpu_pool(engine: TentEngine, node: int, *, page_bytes: int, num_pages: int, numa: int = 0, materialize: bool = True) -> PagePool:
    loc = Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)
    return PagePool(engine, loc, page_bytes=page_bytes, num_pages=num_pages, name=f"cpu@n{node}", materialize=materialize)


def make_disk_pool(engine: TentEngine, node: int, *, page_bytes: int, num_pages: int, materialize: bool = True) -> PagePool:
    loc = Location(node=node, kind=MemoryKind.FILE, device=0, numa=0)
    return PagePool(engine, loc, page_bytes=page_bytes, num_pages=num_pages, name=f"disk@n{node}", materialize=materialize)
