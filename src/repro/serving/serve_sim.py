"""Multi-turn conversation serving simulator (paper §5.1.1 / Table 2).

Clients hold multi-turn conversations; every turn appends `input_tokens` new
prompt tokens to the history. Without HiCache the whole history re-prefills
each turn. With HiCache, the cached-prefix KV pages are *fetched* through
TENT (promotions from the global CPU/disk tiers are the latency-critical
elephant flows) and only the new suffix prefills. The transfer engine policy
("tent" vs "round_robin" vs others) is the only thing that changes between
the compared configurations — exactly the paper's ablation.

Two execution modes share one config and one stats schema:

* mode="sync" — the original analytical loop: per-slot bookkeeping on
  computed times, every promotion a blocking `engine.wait`. Kept as the
  parity reference and for the legacy Table-2 comparisons.
* mode="async" — the event-driven closed loop on the wave engine: each
  request is a small state machine (admit -> HiCache fetch -> chunked
  prefill -> optional prefill->decode KV handoff -> decode -> insert) whose
  transfers are asynchronous TENT batches with completion callbacks and
  whose compute runs on serial per-GPU resources, all on the fabric's
  virtual clock. Concurrent requests' elephant flows genuinely overlap and
  contend; chunked prefill interleaves with decode instead of blocking it;
  an optional `CheckpointEngine` refresh runs overlapped with live traffic.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import Location, MemoryKind, TentEngine
from ..obs import events as OBS
from .checkpoint_engine import CheckpointEngine
from .hicache import HiCache
from .perf_model import PerfModel

_EVENT_BUDGET = 60_000_000


@dataclasses.dataclass
class ServeSimConfig:
    clients: int = 12
    concurrency: int = 4
    turns: int = 10
    input_tokens: int = 2048
    output_tokens: int = 128
    seed: int = 0
    # --- closed-loop (mode="async") knobs ---
    mode: str = "sync"  # "sync" | "async"
    chunk_tokens: int = 0  # prefill chunk size; 0 = one monolithic chunk
    decode_chunk: int = 32  # decode tokens per compute item
    # prefill->decode KV handoff: > 0 ships history_tokens * this many bytes
    # from gpu_node to decode_node through TENT after every prefill
    handoff_bytes_per_token: int = 0
    gpu_node: int = 0
    decode_node: int = 1
    # overlapped weight refresh: this many CheckpointEngine.update_async
    # submissions spread evenly over the run (needs `checkpoint=` at init)
    checkpoint_updates: int = 0

    def __post_init__(self) -> None:
        if self.mode not in ("sync", "async"):
            raise ValueError(f"unknown serving mode {self.mode!r}")


@dataclasses.dataclass
class ServeStats:
    input_throughput: float  # input tokens / s
    avg_ttft: float
    p50_ttft: float
    p90_ttft: float
    p99_ttft: float
    round_avg_ttft: Dict[int, float]
    total_input_tokens: int
    makespan: float
    bytes_promoted: int
    # closed-loop extras (zeroed by the sync mode where not applicable)
    avg_tpot: float = 0.0
    p99_tpot: float = 0.0
    # sum of every request's un-overlapped service time (fetch + prefill +
    # handoff + decode): makespan strictly below this proves transfer/compute
    # overlap across concurrent requests
    serialized_seconds: float = 0.0
    bytes_handoff: int = 0
    checkpoint_updates: int = 0
    checkpoint_seconds: float = 0.0  # summed virtual update durations
    # (finish_time, bytes_moved, ttft) per request, admission order
    request_log: List[Tuple[float, int, float]] = dataclasses.field(
        default_factory=list)


class _SerialResource:
    """One GPU's compute engine as a FIFO resource on the virtual clock:
    items run back to back in submission order, so a monolithic prefill
    monopolizes the GPU while chunked prefill lets other requests' decode
    items slot in between chunks — the continuous-batching contention the
    closed loop exists to expose."""

    def __init__(self, fabric):
        self.fabric = fabric
        self.busy_until = 0.0
        self.busy_seconds = 0.0

    def submit(self, duration: float, cb) -> None:
        start = max(self.fabric.now, self.busy_until)
        self.busy_until = start + duration
        self.busy_seconds += duration
        self.fabric.call_at(self.busy_until, cb)


@dataclasses.dataclass
class _Request:
    client: int
    turn: int
    t_admit: float = 0.0
    fetch_secs: float = 0.0
    cached: int = 0
    bytes_moved: int = 0
    ttft: float = 0.0
    decode_start: float = 0.0
    service_secs: float = 0.0
    t_mark: float = 0.0  # start of the current phase (flight-recorder spans)


class ServingSimulator:
    def __init__(
        self,
        engine: TentEngine,
        perf: PerfModel,
        *,
        hicache: Optional[HiCache],
        sim_cfg: ServeSimConfig,
        checkpoint: Optional[CheckpointEngine] = None,
    ):
        self.engine = engine
        self.perf = perf
        self.hicache = hicache
        self.cfg = sim_cfg
        self.checkpoint = checkpoint

    def run(self) -> ServeStats:
        if self.cfg.clients <= 0 or self.cfg.turns <= 0:
            return self._stats([], {}, 0, 0.0, [], 0.0)
        if self.cfg.mode == "async":
            return self._run_async()
        return self._run_sync()

    # ------------------------------------------------------------- shared
    def _conversations(self) -> Dict[int, List[int]]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        return {
            c: rng.integers(1, 50_000, size=cfg.turns * cfg.input_tokens).tolist()
            for c in range(cfg.clients)
        }

    def _stats(
        self,
        ttfts: List[float],
        per_round: Dict[int, List[float]],
        total_input: int,
        makespan: float,
        tpots: List[float],
        serialized: float,
        *,
        bytes_handoff: int = 0,
        ckpt_updates: int = 0,
        ckpt_seconds: float = 0.0,
        request_log: Optional[List[Tuple[float, int, float]]] = None,
    ) -> ServeStats:
        arr = np.asarray(ttfts, dtype=float)
        tp = np.asarray(tpots, dtype=float)
        return ServeStats(
            # guard: a zero-request run (clients=0) has zero makespan — the
            # throughput is 0, not a ZeroDivisionError
            input_throughput=total_input / makespan if makespan > 0 else 0.0,
            avg_ttft=float(arr.mean()) if arr.size else 0.0,
            p50_ttft=float(np.percentile(arr, 50)) if arr.size else 0.0,
            p90_ttft=float(np.percentile(arr, 90)) if arr.size else 0.0,
            p99_ttft=float(np.percentile(arr, 99)) if arr.size else 0.0,
            round_avg_ttft={r: float(np.mean(v)) for r, v in per_round.items() if v},
            total_input_tokens=total_input,
            makespan=makespan,
            bytes_promoted=self.hicache.bytes_promoted if self.hicache else 0,
            avg_tpot=float(tp.mean()) if tp.size else 0.0,
            p99_tpot=float(np.percentile(tp, 99)) if tp.size else 0.0,
            serialized_seconds=serialized,
            bytes_handoff=bytes_handoff,
            checkpoint_updates=ckpt_updates,
            checkpoint_seconds=ckpt_seconds,
            request_log=request_log or [],
        )

    # ------------------------------------------------------------- sync
    def _run_sync(self) -> ServeStats:
        cfg = self.cfg
        fabric = self.engine.fabric
        convo = self._conversations()
        ttfts: List[float] = []
        per_round: Dict[int, List[float]] = {r: [] for r in range(1, cfg.turns + 1)}
        request_log: List[Tuple[float, int, float]] = []
        slots = [0.0] * cfg.concurrency
        work = [(0.0, c, c, 1) for c in range(cfg.clients)]
        heapq.heapify(work)
        total_input = 0
        makespan = 0.0
        serialized = 0.0
        order = cfg.clients
        while work:
            ready, _, client, turn = heapq.heappop(work)
            si = int(np.argmin(slots))
            start = max(ready, slots[si])
            # the previous turn's fetch may have advanced the fabric past
            # `start`; the virtual clock is monotonic, so clamp the target
            fabric.run_until(max(start, fabric.now))
            history_tokens = convo[client][: turn * cfg.input_tokens]
            total_input += cfg.input_tokens
            if self.hicache is None:
                fetch_secs, cached, moved = 0.0, 0, 0
            else:
                res = self.hicache.fetch_prefix(history_tokens)
                fetch_secs, cached, moved = (
                    res.transfer_seconds, res.prefix_tokens, res.bytes_moved)
            new_tokens = len(history_tokens) - cached
            prefill_secs = self.perf.prefill_seconds(new_tokens)
            # server-side TTFT: from turn admission to first token (queue
            # wait excluded, matching the paper's serving-side measurement)
            ttft = fetch_secs + prefill_secs
            decode_secs = self.perf.decode_seconds(cfg.output_tokens)
            finish = start + fetch_secs + prefill_secs + decode_secs
            if self.hicache is not None:
                self.hicache.insert(history_tokens)
            ttfts.append(ttft)
            per_round[turn].append(ttft)
            request_log.append((finish, moved, ttft))
            serialized += fetch_secs + prefill_secs + decode_secs
            slots[si] = finish
            makespan = max(makespan, finish)
            if turn < cfg.turns:
                order += 1
                heapq.heappush(work, (finish, order, client, turn + 1))
        return self._stats(
            ttfts, per_round, total_input, makespan,
            [self.perf.tpot] * len(ttfts), serialized, request_log=request_log)

    # ------------------------------------------------------------- async
    def _run_async(self) -> ServeStats:
        cfg = self.cfg
        fabric = self.engine.fabric
        convo = self._conversations()
        t0 = fabric.now
        # flight recorder (repro.obs): request phase spans ride the engine's
        # recorder; every site below is one `is not None` guard per phase
        rec = self.engine._rec
        ename = self.engine.name

        def mark_phase(req: _Request, phase: str, span_t0: float,
                       **extra) -> None:
            payload = {"engine": ename, "client": req.client,
                       "turn": req.turn, "phase": phase, "t0": span_t0}
            payload.update(extra)
            rec.append(OBS.PHASE, fabric.now, payload)
        prefill_gpu = _SerialResource(fabric)
        decode_gpu = (
            _SerialResource(fabric) if cfg.handoff_bytes_per_token > 0
            else prefill_gpu)
        handoff_segs = None
        if cfg.handoff_bytes_per_token > 0:
            spec = self.engine.topology.spec
            max_kv = cfg.turns * cfg.input_tokens * cfg.handoff_bytes_per_token
            src = self.engine.register_segment(
                Location(node=cfg.gpu_node, kind=MemoryKind.DEVICE_HBM,
                         device=0, numa=spec.node.gpu_numa(0)),
                max_kv, name="pd-kv-src", materialize=False)
            dst = self.engine.register_segment(
                Location(node=cfg.decode_node, kind=MemoryKind.DEVICE_HBM,
                         device=0, numa=spec.node.gpu_numa(0)),
                max_kv, name="pd-kv-dst", materialize=False)
            handoff_segs = (src.segment_id, dst.segment_id)

        ttfts: List[float] = []
        tpots: List[float] = []
        per_round: Dict[int, List[float]] = {r: [] for r in range(1, cfg.turns + 1)}
        request_log: List[Tuple[float, int, float]] = []
        state = {
            "outstanding": cfg.clients * cfg.turns,
            "pending_ops": 0,  # fire-and-forget inserts / checkpoint pulls
            "slots_free": cfg.concurrency,
            "total_input": 0,
            "serialized": 0.0,
            "last_finish": t0,
            "bytes_handoff": 0,
            "finished": 0,
            "ckpt_fired": 0,
            "ckpt_done": 0,
            "ckpt_seconds": 0.0,
        }
        queue: List[Tuple[float, int, int, int]] = []
        order = [cfg.clients]
        total_requests = cfg.clients * cfg.turns

        def enqueue(ready: float, client: int, turn: int) -> None:
            order[0] += 1
            heapq.heappush(queue, (ready, order[0], client, turn))
            fabric.call_at(ready, try_admit)

        def try_admit() -> None:
            while (state["slots_free"] > 0 and queue
                   and queue[0][0] <= fabric.now):
                _, _, client, turn = heapq.heappop(queue)
                state["slots_free"] -= 1
                start_request(_Request(client=client, turn=turn))

        # -- stage 1: HiCache prefix fetch (async TENT batch) --------------
        def start_request(req: _Request) -> None:
            req.t_admit = fabric.now
            state["total_input"] += cfg.input_tokens
            history = convo[req.client][: req.turn * cfg.input_tokens]
            if self.hicache is None:
                fetched(req, history, 0, 0.0, 0)
            else:
                self.hicache.fetch_prefix_async(
                    history,
                    lambda res, req=req, history=history: fetched(
                        req, history, res.prefix_tokens, res.transfer_seconds,
                        res.bytes_moved))

        # -- stage 2: chunked prefill on the (shared) compute resource ------
        def fetched(req: _Request, history, cached, fetch_secs, moved) -> None:
            if rec is not None:
                mark_phase(req, "fetch", req.t_admit, bytes=moved)
            req.t_mark = fabric.now
            req.cached, req.fetch_secs, req.bytes_moved = cached, fetch_secs, moved
            req.service_secs = fetch_secs
            new_tokens = len(history) - cached
            chunk = cfg.chunk_tokens if cfg.chunk_tokens > 0 else max(new_tokens, 1)
            chunks = [chunk] * (new_tokens // chunk)
            if new_tokens % chunk:
                chunks.append(new_tokens % chunk)
            run_prefill(req, history, chunks)

        def run_prefill(req: _Request, history, chunks: List[int]) -> None:
            if not chunks:
                prefilled(req, history)
                return
            secs = self.perf.prefill_seconds(chunks[0])
            req.service_secs += secs
            prefill_gpu.submit(
                secs, lambda req=req, history=history, rest=chunks[1:]:
                run_prefill(req, history, rest))

        # -- stage 3: prefill->decode KV handoff (async TENT batch) ---------
        def prefilled(req: _Request, history) -> None:
            if rec is not None:
                mark_phase(req, "prefill", req.t_mark)
            if handoff_segs is None:
                req.ttft = fabric.now - req.t_admit
                start_decode(req, history)
                return
            nbytes = max(len(history) * cfg.handoff_bytes_per_token, 1)
            state["bytes_handoff"] += nbytes
            t_ship = fabric.now
            b = self.engine.allocate_batch()
            self.engine.submit_transfer(
                b, [(handoff_segs[0], 0, handoff_segs[1], 0, nbytes)])

            def shipped(res, req=req, history=history, t_ship=t_ship,
                        nbytes=nbytes):
                assert res.ok, res.error
                if rec is not None:
                    mark_phase(req, "handoff", t_ship, bytes=nbytes)
                req.service_secs += fabric.now - t_ship
                # PD mode: the first token comes from the decode worker, so
                # TTFT includes the KV handoff
                req.ttft = fabric.now - req.t_admit
                start_decode(req, history)

            self.engine.on_batch_done(b, shipped)

        # -- stage 4: decode in chunks on the decode resource ---------------
        def start_decode(req: _Request, history) -> None:
            req.decode_start = fabric.now
            req.service_secs += self.perf.decode_seconds(cfg.output_tokens)
            run_decode(req, history, cfg.output_tokens)

        def run_decode(req: _Request, history, tokens_left: int) -> None:
            if tokens_left <= 0:
                finish(req, history)
                return
            n = min(cfg.decode_chunk, tokens_left)
            decode_gpu.submit(
                self.perf.decode_seconds(n),
                lambda req=req, history=history, left=tokens_left - n:
                run_decode(req, history, left))

        # -- stage 5: finish, insert, release the slot ----------------------
        def finish(req: _Request, history) -> None:
            now = fabric.now
            req.ttft = req.ttft or (now - req.t_admit)
            if rec is not None:
                mark_phase(req, "decode", req.decode_start)
                mark_phase(req, "request", req.t_admit, ttft=req.ttft)
            tpot = (now - req.decode_start) / max(cfg.output_tokens, 1)
            ttfts.append(req.ttft)
            tpots.append(tpot)
            per_round[req.turn].append(req.ttft)
            request_log.append((now, req.bytes_moved, req.ttft))
            state["serialized"] += req.service_secs
            state["last_finish"] = max(state["last_finish"], now)
            state["outstanding"] -= 1
            state["finished"] += 1
            state["slots_free"] += 1
            if self.hicache is not None:
                state["pending_ops"] += 1

                def inserted(_secs):
                    state["pending_ops"] -= 1

                self.hicache.insert_async(history, inserted)
            maybe_refresh_weights()
            if req.turn < cfg.turns:
                enqueue(now, req.client, req.turn + 1)
            try_admit()

        # -- overlapped weight refresh --------------------------------------
        def maybe_refresh_weights() -> None:
            if self.checkpoint is None or cfg.checkpoint_updates <= 0:
                return
            due = (state["finished"] * (cfg.checkpoint_updates + 1)
                   ) // max(total_requests, 1)
            while state["ckpt_fired"] < min(due, cfg.checkpoint_updates):
                state["ckpt_fired"] += 1
                state["pending_ops"] += 1

                def refreshed(res):
                    state["ckpt_done"] += 1
                    state["ckpt_seconds"] += res.seconds
                    state["pending_ops"] -= 1

                self.checkpoint.update_async(refreshed)

        for c in range(cfg.clients):
            enqueue(t0, c, 1)
        try_admit()
        guard = 0
        while state["outstanding"] > 0 or state["pending_ops"] > 0:
            if not fabric.step():
                raise RuntimeError(
                    f"serving closed loop stalled: {state['outstanding']} "
                    f"requests and {state['pending_ops']} ops outstanding "
                    "with an idle fabric")
            guard += 1
            if guard > _EVENT_BUDGET:
                raise RuntimeError("serving closed loop exceeded event budget")
        return self._stats(
            ttfts, per_round, state["total_input"],
            state["last_finish"] - t0, tpots, state["serialized"],
            bytes_handoff=state["bytes_handoff"],
            ckpt_updates=state["ckpt_done"],
            ckpt_seconds=state["ckpt_seconds"],
            request_log=request_log,
        )
