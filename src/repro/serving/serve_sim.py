"""Multi-turn conversation serving simulator (paper §5.1.1 / Table 2).

Clients hold multi-turn conversations; every turn appends `input_tokens` new
prompt tokens to the history. Without HiCache the whole history re-prefills
each turn. With HiCache, the cached-prefix KV pages are *fetched* through
TENT (promotions from the global CPU/disk tiers are the latency-critical
elephant flows) and only the new suffix prefills. The transfer engine policy
("tent" vs "round_robin" vs others) is the only thing that changes between
the compared configurations — exactly the paper's ablation.

Three execution modes share one config and one stats schema:

* mode="sync" — the original analytical loop: per-slot bookkeeping on
  computed times, every promotion a blocking `engine.wait`. Kept as the
  parity reference and for the legacy Table-2 comparisons.
* mode="async" — the event-driven closed loop on the wave engine: each
  request is a small state machine (admit -> HiCache fetch -> chunked
  prefill -> optional prefill->decode KV handoff -> decode -> insert) whose
  transfers are asynchronous TENT batches with completion callbacks and
  whose compute runs on serial per-GPU resources, all on the fabric's
  virtual clock. Concurrent requests' elephant flows genuinely overlap and
  contend; chunked prefill interleaves with decode instead of blocking it;
  an optional `CheckpointEngine` refresh runs overlapped with live traffic.
* mode="batched" — the production-stream loop: 10^5-10^6 single-turn
  requests from a seeded Poisson/Zipf arrival stream
  (`repro.scenarios.traffic`), advanced phase-at-a-time per virtual-clock
  tick over the struct-of-arrays `RequestTable` (mirroring what PRs 4-5
  did for slices) instead of one closure per request event. Each tick's
  admitted cohort promotes its cold prefix KV through ONE TENT batch
  (store -> GPU: the transfer-bound contention the spray policy decides),
  prefill and decode advance whole phases under vectorized token budgets,
  and latency percentiles stream through P^2 sketches so no per-request
  log is required.

Request state lives in `RequestTable` for async + batched modes (`Request`
is a thin per-row view, same pattern as `TelemetryStore`/`LinkTelemetry`);
the async event loop's outputs are unchanged by the storage swap.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import Location, MemoryKind, TentEngine
from ..obs import events as OBS
from .checkpoint_engine import CheckpointEngine
from .hicache import HiCache
from .perf_model import PerfModel
from ..analysis import hot_path
from .sketch import PercentileSketch

_EVENT_BUDGET = 60_000_000

# `log_requests=None` resolves to: keep the per-request log below this many
# total requests, drop it at or above (the log is O(N) memory and exists for
# completion-timeline plots; percentiles no longer need it).
LOG_AUTO_LIMIT = 10_000

# request lifecycle phases (RequestTable.phase values)
PH_PENDING = 0  # arrived / queued, no slot yet
PH_FETCH = 1  # waiting on the cohort's KV promotion transfer
PH_PREFILL = 2  # consuming the prefill token budget
PH_DECODE = 3  # consuming the decode token budget
PH_DONE = 4


@dataclasses.dataclass
class ServeSimConfig:
    clients: int = 12
    concurrency: int = 4
    turns: int = 10
    input_tokens: int = 2048
    output_tokens: int = 128
    seed: int = 0
    # --- closed-loop (mode="async") knobs ---
    mode: str = "sync"  # "sync" | "async"
    chunk_tokens: int = 0  # prefill chunk size; 0 = one monolithic chunk
    decode_chunk: int = 32  # decode tokens per compute item
    # prefill->decode KV handoff: > 0 ships history_tokens * this many bytes
    # from gpu_node to decode_node through TENT after every prefill
    handoff_bytes_per_token: int = 0
    gpu_node: int = 0
    decode_node: int = 1
    # overlapped weight refresh: this many CheckpointEngine.update_async
    # submissions spread evenly over the run (needs `checkpoint=` at init)
    checkpoint_updates: int = 0
    # keep the per-request (finish, bytes, ttft) log? None = auto: on below
    # LOG_AUTO_LIMIT total requests, off above (percentiles work either way)
    log_requests: Optional[bool] = None
    # --- production-stream (mode="batched") knobs ---
    # total single-turn requests in the stream; batched mode ignores
    # clients/turns and draws arrivals/groups from repro.scenarios.traffic
    stream_requests: int = 0
    arrival_rate: float = 0.0  # mean arrivals/s (Poisson)
    zipf_alpha: float = 1.1  # popularity skew over prefix groups
    traffic_groups: int = 64  # distinct prefix groups
    prefix_frac: float = 0.5  # cached-prefix share of each prompt
    # KV bytes promoted per cold prefix token (store -> GPU elephant flows);
    # decoupled from the model's true KV width so scenarios can pin the
    # wire-contention level independently of the perf model
    stream_kv_bytes_per_token: int = 1024
    resident_s: float = 1.0  # GPU residency window per prefix group
    tick_s: float = 0.005  # virtual-clock tick of the batched stepper
    store_node: int = 1  # promotion source (KV store tier)

    def __post_init__(self) -> None:
        if self.mode not in ("sync", "async", "batched"):
            raise ValueError(f"unknown serving mode {self.mode!r}")
        if self.mode == "batched" and self.stream_requests <= 0:
            raise ValueError("mode='batched' needs stream_requests > 0")

    def total_requests(self) -> int:
        if self.mode == "batched":
            return self.stream_requests
        return self.clients * self.turns

    def keep_log(self) -> bool:
        if self.log_requests is not None:
            return self.log_requests
        return self.total_requests() < LOG_AUTO_LIMIT


@dataclasses.dataclass
class ServeStats:
    input_throughput: float  # input tokens / s
    avg_ttft: float
    p50_ttft: float
    p90_ttft: float
    p99_ttft: float
    round_avg_ttft: Dict[int, float]
    total_input_tokens: int
    makespan: float
    bytes_promoted: int
    # closed-loop extras (zeroed by the sync mode where not applicable)
    avg_tpot: float = 0.0
    p99_tpot: float = 0.0
    # sum of every request's un-overlapped service time (fetch + prefill +
    # handoff + decode): makespan strictly below this proves transfer/compute
    # overlap across concurrent requests
    serialized_seconds: float = 0.0
    bytes_handoff: int = 0
    checkpoint_updates: int = 0
    checkpoint_seconds: float = 0.0  # summed virtual update durations
    requests: int = 0  # completed requests (survives a dropped log)
    # (finish_time, bytes_moved, ttft) per request, admission order; empty
    # when ServeSimConfig.log_requests resolves off (percentiles above come
    # from the streaming sketches instead)
    request_log: List[Tuple[float, int, float]] = dataclasses.field(
        default_factory=list)


class _SerialResource:
    """One GPU's compute engine as a FIFO resource on the virtual clock:
    items run back to back in submission order, so a monolithic prefill
    monopolizes the GPU while chunked prefill lets other requests' decode
    items slot in between chunks — the continuous-batching contention the
    closed loop exists to expose."""

    def __init__(self, fabric):
        self.fabric = fabric
        self.busy_until = 0.0
        self.busy_seconds = 0.0

    def submit(self, duration: float, cb) -> None:
        start = max(self.fabric.now, self.busy_until)
        self.busy_until = start + duration
        self.busy_seconds += duration
        self.fabric.call_at(self.busy_until, cb)


# RequestTable columns: float64 timelines/budgets and int64 identities.
# `phase` is separate (int8) — it's the column the batched stepper selects on
# every tick, so it stays as compact as possible.
_REQ_F8 = ("arrival", "t_admit", "fetch_secs", "ttft", "decode_start",
           "finish", "service_secs", "t_mark", "prefill_left", "decode_left")
_REQ_I8 = ("client", "turn", "tenant", "input_tokens", "output_tokens",
           "prefix_bytes", "cached", "bytes_moved")


class RequestTable:
    """Struct-of-arrays request state: one contiguous numpy column per
    field, one row per request — the serving twin of `TelemetryStore`.
    The async closed loop reads/writes rows through `Request` views (thin,
    allocation-light); the batched production-stream stepper operates on
    whole columns per tick and never materializes a view."""

    __slots__ = ("capacity", "size", "phase") + _REQ_F8 + _REQ_I8

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.size = 0
        self.phase = np.zeros(capacity, dtype=np.int8)
        for f in _REQ_F8:
            setattr(self, f, np.zeros(capacity, dtype=np.float64))
        for f in _REQ_I8:
            setattr(self, f, np.zeros(capacity, dtype=np.int64))

    def create(self, client: int, turn: int) -> "Request":
        slot = self.size
        if slot >= self.capacity:
            raise IndexError("RequestTable capacity exhausted")
        self.size = slot + 1
        self.client[slot] = client
        self.turn[slot] = turn
        return Request(self, slot)


def _req_field(name: str, cast):
    def _get(self):
        return cast(getattr(self.table, name)[self.slot])

    def _set(self, value):
        getattr(self.table, name)[self.slot] = value

    return property(_get, _set)


class Request:
    """Thin per-row view over a `RequestTable` (the `LinkTelemetry`
    pattern): attribute access reads/writes the backing column, so view
    lifetime carries no state of its own."""

    __slots__ = ("table", "slot")

    def __init__(self, table: RequestTable, slot: int):
        self.table = table
        self.slot = slot


for _name in _REQ_F8:
    setattr(Request, _name, _req_field(_name, float))
for _name in _REQ_I8:
    setattr(Request, _name, _req_field(_name, int))
del _name


class _MetricsAccum:
    """Streaming request metrics: P^2 sketches for TTFT/TPOT percentiles
    (O(1) memory at any request count) plus the optional exact lists and
    per-request log. With `keep_log` on, percentile math uses the exact
    arrays — bit-identical to the pre-sketch behavior for every small
    scenario; with it off, the sketches answer alone."""

    __slots__ = ("keep_log", "ttft_sketch", "tpot_sketch", "ttfts", "tpots",
                 "request_log", "round_sum", "round_cnt", "serialized")

    def __init__(self, keep_log: bool):
        self.keep_log = keep_log
        self.ttft_sketch = PercentileSketch()
        self.tpot_sketch = PercentileSketch()
        self.ttfts: Optional[List[float]] = [] if keep_log else None
        self.tpots: Optional[List[float]] = [] if keep_log else None
        self.request_log: Optional[List[Tuple[float, int, float]]] = (
            [] if keep_log else None)
        self.round_sum: Dict[int, float] = {}
        self.round_cnt: Dict[int, int] = {}
        self.serialized = 0.0

    def observe(self, finish: float, bytes_moved: int, ttft: float,
                tpot: float, turn: int, service_secs: float) -> None:
        self.ttft_sketch.add(ttft)
        self.tpot_sketch.add(tpot)
        self.round_sum[turn] = self.round_sum.get(turn, 0.0) + ttft
        self.round_cnt[turn] = self.round_cnt.get(turn, 0) + 1
        self.serialized += service_secs
        if self.keep_log:
            self.ttfts.append(ttft)
            self.tpots.append(tpot)
            self.request_log.append((finish, bytes_moved, ttft))

    def stats(self, *, total_input: int, makespan: float,
              bytes_promoted: int, bytes_handoff: int = 0,
              ckpt_updates: int = 0, ckpt_seconds: float = 0.0) -> ServeStats:
        if self.keep_log and self.ttfts:
            arr = np.asarray(self.ttfts, dtype=float)
            tp = np.asarray(self.tpots, dtype=float)
            pct = {q: float(np.percentile(arr, q)) for q in (50, 90, 99)}
            avg_ttft = float(arr.mean())
            avg_tpot, p99_tpot = float(tp.mean()), float(np.percentile(tp, 99))
        else:
            ts, ps = self.ttft_sketch, self.tpot_sketch
            pct = {q: ts.percentile(q) for q in (50, 90, 99)}
            avg_ttft = ts.mean
            avg_tpot, p99_tpot = ps.mean, ps.percentile(99)
        return ServeStats(
            input_throughput=total_input / makespan if makespan > 0 else 0.0,
            avg_ttft=avg_ttft,
            p50_ttft=pct[50], p90_ttft=pct[90], p99_ttft=pct[99],
            round_avg_ttft={
                r: self.round_sum[r] / self.round_cnt[r]
                for r in self.round_sum if self.round_cnt[r]},
            total_input_tokens=total_input,
            makespan=makespan,
            bytes_promoted=bytes_promoted,
            avg_tpot=avg_tpot, p99_tpot=p99_tpot,
            serialized_seconds=self.serialized,
            bytes_handoff=bytes_handoff,
            checkpoint_updates=ckpt_updates,
            checkpoint_seconds=ckpt_seconds,
            requests=self.ttft_sketch.count,
            request_log=self.request_log or [],
        )


class ServingSimulator:
    def __init__(
        self,
        engine: TentEngine,
        perf: PerfModel,
        *,
        hicache: Optional[HiCache],
        sim_cfg: ServeSimConfig,
        checkpoint: Optional[CheckpointEngine] = None,
    ):
        self.engine = engine
        self.perf = perf
        self.hicache = hicache
        self.cfg = sim_cfg
        self.checkpoint = checkpoint

    def run(self) -> ServeStats:
        if self.cfg.mode == "batched":
            return self._run_batched()
        if self.cfg.clients <= 0 or self.cfg.turns <= 0:
            return self._stats([], {}, 0, 0.0, [], 0.0)
        if self.cfg.mode == "async":
            return self._run_async()
        return self._run_sync()

    # ------------------------------------------------------------- shared
    def _conversations(self) -> Dict[int, List[int]]:
        # one source of truth for workload shape: repro.scenarios.traffic
        # (lazy import; scenarios packages import serving at executor level)
        from ..scenarios.traffic import conversation_tokens

        cfg = self.cfg
        return conversation_tokens(
            cfg.clients, cfg.turns, cfg.input_tokens, cfg.seed)

    def _stats(
        self,
        ttfts: List[float],
        per_round: Dict[int, List[float]],
        total_input: int,
        makespan: float,
        tpots: List[float],
        serialized: float,
        *,
        bytes_handoff: int = 0,
        ckpt_updates: int = 0,
        ckpt_seconds: float = 0.0,
        request_log: Optional[List[Tuple[float, int, float]]] = None,
    ) -> ServeStats:
        arr = np.asarray(ttfts, dtype=float)
        tp = np.asarray(tpots, dtype=float)
        return ServeStats(
            # guard: a zero-request run (clients=0) has zero makespan — the
            # throughput is 0, not a ZeroDivisionError
            input_throughput=total_input / makespan if makespan > 0 else 0.0,
            avg_ttft=float(arr.mean()) if arr.size else 0.0,
            p50_ttft=float(np.percentile(arr, 50)) if arr.size else 0.0,
            p90_ttft=float(np.percentile(arr, 90)) if arr.size else 0.0,
            p99_ttft=float(np.percentile(arr, 99)) if arr.size else 0.0,
            round_avg_ttft={r: float(np.mean(v)) for r, v in per_round.items() if v},
            total_input_tokens=total_input,
            makespan=makespan,
            bytes_promoted=self.hicache.bytes_promoted if self.hicache else 0,
            avg_tpot=float(tp.mean()) if tp.size else 0.0,
            p99_tpot=float(np.percentile(tp, 99)) if tp.size else 0.0,
            serialized_seconds=serialized,
            bytes_handoff=bytes_handoff,
            checkpoint_updates=ckpt_updates,
            checkpoint_seconds=ckpt_seconds,
            requests=len(ttfts),
            request_log=(request_log or []) if self.cfg.keep_log() else [],
        )

    # ------------------------------------------------------------- sync
    def _run_sync(self) -> ServeStats:
        cfg = self.cfg
        fabric = self.engine.fabric
        convo = self._conversations()
        ttfts: List[float] = []
        per_round: Dict[int, List[float]] = {r: [] for r in range(1, cfg.turns + 1)}
        request_log: List[Tuple[float, int, float]] = []
        slots = [0.0] * cfg.concurrency
        work = [(0.0, c, c, 1) for c in range(cfg.clients)]
        heapq.heapify(work)
        total_input = 0
        makespan = 0.0
        serialized = 0.0
        order = cfg.clients
        while work:
            ready, _, client, turn = heapq.heappop(work)
            si = int(np.argmin(slots))
            start = max(ready, slots[si])
            # the previous turn's fetch may have advanced the fabric past
            # `start`; the virtual clock is monotonic, so clamp the target
            fabric.run_until(max(start, fabric.now))
            history_tokens = convo[client][: turn * cfg.input_tokens]
            total_input += cfg.input_tokens
            if self.hicache is None:
                fetch_secs, cached, moved = 0.0, 0, 0
            else:
                res = self.hicache.fetch_prefix(history_tokens)
                fetch_secs, cached, moved = (
                    res.transfer_seconds, res.prefix_tokens, res.bytes_moved)
            new_tokens = len(history_tokens) - cached
            prefill_secs = self.perf.prefill_seconds(new_tokens)
            # server-side TTFT: from turn admission to first token (queue
            # wait excluded, matching the paper's serving-side measurement)
            ttft = fetch_secs + prefill_secs
            decode_secs = self.perf.decode_seconds(cfg.output_tokens)
            finish = start + fetch_secs + prefill_secs + decode_secs
            if self.hicache is not None:
                self.hicache.insert(history_tokens)
            ttfts.append(ttft)
            per_round[turn].append(ttft)
            request_log.append((finish, moved, ttft))
            serialized += fetch_secs + prefill_secs + decode_secs
            slots[si] = finish
            makespan = max(makespan, finish)
            if turn < cfg.turns:
                order += 1
                heapq.heappush(work, (finish, order, client, turn + 1))
        return self._stats(
            ttfts, per_round, total_input, makespan,
            [self.perf.tpot] * len(ttfts), serialized, request_log=request_log)

    # ------------------------------------------------------------- async
    def _run_async(self) -> ServeStats:
        cfg = self.cfg
        fabric = self.engine.fabric
        convo = self._conversations()
        t0 = fabric.now
        # flight recorder (repro.obs): request phase spans ride the engine's
        # recorder; every site below is one `is not None` guard per phase
        rec = self.engine._rec
        ename = self.engine.name

        def mark_phase(req: Request, phase: str, span_t0: float,
                       **extra) -> None:
            payload = {"engine": ename, "client": req.client,
                       "turn": req.turn, "phase": phase, "t0": span_t0}
            payload.update(extra)
            rec.append(OBS.PHASE, fabric.now, payload)
        prefill_gpu = _SerialResource(fabric)
        decode_gpu = (
            _SerialResource(fabric) if cfg.handoff_bytes_per_token > 0
            else prefill_gpu)
        handoff_segs = None
        if cfg.handoff_bytes_per_token > 0:
            spec = self.engine.topology.spec
            max_kv = cfg.turns * cfg.input_tokens * cfg.handoff_bytes_per_token
            src = self.engine.register_segment(
                Location(node=cfg.gpu_node, kind=MemoryKind.DEVICE_HBM,
                         device=0, numa=spec.node.gpu_numa(0)),
                max_kv, name="pd-kv-src", materialize=False)
            dst = self.engine.register_segment(
                Location(node=cfg.decode_node, kind=MemoryKind.DEVICE_HBM,
                         device=0, numa=spec.node.gpu_numa(0)),
                max_kv, name="pd-kv-dst", materialize=False)
            handoff_segs = (src.segment_id, dst.segment_id)

        table = RequestTable(cfg.clients * cfg.turns)
        acc = _MetricsAccum(cfg.keep_log())
        state = {
            "outstanding": cfg.clients * cfg.turns,
            "pending_ops": 0,  # fire-and-forget inserts / checkpoint pulls
            "slots_free": cfg.concurrency,
            "total_input": 0,
            "last_finish": t0,
            "bytes_handoff": 0,
            "finished": 0,
            "ckpt_fired": 0,
            "ckpt_done": 0,
            "ckpt_seconds": 0.0,
        }
        queue: List[Tuple[float, int, int, int]] = []
        order = [cfg.clients]
        total_requests = cfg.clients * cfg.turns

        def enqueue(ready: float, client: int, turn: int) -> None:
            order[0] += 1
            heapq.heappush(queue, (ready, order[0], client, turn))
            fabric.call_at(ready, try_admit)

        def try_admit() -> None:
            while (state["slots_free"] > 0 and queue
                   and queue[0][0] <= fabric.now):
                _, _, client, turn = heapq.heappop(queue)
                state["slots_free"] -= 1
                start_request(table.create(client, turn))

        # -- stage 1: HiCache prefix fetch (async TENT batch) --------------
        def start_request(req: Request) -> None:
            req.t_admit = fabric.now
            state["total_input"] += cfg.input_tokens
            history = convo[req.client][: req.turn * cfg.input_tokens]
            if self.hicache is None:
                fetched(req, history, 0, 0.0, 0)
            else:
                self.hicache.fetch_prefix_async(
                    history,
                    lambda res, req=req, history=history: fetched(
                        req, history, res.prefix_tokens, res.transfer_seconds,
                        res.bytes_moved))

        # -- stage 2: chunked prefill on the (shared) compute resource ------
        def fetched(req: Request, history, cached, fetch_secs, moved) -> None:
            if rec is not None:
                mark_phase(req, "fetch", req.t_admit, bytes=moved)
            req.t_mark = fabric.now
            req.cached, req.fetch_secs, req.bytes_moved = cached, fetch_secs, moved
            req.service_secs = fetch_secs
            new_tokens = len(history) - cached
            chunk = cfg.chunk_tokens if cfg.chunk_tokens > 0 else max(new_tokens, 1)
            chunks = [chunk] * (new_tokens // chunk)
            if new_tokens % chunk:
                chunks.append(new_tokens % chunk)
            run_prefill(req, history, chunks)

        def run_prefill(req: Request, history, chunks: List[int]) -> None:
            if not chunks:
                prefilled(req, history)
                return
            secs = self.perf.prefill_seconds(chunks[0])
            req.service_secs += secs
            prefill_gpu.submit(
                secs, lambda req=req, history=history, rest=chunks[1:]:
                run_prefill(req, history, rest))

        # -- stage 3: prefill->decode KV handoff (async TENT batch) ---------
        def prefilled(req: Request, history) -> None:
            if rec is not None:
                mark_phase(req, "prefill", req.t_mark)
            if handoff_segs is None:
                req.ttft = fabric.now - req.t_admit
                start_decode(req, history)
                return
            nbytes = max(len(history) * cfg.handoff_bytes_per_token, 1)
            state["bytes_handoff"] += nbytes
            t_ship = fabric.now
            b = self.engine.allocate_batch()
            self.engine.submit_transfer(
                b, [(handoff_segs[0], 0, handoff_segs[1], 0, nbytes)])

            def shipped(res, req=req, history=history, t_ship=t_ship,
                        nbytes=nbytes):
                assert res.ok, res.error
                if rec is not None:
                    mark_phase(req, "handoff", t_ship, bytes=nbytes)
                req.service_secs += fabric.now - t_ship
                # PD mode: the first token comes from the decode worker, so
                # TTFT includes the KV handoff
                req.ttft = fabric.now - req.t_admit
                start_decode(req, history)

            self.engine.on_batch_done(b, shipped)

        # -- stage 4: decode in chunks on the decode resource ---------------
        def start_decode(req: Request, history) -> None:
            req.decode_start = fabric.now
            req.service_secs += self.perf.decode_seconds(cfg.output_tokens)
            run_decode(req, history, cfg.output_tokens)

        def run_decode(req: Request, history, tokens_left: int) -> None:
            if tokens_left <= 0:
                finish(req, history)
                return
            n = min(cfg.decode_chunk, tokens_left)
            decode_gpu.submit(
                self.perf.decode_seconds(n),
                lambda req=req, history=history, left=tokens_left - n:
                run_decode(req, history, left))

        # -- stage 5: finish, insert, release the slot ----------------------
        def finish(req: Request, history) -> None:
            now = fabric.now
            req.ttft = req.ttft or (now - req.t_admit)
            if rec is not None:
                mark_phase(req, "decode", req.decode_start)
                mark_phase(req, "request", req.t_admit, ttft=req.ttft)
            tpot = (now - req.decode_start) / max(cfg.output_tokens, 1)
            acc.observe(now, req.bytes_moved, req.ttft, tpot, req.turn,
                        req.service_secs)
            state["last_finish"] = max(state["last_finish"], now)
            state["outstanding"] -= 1
            state["finished"] += 1
            state["slots_free"] += 1
            if self.hicache is not None:
                state["pending_ops"] += 1

                def inserted(_secs):
                    state["pending_ops"] -= 1

                self.hicache.insert_async(history, inserted)
            maybe_refresh_weights()
            if req.turn < cfg.turns:
                enqueue(now, req.client, req.turn + 1)
            try_admit()

        # -- overlapped weight refresh --------------------------------------
        def maybe_refresh_weights() -> None:
            if self.checkpoint is None or cfg.checkpoint_updates <= 0:
                return
            due = (state["finished"] * (cfg.checkpoint_updates + 1)
                   ) // max(total_requests, 1)
            while state["ckpt_fired"] < min(due, cfg.checkpoint_updates):
                state["ckpt_fired"] += 1
                state["pending_ops"] += 1

                def refreshed(res):
                    state["ckpt_done"] += 1
                    state["ckpt_seconds"] += res.seconds
                    state["pending_ops"] -= 1

                self.checkpoint.update_async(refreshed)

        for c in range(cfg.clients):
            enqueue(t0, c, 1)
        try_admit()
        guard = 0
        while state["outstanding"] > 0 or state["pending_ops"] > 0:
            if not fabric.step():
                raise RuntimeError(
                    f"serving closed loop stalled: {state['outstanding']} "
                    f"requests and {state['pending_ops']} ops outstanding "
                    "with an idle fabric")
            guard += 1
            if guard > _EVENT_BUDGET:
                raise RuntimeError("serving closed loop exceeded event budget")
        return acc.stats(
            total_input=state["total_input"],
            makespan=state["last_finish"] - t0,
            bytes_promoted=self.hicache.bytes_promoted if self.hicache else 0,
            bytes_handoff=state["bytes_handoff"],
            ckpt_updates=state["ckpt_done"],
            ckpt_seconds=state["ckpt_seconds"],
        )

    # ------------------------------------------------------------- batched
    @hot_path
    def _run_batched(self) -> ServeStats:
        """Production-stream stepper: whole phases advance per tick over the
        SoA `RequestTable`; the only per-request Python work is the metric
        observation at finish. The spray policy decides the run through the
        per-tick cohort promotion batches — everything else is identical
        between policies, exactly the paper's ablation discipline."""
        from ..scenarios.traffic import TrafficSpec, promotion_bytes

        cfg = self.cfg
        fabric = self.engine.fabric
        t0 = fabric.now
        stream = TrafficSpec(
            requests=cfg.stream_requests, arrival_rate=cfg.arrival_rate,
            zipf_alpha=cfg.zipf_alpha, groups=cfg.traffic_groups,
            input_tokens=cfg.input_tokens, output_tokens=cfg.output_tokens,
            seed=cfg.seed).generate()
        promo = promotion_bytes(
            stream, prefix_frac=cfg.prefix_frac,
            kv_bytes_per_token=cfg.stream_kv_bytes_per_token,
            resident_s=cfg.resident_s)
        n = len(stream)

        tb = RequestTable(n)
        tb.size = n
        tb.arrival[:] = stream.arrival + t0
        tb.tenant[:] = stream.group
        tb.input_tokens[:] = stream.input_tokens
        tb.output_tokens[:] = stream.output_tokens
        tb.prefix_bytes[:] = promo
        phase = tb.phase  # PH_PENDING everywhere

        # promotion endpoints: the KV store tier's DRAM -> serving GPU HBM
        numa = self.engine.topology.spec.node.gpu_numa(0)
        src = self.engine.register_segment(
            Location(node=cfg.store_node, kind=MemoryKind.HOST_DRAM,
                     device=0, numa=0),
            max(int(promo.sum()), 1), name="stream-kv-store",
            materialize=False)
        dst = self.engine.register_segment(
            Location(node=cfg.gpu_node, kind=MemoryKind.DEVICE_HBM,
                     device=0, numa=numa),
            max(int(promo.sum()), 1), name="stream-kv-gpu",
            materialize=False)

        # vectorized compute budgets (tokens per tick)
        chunk = cfg.chunk_tokens if cfg.chunk_tokens > 0 else 256
        prefill_budget = cfg.tick_s * chunk / self.perf.prefill_seconds(chunk)
        decode_tokens = cfg.tick_s / self.perf.tpot  # per active request

        acc = _MetricsAccum(cfg.keep_log())
        state = {"bytes_promoted": 0, "in_flight": 0, "done": 0}
        admit_ptr = 0  # rows [0, admit_ptr) admitted; arrivals are sorted
        t = t0
        last_finish = t0
        total_input = 0
        # Livelock guard: a saturated server may legitimately run for many
        # multiples of the arrival span (prefill throughput bounds drain
        # rate), so cap *stalled* ticks — virtual time with zero completions
        # while work remains — rather than total runtime.
        stall_limit = int(120.0 / cfg.tick_s) + 1_000

        def cohort_done(res, rows=None):
            assert res.ok, res.error
            sel = rows[phase[rows] == PH_FETCH]
            phase[sel] = PH_PREFILL

        ticks = 0
        last_done = 0
        stalled = 0
        while state["done"] < n:
            t_next = t + cfg.tick_s
            fabric.run_until(t_next)

            # -- admission: arrival order, bounded by free slots ------------
            free = cfg.concurrency - state["in_flight"]
            if free > 0 and admit_ptr < n:
                hi = int(np.searchsorted(tb.arrival, t_next, side="right"))
                k = min(free, hi - admit_ptr)
                if k > 0:
                    rows = np.arange(admit_ptr, admit_ptr + k)
                    admit_ptr += k
                    state["in_flight"] += k
                    tb.t_admit[rows] = t_next
                    total_input += int(tb.input_tokens[rows].sum())
                    prefix_tok = np.rint(
                        tb.input_tokens[rows] * cfg.prefix_frac)
                    tb.prefill_left[rows] = tb.input_tokens[rows] - prefix_tok
                    tb.bytes_moved[rows] = tb.prefix_bytes[rows]
                    cold = tb.prefix_bytes[rows] > 0
                    phase[rows[~cold]] = PH_PREFILL
                    nbytes = int(tb.prefix_bytes[rows].sum())
                    if nbytes > 0:
                        phase[rows[cold]] = PH_FETCH
                        state["bytes_promoted"] += nbytes
                        b = self.engine.allocate_batch()
                        self.engine.submit_transfer(
                            b, [(src.segment_id, 0, dst.segment_id, 0,
                                 nbytes)])
                        self.engine.on_batch_done(
                            b,  # one closure per cohort batch, not per item
                            lambda res, rows=rows[cold]: cohort_done(  # tentlint: disable=hot-path-alloc
                                res, rows))

            # -- prefill: FIFO share of the tick's token budget -------------
            active = np.flatnonzero(phase == PH_PREFILL)
            if active.size:
                left = tb.prefill_left[active]
                cum = np.cumsum(left)
                nfull = int(np.searchsorted(cum, prefill_budget, side="right"))
                done_rows = active[:nfull]
                if nfull < active.size:
                    used = cum[nfull - 1] if nfull > 0 else 0.0
                    tb.prefill_left[active[nfull]] -= prefill_budget - used
                if done_rows.size:
                    tb.prefill_left[done_rows] = 0.0
                    tb.ttft[done_rows] = t_next - tb.t_admit[done_rows]
                    tb.decode_start[done_rows] = t_next
                    tb.decode_left[done_rows] = tb.output_tokens[done_rows]
                    phase[done_rows] = PH_DECODE

            # -- decode: every active request streams at the model's TPOT ---
            active = np.flatnonzero(phase == PH_DECODE)
            if active.size:
                tb.decode_left[active] -= decode_tokens
                fin = active[tb.decode_left[active] <= 0.0]
                if fin.size:
                    phase[fin] = PH_DONE
                    tb.finish[fin] = t_next
                    state["done"] += fin.size
                    state["in_flight"] -= fin.size
                    last_finish = t_next
                    tpots = (t_next - tb.decode_start[fin]) / np.maximum(
                        tb.output_tokens[fin], 1)
                    service = (t_next - tb.t_admit[fin])
                    for i, row in enumerate(fin):
                        acc.observe(
                            t_next, int(tb.bytes_moved[row]),
                            float(tb.ttft[row]), float(tpots[i]), 1,
                            float(service[i]))

            t = t_next
            ticks += 1
            if state["done"] > last_done:
                last_done = state["done"]
                stalled = 0
            else:
                stalled += 1
                if stalled > stall_limit:
                    # raise-path only: building the error message
                    hist = {p: int(np.sum(phase == p)) for p in  # tentlint: disable=hot-path-alloc
                            (PH_PENDING, PH_FETCH, PH_PREFILL, PH_DECODE)}
                    raise RuntimeError(
                        f"batched serving stream livelocked: "
                        f"{state['done']}/{n} finished, no completions in "
                        f"{stalled} ticks (pending/fetch/prefill/decode = "
                        f"{hist})")
        self._last_table = tb  # introspection hook for tests/benchmarks
        return acc.stats(
            total_input=total_input,
            makespan=last_finish - t0,
            bytes_promoted=state["bytes_promoted"],
        )
