"""Multi-turn conversation serving simulator (paper §5.1.1 / Table 2).

Clients hold multi-turn conversations; every turn appends `input_tokens` new
prompt tokens to the history. Without HiCache the whole history re-prefills
each turn. With HiCache, the cached-prefix KV pages are *fetched* through
TENT (promotions from the global CPU/disk tiers are the latency-critical
elephant flows) and only the new suffix prefills. The transfer engine policy
("tent" vs "round_robin" vs others) is the only thing that changes between
the compared configurations — exactly the paper's ablation.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from ..core import TentEngine
from .hicache import HiCache
from .perf_model import PerfModel


@dataclasses.dataclass
class ServeSimConfig:
    clients: int = 12
    concurrency: int = 4
    turns: int = 10
    input_tokens: int = 2048
    output_tokens: int = 128
    seed: int = 0


@dataclasses.dataclass
class ServeStats:
    input_throughput: float  # input tokens / s
    avg_ttft: float
    p50_ttft: float
    p90_ttft: float
    p99_ttft: float
    round_avg_ttft: Dict[int, float]
    total_input_tokens: int
    makespan: float
    bytes_promoted: int


class ServingSimulator:
    def __init__(
        self,
        engine: TentEngine,
        perf: PerfModel,
        *,
        hicache: Optional[HiCache],
        sim_cfg: ServeSimConfig,
    ):
        self.engine = engine
        self.perf = perf
        self.hicache = hicache
        self.cfg = sim_cfg

    def run(self) -> ServeStats:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        fabric = self.engine.fabric
        # Each client's conversation is a fixed random token stream; turn k
        # uses history[: k * input_tokens] + fresh input block.
        convo = {
            c: rng.integers(1, 50_000, size=cfg.turns * cfg.input_tokens).tolist()
            for c in range(cfg.clients)
        }
        ttfts: List[float] = []
        per_round: Dict[int, List[float]] = {r: [] for r in range(1, cfg.turns + 1)}
        # concurrency slots
        slots = [0.0] * cfg.concurrency
        # (ready_time, order, client, turn)
        work = [(0.0, c, c, 1) for c in range(cfg.clients)]
        heapq.heapify(work)
        total_input = 0
        makespan = 0.0
        order = cfg.clients
        while work:
            ready, _, client, turn = heapq.heappop(work)
            si = int(np.argmin(slots))
            start = max(ready, slots[si])
            fabric.run_until(start)
            history_tokens = convo[client][: turn * cfg.input_tokens]
            total_input += cfg.input_tokens
            if self.hicache is None:
                fetch_secs, cached = 0.0, 0
            else:
                res = self.hicache.fetch_prefix(history_tokens)
                fetch_secs, cached = res.transfer_seconds, res.prefix_tokens
            new_tokens = len(history_tokens) - cached
            prefill_secs = self.perf.prefill_seconds(new_tokens)
            # server-side TTFT: from turn admission to first token (queue
            # wait excluded, matching the paper's serving-side measurement)
            ttft = fetch_secs + prefill_secs
            decode_secs = self.perf.decode_seconds(cfg.output_tokens)
            finish = start + fetch_secs + prefill_secs + decode_secs
            if self.hicache is not None:
                self.hicache.insert(history_tokens)
            ttfts.append(ttft)
            per_round[turn].append(ttft)
            slots[si] = finish
            makespan = max(makespan, finish)
            if turn < cfg.turns:
                order += 1
                heapq.heappush(work, (finish, order, client, turn + 1))
        arr = np.asarray(ttfts)
        return ServeStats(
            input_throughput=total_input / makespan,
            avg_ttft=float(arr.mean()),
            p50_ttft=float(np.percentile(arr, 50)),
            p90_ttft=float(np.percentile(arr, 90)),
            p99_ttft=float(np.percentile(arr, 99)),
            round_avg_ttft={r: float(np.mean(v)) for r, v in per_round.items() if v},
            total_input_tokens=total_input,
            makespan=makespan,
            bytes_promoted=self.hicache.bytes_promoted if self.hicache else 0,
        )
