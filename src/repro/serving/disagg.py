"""Real-compute prefill/decode disaggregation over TENT.

A PrefillWorker runs the real JAX model on the prompt and produces a decode
cache; the cache bytes are shipped to the DecodeWorker's node through one
declarative TENT batch (this is the PD-disaggregation elephant flow); the
DecodeWorker then generates tokens with the real model. Used by the
end-to-end example and integration tests at smoke scale — numerically
identical to monolithic generation, by construction and by test.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import Location, MemoryKind, TentEngine
from ..models import decode_step, init_cache, prefill


def tree_to_bytes(tree: Any) -> Tuple[np.ndarray, List[Tuple[tuple, str]]]:
    leaves = jax.tree_util.tree_leaves(tree)
    metas = [(l.shape, str(l.dtype)) for l in leaves]
    blobs = [np.ascontiguousarray(np.asarray(l)).view(np.uint8).reshape(-1) for l in leaves]
    return (np.concatenate(blobs) if blobs else np.zeros(0, np.uint8)), metas


def bytes_to_tree(data: np.ndarray, like: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    off = 0
    for l in leaves:
        nbytes = np.dtype(l.dtype).itemsize * int(np.prod(l.shape)) if l.ndim else np.dtype(l.dtype).itemsize
        arr = data[off : off + nbytes].view(np.dtype(l.dtype) if l.dtype != jnp.bfloat16 else jnp.bfloat16)
        out.append(jnp.asarray(arr.reshape(l.shape)))
        off += nbytes
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class DisaggResult:
    tokens: np.ndarray  # (B, n_new)
    kv_transfer_seconds: float
    kv_bytes: int


class DisaggregatedServer:
    """Prefill on one node's GPUs, decode on another's, KV over TENT."""

    def __init__(self, engine: TentEngine, cfg: ModelConfig, params: Any,
                 *, prefill_node: int = 0, decode_node: int = 1):
        self.engine = engine
        self.cfg = cfg
        self.params = params
        self.prefill_node = prefill_node
        self.decode_node = decode_node
        spec = engine.topology.spec
        self._loc_p = Location(node=prefill_node, kind=MemoryKind.DEVICE_HBM, device=0,
                               numa=spec.node.gpu_numa(0))
        self._loc_d = Location(node=decode_node, kind=MemoryKind.DEVICE_HBM, device=0,
                               numa=spec.node.gpu_numa(0))

    def ship_kv_async(self, data: np.ndarray, on_done=None) -> Tuple[Any, int]:
        """Declarative KV-handoff intent: post the prefill->decode elephant
        flow as one async TENT batch and return (dst_segment, batch_id)
        immediately — the decode side is woken by the completion callback
        instead of the prefill side blocking on the wire. The closed-loop
        serving simulator and `generate(async_handoff=True)` both ride this.
        """
        nbytes = max(data.size, 1)
        src = self.engine.register_segment(self._loc_p, nbytes, name="kv-src")
        dst = self.engine.register_segment(self._loc_d, nbytes, name="kv-dst")
        src.write(0, data)
        batch = self.engine.allocate_batch()
        self.engine.submit_transfer(
            batch, [(src.segment_id, 0, dst.segment_id, 0, nbytes)])
        if on_done is not None:
            self.engine.on_batch_done(batch, on_done)
        return dst, batch

    def generate(self, prompt: jax.Array, n_new: int, max_len: int,
                 enc_frames: jax.Array | None = None,
                 *, async_handoff: bool = False) -> DisaggResult:
        B, S = prompt.shape
        # ---- prefill pool ----
        last_logits, cache = prefill(self.cfg, self.params, prompt, max_len,
                                     enc_frames=enc_frames)
        # ---- ship the cache through TENT ----
        data, _ = tree_to_bytes(cache)
        t0 = self.engine.fabric.now
        if async_handoff:
            # intent mode: the batch is posted and the decode worker starts
            # when the completion callback lands (here: drain the fabric —
            # the real decode numerics need the full cache)
            done = {}
            dst, _ = self.ship_kv_async(
                data, lambda res: done.setdefault("res", res))
            self.engine.run_until_idle()
            res = done["res"]
        else:
            src = self.engine.register_segment(self._loc_p, max(data.size, 1), name="kv-src")
            dst = self.engine.register_segment(self._loc_d, max(data.size, 1), name="kv-dst")
            src.write(0, data)
            res = self.engine.transfer_sync(src.segment_id, 0, dst.segment_id, 0, max(data.size, 1))
        assert res.ok, res.error
        secs = self.engine.fabric.now - t0
        cache = bytes_to_tree(dst.read(0, data.size), cache)
        # ---- decode pool ----
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        out = [np.asarray(tok)]
        step = jax.jit(lambda c, t, p: decode_step(self.cfg, self.params, c, t, p))
        for i in range(n_new - 1):
            logits, cache = step(cache, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok))
        return DisaggResult(
            tokens=np.concatenate(out, axis=1),
            kv_transfer_seconds=secs,
            kv_bytes=int(data.size),
        )


def monolithic_generate(cfg: ModelConfig, params: Any, prompt: jax.Array, n_new: int,
                        max_len: int, enc_frames: jax.Array | None = None) -> np.ndarray:
    B, S = prompt.shape
    last_logits, cache = prefill(cfg, params, prompt, max_len, enc_frames=enc_frames)
    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    step = jax.jit(lambda c, t, p: decode_step(cfg, params, c, t, p))
    for i in range(n_new - 1):
        logits, cache = step(cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)
