"""Moonshot-style checkpoint engine over TENT (paper §5.1.2 / Table 3).

In-place model weight updates for RL pipelines: a parameter-server node
holds the fresh checkpoint in host memory; every rank (GPU) pulls its weight
shard through the transfer engine. All ranks participate concurrently
(Checkpoint Engine v0.2.0 semantics). The backend under the pull — Mooncake
TE's static striping vs TENT's slice spraying — is the Table-3 ablation; the
checkpoint format, sharding, and update schedule stay fixed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import Location, MemoryKind, TentEngine
from ..core.segments import Segment


@dataclasses.dataclass
class UpdateResult:
    seconds: float
    bytes: int
    ranks: int

    @property
    def aggregate_bandwidth(self) -> float:
        return self.bytes / max(self.seconds, 1e-12)


class CheckpointEngine:
    def __init__(
        self,
        engine: TentEngine,
        *,
        nodes: int,
        gpus_per_node: int,
        source_node: int = 0,
        materialize: bool = True,
    ):
        self.engine = engine
        self.nodes = nodes
        self.gpus_per_node = gpus_per_node
        self.source_node = source_node
        self.materialize = materialize
        self.world = nodes * gpus_per_node
        self._src: Optional[Segment] = None
        self._dst: List[Segment] = []
        self._tensor_index: List[Tuple[str, int, int]] = []  # (name, offset, nbytes)
        self.total_bytes = 0

    # ------------------------------------------------------------- staging
    def register_checkpoint(self, table: Dict[str, "np.ndarray | int"]) -> None:
        """Stage a named-tensor table into the parameter-server host segment.

        Values may be arrays (bytes are staged and verifiable) or plain ints
        (sizes only — used with materialize=False for scale simulations).
        Empty or zero-byte tables are rejected: a 0-byte checkpoint would
        register 0-byte segments and post 0-byte per-rank transfers, which is
        never what an RL weight refresh means."""
        if not table:
            raise ValueError("register_checkpoint: empty checkpoint table")
        blobs = []
        off = 0
        self._tensor_index = []
        for name in sorted(table):
            v = table[name]
            if isinstance(v, (int, np.integer)):
                nbytes = int(v)
                raw = None
            else:
                raw = np.ascontiguousarray(v).view(np.uint8).reshape(-1)
                nbytes = raw.size
            self._tensor_index.append((name, off, nbytes))
            if self.materialize:
                assert raw is not None, "materialized checkpoints need real arrays"
                blobs.append(raw)
            off += nbytes
        if off == 0:
            raise ValueError(
                "register_checkpoint: checkpoint table is zero bytes "
                f"({len(table)} entries, all empty)")
        # pad so every rank's shard is equal-sized
        shard = (off + self.world - 1) // self.world
        self.total_bytes = shard * self.world
        self.shard_bytes = shard
        loc = Location(node=self.source_node, kind=MemoryKind.HOST_DRAM, device=0, numa=0)
        self._src = self.engine.register_segment(
            loc, self.total_bytes, name="ckpt-src", materialize=self.materialize)
        if self.materialize:
            payload = np.concatenate(blobs) if blobs else np.zeros(0, np.uint8)
            padded = np.zeros(self.total_bytes, dtype=np.uint8)
            padded[: payload.size] = payload
            self._src.write(0, padded)
        # per-rank GPU destination segments
        spec = self.engine.topology.spec
        self._dst = []
        for n in range(self.nodes):
            for g in range(self.gpus_per_node):
                loc = Location(
                    node=n, kind=MemoryKind.DEVICE_HBM, device=g,
                    numa=spec.node.gpu_numa(g),
                )
                self._dst.append(
                    self.engine.register_segment(
                        loc, shard, name=f"ckpt-r{n}.{g}", materialize=self.materialize)
                )

    # ------------------------------------------------------------- update
    def _submit_update(self) -> int:
        assert self._src is not None, "register_checkpoint first"
        batch = self.engine.allocate_batch()
        self.engine.submit_transfer(
            batch,
            [
                (self._src.segment_id, r * self.shard_bytes, dst.segment_id, 0, self.shard_bytes)
                for r, dst in enumerate(self._dst)
            ],
        )
        return batch

    def update_async(
        self, on_done: Optional[Callable[[UpdateResult], None]] = None
    ) -> int:
        """Overlap-mode weight refresh: the all-rank pull is submitted and the
        call returns immediately with the batch id, so the refresh contends
        with whatever live traffic (decode, KV promotion) shares the fabric.
        `on_done` fires with the `UpdateResult` when the last shard lands."""
        t0 = self.engine.fabric.now
        batch = self._submit_update()

        def _landed(res):
            assert res.ok, res.error
            if on_done is not None:
                on_done(UpdateResult(
                    seconds=self.engine.fabric.now - t0,
                    bytes=self.total_bytes, ranks=self.world))

        self.engine.on_batch_done(batch, _landed)
        return batch

    def update(self, *, verify: bool = False) -> UpdateResult:
        """One in-place weight refresh: every rank pulls its shard, one
        declarative batch, all ranks in flight concurrently."""
        t0 = self.engine.fabric.now
        batch = self._submit_update()
        res = self.engine.wait(batch)
        assert res.ok, res.error
        secs = self.engine.fabric.now - t0
        if verify:
            for r, dst in enumerate(self._dst):
                got = dst.read(0, self.shard_bytes)
                want = self._src.read(r * self.shard_bytes, self.shard_bytes)
                np.testing.assert_array_equal(got, want)
        return UpdateResult(seconds=secs, bytes=self.total_bytes, ranks=self.world)

    # ------------------------------------------------------------- readback
    def rank_table(self, rank: int) -> Dict[str, np.ndarray]:
        """Reassemble the tensors whose bytes landed (fully) in one rank's
        shard — used by tests to prove end-to-end integrity."""
        dst = self._dst[rank]
        lo = rank * self.shard_bytes
        hi = lo + self.shard_bytes
        out = {}
        for name, off, nbytes in self._tensor_index:
            if off >= lo and off + nbytes <= hi:
                out[name] = dst.read(off - lo, nbytes)
        return out
