"""Streaming percentile estimation (P² algorithm, Jain & Chlamtac 1985).

At 10^5-10^6 requests per scenario, keeping every TTFT/TPOT sample alive
just to call `np.percentile` at the end is an O(N) memory tax on the hot
loop — the request log grew unboundedly in async mode before
`ServeSimConfig.log_requests` gated it. A `P2Quantile` keeps five markers
(O(1) memory, O(1) update) and tracks one quantile; `PercentileSketch`
bundles the P50/P90/P99 trio plus exact count/mean/max. Below
`EXACT_THRESHOLD` observations the sketch returns exact order statistics
from its warm-up buffer (P² needs >= 5 samples to even initialize, and
small scenarios — the whole existing library — should keep their exact
percentiles bit-for-bit).

Deterministic given insertion order: no randomness, so the virtual clock's
reproducibility guarantee extends through the metrics path.
"""
from __future__ import annotations

from typing import List

__all__ = ["P2Quantile", "PercentileSketch", "EXACT_THRESHOLD"]

# Sketches report exact order statistics until this many samples have been
# observed; beyond it the P^2 markers take over. 1000 keeps every scenario
# in today's library exact while bounding the buffer.
EXACT_THRESHOLD = 1000


class P2Quantile:
    """Single-quantile P^2 estimator: five markers whose heights approximate
    the (0, q/2, q, (1+q)/2, 1) quantiles, nudged toward ideal positions by
    a piecewise-parabolic update on every observation."""

    __slots__ = ("q", "n", "heights", "pos", "want", "dpos")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self.n = 0
        self.heights: List[float] = []
        self.pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.want = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self.dpos = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        h = self.heights
        if self.n <= 5:
            h.append(x)
            if self.n == 5:
                h.sort()
            return
        # locate the cell and bump marker positions above it
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        pos = self.pos
        for i in range(k + 1, 5):
            pos[i] += 1.0
        want = self.want
        for i in range(5):
            want[i] += self.dpos[i]
        # nudge the three interior markers toward their ideal positions
        for i in (1, 2, 3):
            d = want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
                    (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d >= 0 else -1.0
                hp = self._parabolic(i, d)
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:  # parabolic estimate escaped; fall back to linear
                    j = i + int(d)
                    h[i] = h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, pos = self.heights, self.pos
        return h[i] + d / (pos[i + 1] - pos[i - 1]) * (
            (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i]) /
            (pos[i + 1] - pos[i]) +
            (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1]) /
            (pos[i] - pos[i - 1]))

    def value(self) -> float:
        if self.n == 0:
            return 0.0
        if self.n <= 5:
            s = sorted(self.heights)
            # nearest-rank on the warm-up buffer
            idx = min(len(s) - 1, max(0, round(self.q * (len(s) - 1))))
            return s[int(idx)]
        return self.heights[2]


class PercentileSketch:
    """P50/P90/P99 + count/mean/max over one metric stream. Exact (buffered
    numpy percentile, linear interpolation — identical to the legacy log
    path) below EXACT_THRESHOLD samples, P^2 beyond."""

    __slots__ = ("count", "total", "max", "_buf", "_p2")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._buf: List[float] = []
        self._p2 = (P2Quantile(0.50), P2Quantile(0.90), P2Quantile(0.99))

    def add(self, x: float) -> None:
        self.count += 1
        self.total += x
        if x > self.max:
            self.max = x
        if self._buf is not None:
            self._buf.append(x)
            if len(self._buf) > EXACT_THRESHOLD:
                self._buf = None
        for p2 in self._p2:
            p2.add(x)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100], matching np.percentile's convention."""
        if self.count == 0:
            return 0.0
        if self._buf is not None:
            import numpy as np

            return float(np.percentile(np.asarray(self._buf), q))
        for p2 in self._p2:
            if abs(p2.q * 100.0 - q) < 1e-9:
                return p2.value()
        raise ValueError(
            f"P{q:g} not tracked beyond the exact buffer (have P50/P90/P99)")
