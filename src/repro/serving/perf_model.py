"""Compute-time model for the serving simulator.

The fabric simulator gives *transfer* times in virtual seconds; this module
supplies the *compute* times (prefill/decode) for a model on a given chip
budget, so end-to-end serving metrics (TTFT, TPOT, throughput) can be
assembled on the same virtual clock.

Constants are calibrated two ways:
  * `from_table2()` matches the paper's 8xH800 TP8 Qwen3-235B-A22B testbed
    (baseline R1 TTFT 0.38 s @ 2048 input tokens -> ~5.4k tok/s prefill;
    TPOT < 30 ms) so the Table 2 reproduction is apples-to-apples.
  * `from_roofline()` derives rates from MODEL_FLOPS = 6 N D against a chip
    budget with an MFU assumption — used for the TPU-target what-ifs.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class PerfModel:
    prefill_tokens_per_s: float
    tpot: float  # seconds per output token

    def prefill_seconds(self, tokens: int) -> float:
        return tokens / self.prefill_tokens_per_s

    def decode_seconds(self, tokens: int) -> float:
        return tokens * self.tpot


def from_table2() -> PerfModel:
    """Paper testbed: Qwen3-235B-A22B, 8xH800, TP8 (Table 2 baseline R1)."""
    return PerfModel(prefill_tokens_per_s=2048 / 0.38, tpot=0.025)


def from_roofline(
    cfg: ModelConfig, *, chips: int, peak_flops: float = 197e12, mfu: float = 0.45
) -> PerfModel:
    n_active = cfg.param_count(active_only=True)
    flops_per_token = 2 * n_active  # forward
    rate = chips * peak_flops * mfu / flops_per_token
    # decode is memory-bound; approximate TPOT by weight-read time
    hbm = 819e9
    bytes_per_step = 2 * n_active  # bf16 weights
    tpot = bytes_per_step / (chips * hbm * 0.6)
    return PerfModel(prefill_tokens_per_s=rate, tpot=max(tpot, 1e-4))
