"""Sharding rules: logical parameter/activation axes -> mesh axes.

Policy (GSPMD-propagated from in_shardings + a few constraints):

  * weights are 2-D sharded: the "wide" axis (heads / ffn / experts / vocab)
    over `model`, the d_model (or stacked) axis over `data` (FSDP-style) —
    so even 132B/235B configs fit 16 GB/chip with optimizer state;
  * batch shards over (`pod`, `data`); sequence stays unsharded (decode KV
    ring buffers and SSD chunk scans keep locality);
  * KV heads shard over `model` only when divisible (granite's MQA kv=1
    replicates); MoE experts shard over `model` (expert parallelism);
  * optimizer moments follow their parameters.

An axis is dropped (replicated) whenever its size doesn't divide the mesh
axis — jax pads otherwise, which burns memory at 512 devices.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


def _div(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    return axis is not None and axis in mesh.shape and n % mesh.shape[axis] == 0


def _maybe(axis: Optional[str], size: int, mesh: Mesh) -> Optional[str]:
    return axis if _div(size, mesh, axis) else None


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _wide_spec(shape: tuple, mesh: Mesh, *, wide_axis: int, fsdp_axis: Optional[int],
               fsdp: bool) -> P:
    """Shard shape[wide_axis] over model, shape[fsdp_axis] over data."""
    spec: list = [None] * len(shape)
    if _div(shape[wide_axis], mesh, "model"):
        spec[wide_axis] = "model"
    if fsdp and fsdp_axis is not None and _div(shape[fsdp_axis], mesh, "data"):
        spec[fsdp_axis] = "data"
    return P(*spec)


def param_partition_specs(
    cfg: ModelConfig, mesh: Mesh, shapes: Dict[str, Any], *, fsdp: bool = True
) -> Dict[str, Any]:
    """PartitionSpec pytree matching param_shapes(cfg)'s structure."""

    def leaf_spec(key_path: str, shape: tuple) -> P:
        name = key_path.split("/")[-1]
        nd = len(shape)
        # embeddings / lm head: vocab over model, d_model over data
        if name == "embed":
            return _wide_spec(shape, mesh, wide_axis=0, fsdp_axis=1, fsdp=fsdp)
        if name == "lm_head":
            return _wide_spec(shape, mesh, wide_axis=1, fsdp_axis=0, fsdp=fsdp)
        # stacked layer tensors: axis 0 = layer (never sharded)
        if name in ("ln1", "ln2", "lnx", "final_norm", "ssm_norm", "ssm_A_log",
                    "ssm_D", "ssm_dt_bias", "ssm_conv_b", "bq", "bk", "bv"):
            return P()
        if name == "ssm_conv_w":
            return P()
        if name in ("wq", "wk", "wv", "xwq", "xwk", "xwv"):  # (L, D, heads*hd)
            return _wide_spec(shape, mesh, wide_axis=nd - 1, fsdp_axis=nd - 2, fsdp=fsdp)
        if name in ("wo", "xwo"):  # (L, heads*hd, D)
            return _wide_spec(shape, mesh, wide_axis=nd - 2, fsdp_axis=nd - 1, fsdp=fsdp)
        if name == "router":  # (L, D, E): replicate E (tiny), fsdp D
            spec = [None] * nd
            if fsdp and _div(shape[nd - 2], mesh, "data"):
                spec[nd - 2] = "data"
            return P(*spec)
        if name in ("w_gate", "w_up"):
            if cfg.num_experts > 0 and nd == 4:  # (L, E, D, F): expert parallel
                return _wide_spec(shape, mesh, wide_axis=1, fsdp_axis=3, fsdp=fsdp)
            return _wide_spec(shape, mesh, wide_axis=nd - 1, fsdp_axis=nd - 2, fsdp=fsdp)
        if name == "w_down":
            if cfg.num_experts > 0 and nd == 4:  # (L, E, F, D)
                return _wide_spec(shape, mesh, wide_axis=1, fsdp_axis=2, fsdp=fsdp)
            return _wide_spec(shape, mesh, wide_axis=nd - 2, fsdp_axis=nd - 1, fsdp=fsdp)
        if name == "ssm_in":  # (L, D, 2di+2N+nh): inner dim over model
            return _wide_spec(shape, mesh, wide_axis=nd - 1, fsdp_axis=nd - 2, fsdp=fsdp)
        if name == "ssm_out":  # (L, di, D)
            return _wide_spec(shape, mesh, wide_axis=nd - 2, fsdp_axis=nd - 1, fsdp=fsdp)
        return P()

    def walk(tree: Any, prefix: str = "") -> Any:
        if isinstance(tree, dict):
            return {k: walk(v, prefix + k + "/") for k, v in tree.items()}
        return leaf_spec(prefix.rstrip("/"), tree)

    return walk(shapes)


def param_shardings(cfg: ModelConfig, mesh: Mesh, shapes: Dict[str, Any], *, fsdp: bool = True):
    specs = param_partition_specs(cfg, mesh, shapes, fsdp=fsdp)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh) -> P:
    axes = batch_axes(mesh)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def cache_partition_specs(cfg: ModelConfig, mesh: Mesh, cache_shapes: Dict[str, Any]) -> Dict[str, P]:
    """Decode cache: (L, B, ...) — batch over (pod, data), kv-heads/ssm-heads
    over model when divisible."""
    baxes = batch_axes(mesh)
    out: Dict[str, P] = {}
    for name, sds in cache_shapes.items():
        shape = sds.shape
        B = shape[1]
        bshard = baxes if B % int(np.prod([mesh.shape[a] for a in baxes])) == 0 else (
            baxes[-1] if baxes and B % mesh.shape[baxes[-1]] == 0 else None
        )
        b = bshard if bshard else None
        if name in ("k", "v", "enc_k", "enc_v"):  # (L, B, W, K, Hd)
            kv = "model" if _div(shape[3], mesh, "model") else None
            # MQA/GQA with kv_heads < mesh: shard the cache LENGTH instead —
            # keeps e.g. granite's kv=1 32k cache at W/16 per chip.
            wshard = "model" if kv is None and _div(shape[2], mesh, "model") else None
            out[name] = P(None, b, wshard, kv, None)
        elif name == "ssm_state":  # (L, B, nh, hd, N)
            heads = "model" if _div(shape[2], mesh, "model") else None
            out[name] = P(None, b, heads, None, None)
        elif name == "conv_buf":  # (L, B, k-1, dim)
            dim = "model" if _div(shape[3], mesh, "model") else None
            out[name] = P(None, b, None, dim)
        else:
            out[name] = P()
    return out


def opt_state_specs(param_specs: Dict[str, Any]) -> Dict[str, Any]:
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }
