"""Activation-sharding context.

Model code is mesh-agnostic; the launcher activates this context and the
model's `constrain(x, kind)` calls become GSPMD sharding constraints that
pin activations to the megatron-style layout (batch over data axes, hidden
"wide" dims over model). Without constraints the partitioner is free to
all-gather full-batch activations against FSDP-sharded weights — the
pathological layout the §Perf baseline measures.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _cur():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, enabled: bool = True):
    """Enable activation constraints for everything traced inside."""
    batch = tuple(a for a in ("pod", "data") if a in mesh.shape)
    prev = _cur()
    _state.ctx = {
        "mesh": mesh,
        "batch": batch if len(batch) != 1 else batch[0],
        "model": "model" if "model" in mesh.shape else None,
        "enabled": enabled,
    }
    try:
        yield
    finally:
        _state.ctx = prev


def _wsc(x, spec: P):
    ctx = _cur()
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx["mesh"], spec))


def constrain(x, kind: str):
    """Apply the layout rule `kind` if a sharding context is active.

    kinds:
      bsd      (B, S, D) residual stream        -> (batch, None, None)
      bsf      (B, S, F) ffn/inner hidden       -> (batch, None, model)
      bshd     (B, S, H, hd) attention heads    -> (batch, None, model, None)
      logits   (B, S, V) or (B, V)              -> (batch, ..., model)
      ecd      (E, C, D) expert buckets         -> (model, None, None)
      lbskd    (L, B, W, K, hd) kv cache blocks -> (None, batch, None, model, None)
    """
    ctx = _cur()
    if ctx is None or not ctx["enabled"]:
        return x
    b, m = ctx["batch"], ctx["model"]
    if kind == "bsd":
        spec = P(b, None, None)
    elif kind == "bsf":
        spec = P(b, None, m)
    elif kind == "bshd":
        spec = P(b, None, m, None)
    elif kind == "logits":
        spec = P(*([b] + [None] * (x.ndim - 2) + [m]))
    elif kind == "ecd":
        spec = P(m, None, None)
    elif kind == "bhst":
        spec = P(b, m, None, None)
    elif kind == "lbskd":
        spec = P(None, b, None, m, None)
    elif kind == "cache_kv":
        # (B, W, K, Hd) collected decode-cache block: prefer sharding KV
        # heads over model; MQA/GQA below mesh size shard the length instead
        # (mirrors sharding.rules.cache_partition_specs).
        K = x.shape[2]
        msize = mesh_size = 1
        if m is not None:
            mesh_size = ctx["mesh"].shape[m]
        if m is not None and K % mesh_size == 0:
            spec = P(b, None, m, None)
        elif m is not None and x.shape[1] % mesh_size == 0:
            spec = P(b, m, None, None)
        else:
            spec = P(b, None, None, None)
    else:
        raise ValueError(f"unknown constraint kind {kind!r}")
    # divisibility guard: drop axes that don't divide
    mesh = ctx["mesh"]

    def ok(axis, dim):
        if axis is None:
            return None
        axes = axis if isinstance(axis, tuple) else (axis,)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        return axis if dim % total == 0 else None

    spec = P(*(ok(a, d) for a, d in zip(spec, x.shape)))
    return _wsc(x, spec)
