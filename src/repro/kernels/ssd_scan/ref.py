"""Pure-jnp oracle for the SSD scan kernel: the step-by-step recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jax.Array,  # (B, S, H, P)
    a: jax.Array,  # (B, S, H)
    B_in: jax.Array,  # (B, S, N)
    C_in: jax.Array,  # (B, S, N)
    initial_state: jax.Array | None = None,  # (B, H, P, N)
):
    b, s, h, p = x.shape
    n = B_in.shape[-1]
    st0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(st, t_in):
        x_t, a_t, B_t, C_t = t_in
        st = st * jnp.exp(a_t.astype(jnp.float32))[..., None, None]
        st = st + jnp.einsum("bhp,bn->bhpn", x_t.astype(jnp.float32), B_t.astype(jnp.float32))
        y_t = jnp.einsum("bhpn,bn->bhp", st, C_t.astype(jnp.float32))
        return st, y_t

    xs = (
        x.transpose(1, 0, 2, 3),
        a.transpose(1, 0, 2),
        B_in.transpose(1, 0, 2),
        C_in.transpose(1, 0, 2),
    )
    fin, ys = jax.lax.scan(step, st0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), fin
