from .ops import ssd_chunked
from .ref import ssd_scan_ref

__all__ = ["ssd_chunked", "ssd_scan_ref"]
