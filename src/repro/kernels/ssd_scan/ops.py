"""Jitted public wrapper matching repro.models.ssm.ssd_chunked's signature."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_bhsp


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(
    x: jax.Array,  # (B, S, H, P) — pre-multiplied by dt
    a: jax.Array,  # (B, S, H)
    B_in: jax.Array,  # (B, S, N)
    C_in: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, s, h, p = x.shape
    n = B_in.shape[-1]
    chunk = min(chunk, max(8, 1 << (s - 1).bit_length()))
    pad = (-s) % chunk
    if pad:
        # identity steps: x=0, B=0, a=0 leave the state untouched
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B_in = jnp.pad(B_in, ((0, 0), (0, pad), (0, 0)))
        C_in = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0)))
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    xt = x.transpose(0, 2, 1, 3)  # (B, H, S, P)
    at = a.transpose(0, 2, 1)  # (B, H, S)
    y, fin = ssd_scan_bhsp(xt, at, B_in, C_in, initial_state, chunk=chunk, interpret=interpret)
    y = y.transpose(0, 2, 1, 3)[:, :s]
    return y.astype(x.dtype), fin.astype(x.dtype)
