"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid: (batch, heads, chunks); the chunk axis is innermost and sequential, so
the fp32 inter-chunk recurrent state (headdim x state) lives in VMEM scratch
and flows from chunk to chunk without HBM round-trips. Per grid step the
kernel does the intra-chunk quadratic block (chunk x chunk decay-masked
attention-like matmuls, all 128-aligned for chunk=128/state=128) and one
rank-(chunk) state update — the same decomposition the SSD paper uses to hit
the MXU instead of a sequential scan.

BlockSpec tiling (per grid step, in VMEM):
  x: (1, 1, chunk, headdim)   a: (1, 1, chunk)
  B, C: (1, chunk, state)     y: (1, 1, chunk, headdim)
  state scratch: (headdim, state) fp32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, fin_ref, state_scr, *, nc: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)  # (l, p)
    a = a_ref[0, 0].astype(jnp.float32)  # (l,)
    B = b_ref[0].astype(jnp.float32)  # (l, n)
    C = c_ref[0].astype(jnp.float32)  # (l, n)
    l = x.shape[0]

    a_cs = jnp.cumsum(a)  # (l,)
    seg = a_cs[:, None] - a_cs[None, :]  # seg[i,j] = sum_{k=j+1..i} a_k
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    # intra-chunk quadratic block
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * Lmat  # (l, l)
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # inter-chunk contribution from the carried state
    state = state_scr[...]  # (p, n)
    y = y + jnp.exp(a_cs)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    # state update: state' = decay(chunk) * state + x^T (B * decay_to_end)
    decay_to_end = jnp.exp(a_cs[-1] - a_cs)  # (l,)
    state_scr[...] = state * jnp.exp(a_cs[-1]) + jax.lax.dot_general(
        x, B * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == nc - 1)
    def _finalize():
        fin_ref[0, 0, :, :] = state_scr[...].astype(fin_ref.dtype)


def ssd_scan_bhsp(
    x: jax.Array,  # (B, H, S, P) — pre-multiplied by dt
    a: jax.Array,  # (B, H, S)
    B_in: jax.Array,  # (B, S, N)
    C_in: jax.Array,  # (B, S, N)
    s0: jax.Array,  # (B, H, P, N) initial state
    *,
    chunk: int,
    interpret: bool = False,
):
    b, h, s, p = x.shape
    n = B_in.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1, chunk, n), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, B_in, C_in, s0)
