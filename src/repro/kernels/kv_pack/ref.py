"""Pure-jnp oracle for kv_pack/kv_unpack."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_pack_ref(pool: jax.Array, indices: jax.Array) -> jax.Array:
    return pool[indices]


def kv_unpack_ref(pool: jax.Array, buf: jax.Array, indices: jax.Array) -> jax.Array:
    return pool.at[indices].set(buf)
