from .ops import kv_pack, kv_unpack
from .ref import kv_pack_ref, kv_unpack_ref

__all__ = ["kv_pack", "kv_unpack", "kv_pack_ref", "kv_unpack_ref"]
