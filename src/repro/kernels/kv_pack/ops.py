"""Jitted public wrappers for KV page pack/unpack."""
from __future__ import annotations

import functools

import jax

from .kernel import kv_pack_pages, kv_unpack_pages


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_pack(pool: jax.Array, indices: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return kv_pack_pages(pool, indices, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def kv_unpack(
    pool: jax.Array, buf: jax.Array, indices: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return kv_unpack_pages(pool, buf, indices, interpret=interpret)
