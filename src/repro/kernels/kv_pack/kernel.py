"""KV-page gather/scatter — Pallas TPU kernel.

The device-side hot spot of TENT's KV-cache movement: a HiCache-style radix
tree keeps KV pages scattered across the cache pool, but the transfer engine
wants contiguous slices to spray (one-sided writes to absolute offsets need
contiguous source buffers). `kv_pack` gathers an arbitrary page-index list
into a contiguous transfer buffer; `kv_unpack` scatters a received buffer
back into pool pages.

TPU-idiomatic adaptation: the page-index list is a *scalar-prefetch* operand
(pltpu.PrefetchScalarGridSpec), so the DMA engine computes each block's HBM
address from the index array before the grid step runs — the gather happens
in the memory system, not as vector compute. Block = one page
(page_size x kv_dim), which for page_size=16, kv_dim=256 is 8 KiB in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, src_ref, dst_ref):
    del idx_ref  # consumed by the index_map (scalar prefetch)
    dst_ref[...] = src_ref[...]


def kv_pack_pages(
    pool: jax.Array,  # (num_pages, page_size, kv_dim)
    indices: jax.Array,  # (n,) int32 — pages to gather, in slice order
    *,
    interpret: bool = False,
) -> jax.Array:
    """Gather pool[indices] into a contiguous (n, page_size, kv_dim) buffer."""
    n = indices.shape[0]
    _, page, dim = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, page, dim), lambda i, idx: (idx[i], 0, 0))],
        out_specs=pl.BlockSpec((1, page, dim), lambda i, idx: (i, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, page, dim), pool.dtype),
        interpret=interpret,
    )(indices, pool)


def kv_unpack_pages(
    pool: jax.Array,  # (num_pages, page_size, kv_dim) — pool to update
    buf: jax.Array,  # (n, page_size, kv_dim) — received contiguous slices
    indices: jax.Array,  # (n,) int32 — destination pages
    *,
    interpret: bool = False,
) -> jax.Array:
    """Scatter buf rows into pool at `indices` (returns the updated pool).

    Implemented with input/output aliasing so the pool is updated in place
    on TPU (no full-pool copy)."""
    n = indices.shape[0]
    _, page, dim = pool.shape

    def _scatter_kernel(idx_ref, buf_ref, pool_in_ref, pool_out_ref):
        del idx_ref, pool_in_ref
        pool_out_ref[...] = buf_ref[...]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, page, dim), lambda i, idx: (i, 0, 0)),
            pl.BlockSpec((1, page, dim), lambda i, idx: (idx[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, dim), lambda i, idx: (idx[i], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},  # alias pool input -> output
        interpret=interpret,
    )(indices, buf, pool)
