"""Blockwise causal GQA flash attention — Pallas TPU kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv axis is innermost and
sequential, so the fp32 running-max/denominator/accumulator scratch in VMEM
persists across kv steps (the canonical TPU online-softmax pattern).

BlockSpec tiling (per grid step, in VMEM):
  q:  (1, 1, block_q, head_dim)
  k,v:(1, 1, block_k, head_dim)  — kv head = q_head // group_size (GQA)
  o:  (1, 1, block_q, head_dim)
With block_q = block_k = 128 and head_dim <= 128 (all assigned archs), the
working set is ~4 * 128 * 128 * 4B ≈ 256 KiB — comfortably inside the
16 MiB VMEM budget, and every matmul dimension is 128-aligned for the MXU.

Causal + sliding-window masking is applied inside the block; fully-masked
blocks are skipped with pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, nk: int, scale: float,
    causal: bool, window: int, seq_len: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k
    # Skip blocks entirely above the causal diagonal or left of the window.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len  # padded keys never attend
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        l_prev = l_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = corr * l_prev + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0, 0, :, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, K, S, D)
    v: jax.Array,  # (B, K, S, D)
    *,
    causal: bool = True,
    window: int = 0,
    seq_len: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    K = k.shape[1]
    G = H // K
    assert H % K == 0, "q heads must be a multiple of kv heads"
    assert S % block_q == 0 and S % block_k == 0, "caller pads to block multiple"
    nq, nk = S // block_q, S // block_k
    seq_len = S if seq_len is None else seq_len
    scale = D ** -0.5

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q, block_k=block_k, nk=nk, scale=scale,
        causal=causal, window=window, seq_len=seq_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max (lane-replicated)
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denominator
            pltpu.VMEM((block_q, D), jnp.float32),  # fp32 output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
