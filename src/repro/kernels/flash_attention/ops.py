"""Jitted public wrapper: layout handling, padding, backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, K, D)
    v: jax.Array,  # (B, S, K, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, S, H, D = q.shape
    block_q = min(block_q, max(8, 1 << (S - 1).bit_length()))
    block_k = min(block_k, block_q)
    # pad q and kv to a common multiple so q-blocks and kv-blocks tile evenly
    qt = _pad_to(q.transpose(0, 2, 1, 3), block_q, 2)  # (B,H,S',D)
    kt = _pad_to(k.transpose(0, 2, 1, 3), block_q, 2)
    vt = _pad_to(v.transpose(0, 2, 1, 3), block_q, 2)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, seq_len=S,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :, :S].transpose(0, 2, 1, 3)
