"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, K, D)
    v: jax.Array,  # (B, S, K, D)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) / (D ** 0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
