"""tentlint framework core: files, findings, pragmas, fingerprints.

The linter is purely syntactic — it parses every file with `ast` and never
imports the code under analysis, so it runs in milliseconds, works on files
with unmet optional dependencies (jax-less environments), and can never be
perturbed by import-time side effects.

Three layers:

* `FileContext` — one parsed file: source text, AST, and the per-line
  suppression pragmas (`# tentlint: disable=<rule>[,<rule>]` on the flagged
  line, `# tentlint: disable-file=<rule>` anywhere for whole-file opt-out).
* `Project` — the scanned file set plus the classification every rule
  shares: which files count as engine source (`src/repro/` by default) and
  which count as tests. Cross-file rules (twin-drift) resolve names here.
* `Finding` — one diagnostic, carrying a *content fingerprint* (rule +
  file basename + normalized line text + same-line occurrence ordinal) so
  baseline entries survive unrelated line drift but die with the code they
  described.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "FileContext",
    "Finding",
    "Project",
    "Rule",
    "dotted_name",
    "iter_python_files",
]

# Directories never walked: generated caches plus the deliberate-violation
# lint fixtures (they exist to be broken; the fixture tests lint them with
# explicit paths, which bypass the walk entirely).
SKIP_DIR_NAMES = {"__pycache__", ".git", ".hypothesis", ".pytest_cache"}
SKIP_REL_PREFIXES = ("tests/fixtures/",)

_PRAGMA_RE = re.compile(r"#\s*tentlint:\s*(disable|disable-file)=([\w\-, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic. `fingerprint` identifies the finding by content (not
    line number) for the committed baseline; `suppressed`/`baselined` are
    set by the driver, never by rules."""

    rule: str
    path: str  # project-root-relative, posix separators
    line: int
    col: int
    message: str
    snippet: str
    fingerprint: str
    suppressed: bool = False
    baselined: bool = False

    @property
    def active(self) -> bool:
        """True when this finding should fail a gate: neither suppressed by
        a pragma nor accepted into the committed baseline."""
        return not (self.suppressed or self.baselined)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """One parsed source file plus its suppression pragmas."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel)
        self.line_disables: Dict[int, Set[str]] = {}
        self.file_disables: Set[str] = set()
        self._scan_pragmas()

    def _scan_pragmas(self) -> None:
        # Pragmas live in comments; scanning raw lines would also match
        # string literals, so only genuine COMMENT tokens count.
        try:
            tokens = tokenize.generate_tokens(iter(self.lines_iter()).__next__)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _PRAGMA_RE.search(tok.string)
                if not m:
                    continue
                kind = m.group(1)
                ids = {r.strip() for r in m.group(2).split(",") if r.strip()}
                if kind == "disable-file":
                    self.file_disables |= ids
                else:
                    self.line_disables.setdefault(tok.start[0], set()).update(ids)
        except tokenize.TokenError:  # unterminated constructs: best effort
            pass

    def lines_iter(self):
        for ln in self.lines:
            yield ln + "\n"

    def is_suppressed(self, rule_id: str, line: int) -> bool:
        for ids in (self.file_disables, self.line_disables.get(line, ())):
            if rule_id in ids or "all" in ids:
                return True
        return False

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""


class Rule:
    """One invariant. Subclasses implement `check_file` (per-file findings
    as `(line, col, message)` triples) and may implement `finalize` for
    cross-file checks that need the whole `Project`."""

    id: str = "abstract"
    description: str = ""

    def check_file(self, ctx: FileContext,
                   project: "Project") -> Iterable[Tuple[int, int, str]]:
        return ()

    def finalize(self,
                 project: "Project") -> Iterable[Tuple[str, int, int, str]]:
        """Cross-file findings as `(rel_path, line, col, message)`."""
        return ()


class Project:
    """The scanned file set plus shared path classification.

    `src_prefixes` decides which files carry the engine-source invariants
    (wall-clock purity, FMA guards, ordered iteration); the default matches
    this repo's layout and the fixture tests override it to treat a fixture
    directory as its own miniature project.
    """

    def __init__(self, root: Path, files: Sequence[Path], *,
                 src_prefixes: Tuple[str, ...] = ("src/repro/",),
                 test_markers: Tuple[str, ...] = ("tests/",)):
        self.root = Path(root)
        self.src_prefixes = src_prefixes
        self.test_markers = test_markers
        self.contexts: List[FileContext] = []
        self.errors: List[Tuple[str, str]] = []  # (rel, parse error)
        for f in files:
            rel = self._rel(f)
            try:
                text = f.read_text()
                self.contexts.append(FileContext(f, rel, text))
            except (SyntaxError, UnicodeDecodeError) as e:
                self.errors.append((rel, str(e)))

    def _rel(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def is_src(self, rel: str) -> bool:
        return any(rel.startswith(p) or p in ("", "./")
                   for p in self.src_prefixes)

    def is_test(self, rel: str) -> bool:
        return any(rel.startswith(m) or f"/{m}" in rel
                   for m in self.test_markers)

    def context_for(self, rel: str) -> Optional[FileContext]:
        for ctx in self.contexts:
            if ctx.rel == rel:
                return ctx
        return None


def fingerprint(rule_id: str, rel: str, normalized_line: str,
                ordinal: int) -> str:
    base = Path(rel).name
    payload = f"{rule_id}\x00{base}\x00{normalized_line}\x00{ordinal}"
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def make_findings(rule_id: str, ctx: FileContext,
                  raw: Iterable[Tuple[int, int, str]]) -> List[Finding]:
    """Attach suppression flags and content fingerprints to a rule's raw
    `(line, col, message)` output. The ordinal counts earlier findings of
    the same rule on an identical normalized line in the same file, so two
    copies of one bad statement get distinct, stable fingerprints."""
    seen: Dict[str, int] = {}
    out: List[Finding] = []
    for line, col, message in sorted(raw):
        norm = " ".join(ctx.line_text(line).split())
        ordinal = seen.get(norm, 0)
        seen[norm] = ordinal + 1
        out.append(Finding(
            rule=rule_id,
            path=ctx.rel,
            line=line,
            col=col,
            message=message,
            snippet=ctx.line_text(line).strip(),
            fingerprint=fingerprint(rule_id, ctx.rel, norm, ordinal),
            suppressed=ctx.is_suppressed(rule_id, line),
        ))
    return out


def run_rules(project: Project, rules: Sequence[Rule]) -> List[Finding]:
    findings: List[Finding] = []
    for rule in rules:
        for ctx in project.contexts:
            findings.extend(make_findings(
                rule.id, ctx, rule.check_file(ctx, project)))
        for rel, line, col, message in rule.finalize(project):
            ctx = project.context_for(rel)
            if ctx is None:  # finding against a missing file: no pragmas
                findings.append(Finding(
                    rule=rule.id, path=rel, line=line, col=col,
                    message=message, snippet="",
                    fingerprint=fingerprint(rule.id, rel, message, 0)))
            else:
                findings.extend(make_findings(
                    rule.id, ctx, [(line, col, message)]))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_python_files(paths: Sequence[Path], root: Path) -> List[Path]:
    """Expand CLI path arguments. Directories are walked (skipping caches
    and the lint fixtures); explicitly named files are always included, so
    fixture tests can lint deliberate violations directly."""
    out: List[Path] = []
    seen: Set[Path] = set()

    def add(p: Path) -> None:
        rp = p.resolve()
        if rp not in seen:
            seen.add(rp)
            out.append(p)

    for p in paths:
        if p.is_file():
            add(p)
            continue
        for f in sorted(p.rglob("*.py")):
            if any(part in SKIP_DIR_NAMES for part in f.parts):
                continue
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            if any(rel.startswith(pre) for pre in SKIP_REL_PREFIXES):
                continue
            add(f)
    return out


def dotted_name(node: ast.AST) -> Optional[str]:
    """`a.b.c` for Name/Attribute chains, None for anything dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
