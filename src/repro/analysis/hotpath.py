"""The `@hot_path` contract marker.

PR 5's hot-path sweep established the allocation discipline the wave engine
lives by: no per-item closures, no `functools.partial`, no comprehension
churn inside the per-event loops that run once per slice/completion/tick.
The decorator formalizes that sweep as a *contract*: tagging a function
declares "this body is on the per-event timed path", and the
`hot-path-alloc` lint rule (`repro.analysis.rules`) statically enforces the
discipline on every tagged body from then on.

The decorator itself is deliberately zero-cost: it sets one attribute and
returns the function unchanged — no wrapper frame, no signature change, no
import-time side effects — so tagging can never perturb the timed path it
protects (the same zero-cost-when-off bar the flight recorder holds).
"""
from __future__ import annotations

from typing import Callable, TypeVar

__all__ = ["hot_path", "is_hot_path"]

F = TypeVar("F", bound=Callable)

#: Attribute set on tagged functions; tests and tooling may enumerate it.
HOT_PATH_ATTR = "__tent_hot_path__"


def hot_path(fn: F) -> F:
    """Mark `fn` as being on the engine's per-event timed path.

    Purely declarative: the returned object *is* `fn` (identity preserved,
    no wrapper), with `__tent_hot_path__ = True` attached. The static
    `hot-path-alloc` rule keys off the decorator syntactically, so the tag
    works even on modules the linter never imports.
    """
    setattr(fn, HOT_PATH_ATTR, True)
    return fn


def is_hot_path(fn: Callable) -> bool:
    """True when `fn` (or the function behind a bound method) is tagged."""
    return bool(getattr(fn, HOT_PATH_ATTR, False))
