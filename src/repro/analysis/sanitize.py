"""Runtime sanitizer: the dynamic teeth behind no-wall-clock/no-global-rng.

The static rules prove engine *source* clean; this module catches what
statics can't — third-party callbacks, exec'd strings, getattr dispatch —
by monkeypatching the wall-clock functions and global-RNG entry points to
raise while a simulated path is running. Set `REPRO_SANITIZE=1` and every
`ScenarioRunner.run_policy` body executes under the patch; any engine-side
call to `time.time()` or `np.random.rand()` dies loudly with the invariant
it broke.

Scoping is by *caller module*: the stub raises only when the frame that
called it belongs to a `repro.*` module outside `DYNAMIC_ALLOWLIST`.
Library internals (jax, numpy itself, pytest) keep working — jax probes
`time.monotonic` during tracing and that is not our violation to report.

Zero-cost-when-off, same bar as the flight recorder: with the env var
unset, `maybe_sanitized()` returns a nullcontext and no patching happens.
"""
from __future__ import annotations

import contextlib
import os
import random as _py_random
import sys
import time as _time
from typing import Iterator

import numpy as _np

__all__ = [
    "SanitizerError",
    "DYNAMIC_ALLOWLIST",
    "enabled",
    "sanitized",
    "maybe_sanitized",
]


class SanitizerError(RuntimeError):
    """A wall-clock or global-RNG call escaped onto a simulated path."""


#: repro modules allowed to touch the wall clock even under the sanitizer —
#: mirrors the static rule's ALLOWED_FILES (their job is wall timing).
DYNAMIC_ALLOWLIST = frozenset({
    "repro.training.train_loop",
    "repro.launch.dryrun",
})

_ENV_VAR = "REPRO_SANITIZE"

# (module object, attribute name, invariant tag)
_WALL_CLOCK = [
    (_time, name, "no-wall-clock") for name in (
        "time", "time_ns", "perf_counter", "perf_counter_ns",
        "monotonic", "monotonic_ns", "process_time", "process_time_ns",
        "sleep",
    )
]
_NP_GLOBAL_RNG = [
    (_np.random, name, "no-global-rng") for name in (
        "seed", "random", "rand", "randn", "randint", "random_sample",
        "choice", "shuffle", "permutation", "uniform", "normal",
        "standard_normal", "exponential", "poisson", "beta", "gamma",
        "binomial", "bytes", "random_integers",
    ) if hasattr(_np.random, name)
]
_PY_GLOBAL_RNG = [
    (_py_random, name, "no-global-rng") for name in (
        "seed", "random", "uniform", "randint", "randrange", "choice",
        "choices", "shuffle", "sample", "gauss", "normalvariate",
        "expovariate", "betavariate", "gammavariate", "getrandbits",
    )
]

_PATCH_TABLE = _WALL_CLOCK + _NP_GLOBAL_RNG + _PY_GLOBAL_RNG


def enabled() -> bool:
    """True when `REPRO_SANITIZE` is set to a truthy value."""
    return os.environ.get(_ENV_VAR, "").strip().lower() in (
        "1", "true", "yes", "on")


def _caller_module(depth: int = 2) -> str:
    try:
        frame = sys._getframe(depth)
    except ValueError:
        return ""
    return frame.f_globals.get("__name__", "") or ""


def _make_stub(original, qualname: str, invariant: str):
    def stub(*args, **kwargs):
        mod = _caller_module()
        if mod.startswith("repro.") and mod not in DYNAMIC_ALLOWLIST:
            raise SanitizerError(
                f"{invariant}: `{qualname}()` called from simulated-path "
                f"module `{mod}` under REPRO_SANITIZE — simulated time "
                "must come from Fabric.now and randomness from a seeded "
                "Generator (see docs/ANALYSIS.md)")
        return original(*args, **kwargs)

    stub.__tentlint_stub__ = True  # marks an active patch (re-entrancy)
    stub.__wrapped__ = original
    return stub


@contextlib.contextmanager
def sanitized() -> Iterator[None]:
    """Patch wall-clock and global-RNG entry points for the duration of
    the block. Re-entrant: nested blocks see the patch already applied and
    leave it untouched, so the outermost block owns the restore."""
    saved = []
    for mod, name, invariant in _PATCH_TABLE:
        current = getattr(mod, name)
        if getattr(current, "__tentlint_stub__", False):
            continue  # already patched by an enclosing block
        qual = f"{mod.__name__}.{name}"
        saved.append((mod, name, current))
        setattr(mod, name, _make_stub(current, qual, invariant))
    try:
        yield
    finally:
        for mod, name, original in reversed(saved):
            setattr(mod, name, original)


def maybe_sanitized():
    """`sanitized()` when REPRO_SANITIZE is on, else a no-op context.

    The simulated-path entry points (scenario runner policies) wrap their
    bodies in this so production runs pay nothing and sanitizer runs get
    full dynamic enforcement.
    """
    return sanitized() if enabled() else contextlib.nullcontext()
