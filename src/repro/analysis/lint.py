"""tentlint CLI: `python -m repro.analysis.lint [paths...] [options]`.

Exit codes: 0 clean (every finding suppressed or baselined; under
`--strict` also no stale baseline entries and no parse errors), 1 active
findings (or strict-mode staleness), 2 usage errors.

Typical invocations:

    python -m repro.analysis.lint                      # whole tree
    python -m repro.analysis.lint --strict --json out.json   # CI gate
    python -m repro.analysis.lint src/repro/core/engine.py   # one file
    python -m repro.analysis.lint --write-baseline     # accept current debt
    python -m repro.analysis.lint --list-rules
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .baseline import Baseline, apply_baseline
from .core import Finding, Project, iter_python_files, run_rules
from .rules import ALL_RULES, default_rules

__all__ = ["main", "run_lint"]

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples", "tests",
                 "experiments")
DEFAULT_BASELINE = "tentlint_baseline.json"


def find_root(start: Path) -> Path:
    """Walk up to the project root (pyproject.toml / .git marker)."""
    cur = start.resolve()
    for candidate in (cur, *cur.parents):
        if (candidate / "pyproject.toml").exists() or \
                (candidate / ".git").exists():
            return candidate
    return start


def run_lint(root: Path, paths: Sequence[Path], *,
             rules: Optional[Sequence[str]] = None,
             baseline_path: Optional[Path] = None):
    """Programmatic entry: returns (findings, stale_entries, project)."""
    files = iter_python_files(paths, root)
    project = Project(root, files)
    findings = run_rules(project, default_rules(rules))
    baseline = Baseline.load(baseline_path) if baseline_path else Baseline()
    findings, stale = apply_baseline(findings, baseline)
    return findings, stale, project


def _human_report(findings: List[Finding], stale: List[dict],
                  errors, strict: bool, out) -> None:
    active = [f for f in findings if f.active]
    for f in active:
        print(f"{f.path}:{f.line}:{f.col}: [{f.rule}] {f.message}",
              file=out)
        if f.snippet:
            print(f"    {f.snippet}", file=out)
    for rel, err in errors:
        print(f"{rel}: [parse-error] {err}", file=out)
    suppressed = sum(1 for f in findings if f.suppressed)
    baselined = sum(1 for f in findings if f.baselined)
    print(f"tentlint: {len(active)} active, {suppressed} suppressed, "
          f"{baselined} baselined, {len(stale)} stale baseline "
          f"entr{'y' if len(stale) == 1 else 'ies'}, "
          f"{len(errors)} parse error{'' if len(errors) == 1 else 's'}",
          file=out)
    if stale and strict:
        for e in stale:
            print(f"  stale: [{e['rule']}] {e['path']} "
                  f"{e['fingerprint']} ({e.get('reason', '')}) — the "
                  "finding is gone; delete the entry", file=out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="tentlint: enforce the repo's determinism, parity, "
                    "and hot-path invariants statically.")
    parser.add_argument("paths", nargs="*",
                        help=f"files/directories to lint "
                             f"(default: {' '.join(DEFAULT_PATHS)})")
    parser.add_argument("--root", type=Path, default=None,
                        help="project root (default: auto-detect via "
                             "pyproject.toml/.git walk-up)")
    parser.add_argument("--strict", action="store_true",
                        help="also fail on stale baseline entries and "
                             "parse errors (the CI gate)")
    parser.add_argument("--json", type=Path, metavar="FILE",
                        help="write the full machine-readable report")
    parser.add_argument("--baseline", type=Path, default=None,
                        help=f"baseline file (default: "
                             f"<root>/{DEFAULT_BASELINE})")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept all current active findings into the "
                             "baseline (reasons carried forward) and exit")
    parser.add_argument("--rules", type=str, default=None,
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id:16s} {rule.description}")
        return 0

    root = args.root.resolve() if args.root else find_root(Path.cwd())
    baseline_path = args.baseline if args.baseline else \
        root / DEFAULT_BASELINE
    raw_paths = [Path(p) for p in args.paths] if args.paths else \
        [root / p for p in DEFAULT_PATHS if (root / p).exists()]
    rules = [r.strip() for r in args.rules.split(",") if r.strip()] \
        if args.rules else None

    try:
        findings, stale, project = run_lint(
            root, raw_paths, rules=rules, baseline_path=baseline_path)
    except ValueError as e:
        print(f"tentlint: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        old = Baseline.load(baseline_path)
        new = Baseline.from_findings(
            [f for f in findings if not f.suppressed], old)
        new.save(baseline_path)
        print(f"tentlint: wrote {len(new.entries)} baseline entr"
              f"{'y' if len(new.entries) == 1 else 'ies'} to "
              f"{baseline_path}")
        return 0

    if args.json:
        report = {
            "root": str(root),
            "files_scanned": len(project.contexts),
            "findings": [f.to_dict() for f in findings],
            "stale_baseline": stale,
            "parse_errors": [{"path": p, "error": e}
                             for p, e in project.errors],
            "counts": {
                "active": sum(1 for f in findings if f.active),
                "suppressed": sum(1 for f in findings if f.suppressed),
                "baselined": sum(1 for f in findings if f.baselined),
            },
        }
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(report, indent=2) + "\n")

    _human_report(findings, stale, project.errors, args.strict, sys.stdout)

    active = any(f.active for f in findings)
    strict_fail = args.strict and (stale or project.errors)
    return 1 if (active or strict_fail) else 0


if __name__ == "__main__":
    raise SystemExit(main())
