"""Committed-baseline support for tentlint.

The baseline is a reviewed, committed JSON file that accepts specific
findings by *content fingerprint* rather than line number, so unrelated
edits above a baselined line don't invalidate the entry, while changing
the flagged code itself does (the fingerprint hashes the normalized line
text). Every entry carries a human `reason` — a baseline is a justified
debt record, not a mute button.

Format (version 1):

    {
      "version": 1,
      "findings": [
        {"rule": "...", "path": "...", "fingerprint": "...",
         "reason": "why this is accepted"}
      ]
    }

`--write-baseline` regenerates the file from the current active findings
(preserving reasons for fingerprints that survive); `--strict` fails on
stale entries so the debt record can only shrink by being paid down.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Sequence, Set, Tuple

from .core import Finding

__all__ = ["Baseline", "apply_baseline"]

_VERSION = 1


class Baseline:
    """The committed set of accepted findings, keyed by fingerprint."""

    def __init__(self, entries: Sequence[dict] = ()):  # validated dicts
        self.entries: List[dict] = list(entries)
        self.by_fp: Dict[str, dict] = {e["fingerprint"]: e
                                       for e in self.entries}

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        if data.get("version") != _VERSION:
            raise ValueError(
                f"{path}: unsupported baseline version {data.get('version')!r}"
                f" (expected {_VERSION})")
        entries = []
        for e in data.get("findings", []):
            missing = {"rule", "path", "fingerprint"} - set(e)
            if missing:
                raise ValueError(
                    f"{path}: baseline entry missing {sorted(missing)}: {e}")
            entries.append({
                "rule": e["rule"],
                "path": e["path"],
                "fingerprint": e["fingerprint"],
                "reason": e.get("reason", ""),
            })
        return cls(entries)

    def save(self, path: Path) -> None:
        payload = {
            "version": _VERSION,
            "findings": sorted(
                self.entries,
                key=lambda e: (e["path"], e["rule"], e["fingerprint"])),
        }
        path.write_text(json.dumps(payload, indent=2) + "\n")

    @classmethod
    def from_findings(cls, findings: Sequence[Finding],
                      old: "Baseline" = None,
                      default_reason: str = "accepted pre-existing finding"
                      ) -> "Baseline":
        """Build a baseline accepting every currently-active finding,
        carrying reasons forward from `old` where fingerprints survive."""
        entries = []
        seen: Set[str] = set()
        for f in findings:
            if f.suppressed or f.fingerprint in seen:
                continue
            seen.add(f.fingerprint)
            carried = old.by_fp.get(f.fingerprint) if old else None
            entries.append({
                "rule": f.rule,
                "path": f.path,
                "fingerprint": f.fingerprint,
                "reason": carried["reason"] if carried else default_reason,
            })
        return cls(entries)


def apply_baseline(findings: Sequence[Finding],
                   baseline: Baseline) -> Tuple[List[Finding], List[dict]]:
    """Mark findings whose fingerprints the baseline accepts; return the
    updated findings plus the *stale* baseline entries (accepted
    fingerprints that no longer occur — debt that has been paid and should
    be deleted from the file)."""
    matched: Set[str] = set()
    out: List[Finding] = []
    for f in findings:
        if f.fingerprint in baseline.by_fp and not f.suppressed:
            matched.add(f.fingerprint)
            out.append(Finding(**{**f.to_dict(), "baselined": True}))
        else:
            out.append(f)
    stale = [e for e in baseline.entries if e["fingerprint"] not in matched]
    return out, stale
