"""The repo-specific rule set, distilled from hazards PRs 4-9 actually hit.

Each rule documents the invariant it guards and the PR that motivated it;
`docs/ANALYSIS.md` is the narrative version. Rules are deliberately
high-precision: they key on the syntactic shapes the hazards take in this
codebase rather than trying to be a general-purpose linter, and anything
they cannot prove is left to the parity/property tests that remain the
dynamic backstop.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .core import FileContext, Project, Rule, dotted_name

__all__ = ["ALL_RULES", "RULES_BY_ID", "default_rules"]


# ---------------------------------------------------------------------------
# import-alias resolution (shared by the wall-clock and RNG rules)
# ---------------------------------------------------------------------------

def _import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to the dotted origin they were imported as:
    `import numpy as np` -> {"np": "numpy"}, `from time import perf_counter
    as pc` -> {"pc": "time.perf_counter"}. Only module-level imports are
    tracked — that is where this repo imports time/numpy/random."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _resolve(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted name of a call target with the leading alias expanded."""
    name = dotted_name(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = aliases.get(head)
    if origin is None:
        return name
    return f"{origin}.{rest}" if rest else origin


# ---------------------------------------------------------------------------
# no-wall-clock
# ---------------------------------------------------------------------------

class NoWallClock(Rule):
    """Virtual-clock purity (every PR; the sub-50 ms healing claims).

    All simulated time flows from `Fabric.now`; a single `time.time()` or
    `datetime.now()` on a simulated path makes reports machine-dependent
    and kills byte-identical reproduction. Forbidden throughout engine
    source (`src/repro/`), with an explicit allowlist for the modules whose
    *job* is wall-clock measurement. Benchmarks/examples/tests are exempt
    by scope: timing real walls is what a benchmark driver does.
    """

    id = "no-wall-clock"
    description = ("time.time/perf_counter/monotonic/sleep/datetime.now "
                   "forbidden in engine source (virtual-clock purity)")

    FORBIDDEN = {
        "time.time", "time.time_ns",
        "time.perf_counter", "time.perf_counter_ns",
        "time.monotonic", "time.monotonic_ns",
        "time.process_time", "time.process_time_ns",
        "time.sleep",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "datetime.date.today",
    }
    # suffix matches catch `from datetime import datetime; datetime.now()`
    FORBIDDEN_SUFFIXES = ("datetime.now", "datetime.utcnow", "date.today")

    # Modules whose purpose is wall-clock measurement (never on a simulated
    # path): the real-training step timer and the XLA compile-time probe.
    ALLOWED_FILES = {
        "src/repro/training/train_loop.py",
        "src/repro/launch/dryrun.py",
    }

    def check_file(self, ctx: FileContext, project: Project):
        if not project.is_src(ctx.rel) or project.is_test(ctx.rel):
            return
        if ctx.rel in self.ALLOWED_FILES:
            return
        aliases = _import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _resolve(node.func, aliases)
            if name is None:
                continue
            if name in self.FORBIDDEN or name.endswith(self.FORBIDDEN_SUFFIXES):
                yield (node.lineno, node.col_offset,
                       f"wall-clock call `{name}()` in engine source — "
                       "simulated paths must read the fabric's virtual "
                       "clock (Fabric.now)")


# ---------------------------------------------------------------------------
# no-global-rng
# ---------------------------------------------------------------------------

class NoGlobalRng(Rule):
    """Seeded-randomness discipline (PR 8's vmapped-lane == single-seed
    exactness; every determinism pin in the suite).

    Randomness must flow through an explicitly seeded `np.random.Generator`
    (or `jax.random` key): the numpy/stdlib *global* RNGs are hidden shared
    state that any import can perturb. Seeding a generator from `id()`,
    `hash()` or the wall clock is the same hazard wearing a disguise —
    `id()` changes run to run, `hash(str)` changes with PYTHONHASHSEED.
    Applies to the whole tree: an unseeded benchmark or test is exactly as
    unreproducible as an unseeded engine.
    """

    id = "no-global-rng"
    description = ("module-level np.random.* / bare random.* and "
                   "id()/hash()/wall-clock seeds forbidden; use seeded "
                   "np.random.Generator or jax.random keys")

    NP_ALLOWED = {
        "default_rng", "Generator", "SeedSequence", "BitGenerator",
        "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
    }
    PY_RANDOM_ALLOWED = {"Random"}  # random.Random(seed) is explicit state
    # constructors whose seed argument must be deterministic
    SEEDED_CTORS = ("default_rng", "SeedSequence", "Random", "RandomState",
                    "PRNGKey", "key", "seed", "fold_in")
    BAD_SEED_CALLS = {"id", "hash", "time.time", "time.time_ns",
                      "time.perf_counter", "time.monotonic", "uuid.uuid4"}

    def check_file(self, ctx: FileContext, project: Project):
        aliases = _import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _resolve(node.func, aliases)
            if name is None:
                continue
            yield from self._check_global(node, name)
            yield from self._check_seed_args(node, name, aliases)

    def _check_global(self, node: ast.Call, name: str):
        if name.startswith("numpy.random."):
            tail = name[len("numpy.random."):]
            if "." not in tail and tail not in self.NP_ALLOWED:
                yield (node.lineno, node.col_offset,
                       f"global-state RNG `{name}()` — draw from a seeded "
                       "np.random.default_rng(seed) Generator instead")
        elif name.startswith("random."):
            tail = name[len("random."):]
            if "." not in tail and tail not in self.PY_RANDOM_ALLOWED:
                yield (node.lineno, node.col_offset,
                       f"stdlib global RNG `{name}()` — use a seeded "
                       "random.Random(seed) or np.random.default_rng(seed)")

    def _check_seed_args(self, node: ast.Call, name: str, aliases):
        if not name.endswith(self.SEEDED_CTORS):
            return
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            for sub in ast.walk(arg):
                if not isinstance(sub, ast.Call):
                    continue
                sub_name = _resolve(sub.func, aliases)
                if sub_name in self.BAD_SEED_CALLS:
                    yield (sub.lineno, sub.col_offset,
                           f"nondeterministic seed: `{sub_name}()` feeding "
                           f"`{name.rsplit('.', 1)[-1]}(...)` changes run "
                           "to run — derive seeds from the spec/config")


# ---------------------------------------------------------------------------
# fma-hazard
# ---------------------------------------------------------------------------

class FmaHazard(Rule):
    """XLA FMA-contraction defense (PR 8's key numerics discovery).

    Inside a compiled `lax.scan` body (or a jitted kernel), a multiply
    whose result feeds an add/sub gets contracted into a single-rounded
    fma — one ulp off the numpy twin, and `optimization_barrier` does NOT
    stop it. The PR 8 idiom routes every such product through a division
    the compiler cannot fold (`(u*v) / one` with a traced always-1.0
    divisor, or an algebraically equivalent `x / (1/s)` reshuffle): a
    division result feeding an add is not a contraction candidate.

    The rule flags `a*b + c` / `c - a*b` where the product is a *direct*
    operand of the add/sub, inside functions that are scanned/jitted:
    defs passed to `lax.scan`/`lax.map`/`while_loop`/`fori_loop`, defs
    decorated with `jit`, and everything nested inside them. Products
    already wrapped in a division pass untouched; pure-integer products
    (shape/index arithmetic) are skipped.
    """

    id = "fma-hazard"
    description = ("unguarded `a*b + c` inside lax.scan/jit bodies — route "
                   "the product through a division (PR 8 idiom) to block "
                   "fma contraction")

    SCAN_TAILS = ("lax.scan", "lax.map", "lax.while_loop", "lax.fori_loop",
                  "lax.cond", "lax.associative_scan")

    def check_file(self, ctx: FileContext, project: Project):
        if not project.is_src(ctx.rel) or project.is_test(ctx.rel):
            return
        aliases = _import_aliases(ctx.tree)
        compiled: List[ast.AST] = []

        # defs by name per enclosing scope, to resolve `lax.scan(step, ...)`
        for scope in ast.walk(ctx.tree):
            if not isinstance(scope, (ast.Module, ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            local_defs = {n.name: n for n in ast.iter_child_nodes(scope)
                          if isinstance(n, ast.FunctionDef)}
            for node in ast.walk(scope):
                if not isinstance(node, ast.Call):
                    continue
                name = _resolve(node.func, aliases) or ""
                if not name.endswith(self.SCAN_TAILS):
                    continue
                for arg in node.args:
                    if isinstance(arg, ast.Name) and arg.id in local_defs:
                        compiled.append(local_defs[arg.id])
                    elif isinstance(arg, ast.Lambda):
                        compiled.append(arg)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef) and self._is_jitted(node,
                                                                    aliases):
                compiled.append(node)

        seen: Set[int] = set()
        for body in compiled:
            for expr in ast.walk(body):
                if id(expr) in seen:
                    continue
                seen.add(id(expr))
                if (isinstance(expr, ast.BinOp)
                        and isinstance(expr.op, (ast.Add, ast.Sub))):
                    for side in (expr.left, expr.right):
                        if (isinstance(side, ast.BinOp)
                                and isinstance(side.op, ast.Mult)
                                and not self._integer_product(side)):
                            yield (side.lineno, side.col_offset,
                                   "product feeding an add/sub inside a "
                                   "compiled scan/jit body invites fma "
                                   "contraction — divide the product by a "
                                   "traced 1.0 (see scheduler.py's `one` "
                                   "idiom) or restructure as `x / (1/s)`")

    @staticmethod
    def _is_jitted(node: ast.FunctionDef, aliases) -> bool:
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = _resolve(target, aliases) or ""
            if name.endswith((".jit", "functools.partial")) or name == "jit":
                if isinstance(dec, ast.Call) and name.endswith("partial"):
                    inner = dec.args[0] if dec.args else None
                    iname = _resolve(inner, aliases) if inner is not None \
                        else None
                    if not (iname or "").endswith("jit"):
                        continue
                return True
        return False

    @staticmethod
    def _integer_product(node: ast.BinOp) -> bool:
        return all(isinstance(s, ast.Constant) and isinstance(s.value, int)
                   for s in (node.left, node.right))


# ---------------------------------------------------------------------------
# unordered-iteration
# ---------------------------------------------------------------------------

class UnorderedIteration(Rule):
    """Ordering-stable iteration (the byte-identical `ScenarioReport` pins
    across the wave/jit/calendar toggles).

    Python `set` iteration order depends on element hashes — for strings,
    on PYTHONHASHSEED — so a set iterated into scheduling or report
    building makes whole runs irreproducible. (`dict` is *not* flagged:
    CPython dict iteration is insertion-ordered and deterministic, which
    the engine exploits deliberately.) The rule flags iteration contexts —
    for/comprehensions and order-materializing calls (`list`, `tuple`,
    `enumerate`, `iter`) — whose iterable is syntactically a set: a set
    literal/comprehension, `set(...)`/`frozenset(...)`, a set-operator
    expression, or a local name only ever assigned such values. Wrapping
    in `sorted(...)` (or reducing with min/max/sum/len/any/all) is the
    fix, and passes automatically because the iterable is then the
    `sorted` call, not the set.
    """

    id = "unordered-iter"
    description = ("iterating a set in engine source — hash order is not "
                   "deterministic; wrap in sorted(...) or use a "
                   "list/dict")

    MATERIALIZERS = {"list", "tuple", "enumerate", "iter"}

    def check_file(self, ctx: FileContext, project: Project):
        if not project.is_src(ctx.rel) or project.is_test(ctx.rel):
            return
        for scope in ast.walk(ctx.tree):
            if not isinstance(scope, (ast.Module, ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            set_locals = self._set_locals(scope)
            for node in ast.iter_child_nodes(scope):
                yield from self._check_scope_body(node, set_locals)

    def _check_scope_body(self, node: ast.AST, set_locals: Set[str]):
        """Walk one scope without descending into nested function scopes
        (they get their own `set_locals`)."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        iterables: List[ast.AST] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iterables.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iterables.extend(g.iter for g in node.generators)
        elif isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in self.MATERIALIZERS and node.args:
                iterables.append(node.args[0])
        for it in iterables:
            if self._is_set_expr(it, set_locals):
                yield (it.lineno, it.col_offset,
                       "iteration over a set — order follows element "
                       "hashes (PYTHONHASHSEED-dependent for strings); "
                       "wrap in sorted(...) to pin it")
        for child in ast.iter_child_nodes(node):
            yield from self._check_scope_body(child, set_locals)

    def _set_locals(self, scope: ast.AST) -> Set[str]:
        """Local names assigned *only* syntactic-set values in this scope."""
        assigned: Dict[str, List[bool]] = {}
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            else:
                continue
            value = getattr(node, "value", None)
            if value is None:
                continue
            for t in targets:
                if isinstance(t, ast.Name):
                    assigned.setdefault(t.id, []).append(
                        self._is_set_expr(value, set()))
        return {name for name, kinds in assigned.items() if all(kinds)}

    def _is_set_expr(self, node: ast.AST, set_locals: Set[str]) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in ("set", "frozenset"):
                return True
            # s.union(t) / s.intersection(t) / ... on a syntactic set
            if isinstance(node.func, ast.Attribute) and node.func.attr in (
                    "union", "intersection", "difference",
                    "symmetric_difference", "copy"):
                return self._is_set_expr(node.func.value, set_locals)
            return False
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitAnd, ast.BitOr, ast.BitXor, ast.Sub)):
            return (self._is_set_expr(node.left, set_locals)
                    or self._is_set_expr(node.right, set_locals))
        if isinstance(node, ast.Name):
            return node.id in set_locals
        return False


# ---------------------------------------------------------------------------
# hot-path-alloc
# ---------------------------------------------------------------------------

class HotPathAlloc(Rule):
    """The PR 5 allocation discipline as a decorator-driven contract.

    Functions tagged `@hot_path` (repro.analysis.hotpath) run once per
    slice/completion/tick; PR 4-5 earned their 3-6x by removing per-item
    closures, `functools.partial` wrappers, and comprehension churn from
    exactly these bodies. The rule keeps them out: inside a tagged
    function it flags lambdas/nested defs and comprehensions *inside
    loops* (per-iteration allocation), and any `functools.partial` call
    (the per-op closure PR 5 removed from the fabric heap). One-time setup
    allocations before the loop are fine and not flagged.
    """

    id = "hot-path-alloc"
    description = ("per-iteration closures/comprehensions or "
                   "functools.partial inside an @hot_path body")

    def check_file(self, ctx: FileContext, project: Project):
        aliases = _import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and self._tagged(node):
                yield from self._check_body(node, aliases, loop_depth=0,
                                            root=True)

    @staticmethod
    def _tagged(node: ast.AST) -> bool:
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = dotted_name(target) or ""
            if name == "hot_path" or name.endswith(".hot_path"):
                return True
        return False

    def _check_body(self, node: ast.AST, aliases, loop_depth: int,
                    root: bool = False):
        in_loop = loop_depth > 0
        if not root:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                if in_loop:
                    kind = "lambda" if isinstance(node, ast.Lambda) \
                        else f"nested def `{node.name}`"
                    yield (node.lineno, node.col_offset,
                           f"{kind} created inside a loop on a @hot_path "
                           "body — one closure per iteration; hoist it or "
                           "use a shared tagged callback (PR 5 idiom)")
                return  # nested scopes are their own (untagged) world
            if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)) and in_loop:
                yield (node.lineno, node.col_offset,
                       "comprehension inside a loop on a @hot_path body — "
                       "per-iteration list churn; hoist or write into a "
                       "preallocated buffer")
                return
            if isinstance(node, ast.Call):
                name = _resolve(node.func, aliases) or ""
                if name == "partial" or name.endswith("functools.partial"):
                    yield (node.lineno, node.col_offset,
                           "functools.partial on a @hot_path body — "
                           "allocates a wrapper per call; use a shared "
                           "tagged callback instead")
        next_depth = loop_depth + (1 if isinstance(
            node, (ast.For, ast.AsyncFor, ast.While)) else 0)
        for child in ast.iter_child_nodes(node):
            yield from self._check_body(child, aliases, next_depth)


# ---------------------------------------------------------------------------
# twin-drift
# ---------------------------------------------------------------------------

class TwinDrift(Rule):
    """Kernel-twin discipline (the bit-parity contract behind every
    `*_jnp` kernel since PR 4).

    Every public module-level `*_jnp` kernel in engine source must have a
    registered numpy twin and a parity test referencing both, or the
    jax/numpy pair silently drifts apart the first time one side changes.
    Registration is the defining module's `__numpy_twins__` dict:

        __numpy_twins__ = {
            "tent_choose_wave_jnp": "tent_choose_wave",        # same module
            "x_jnp": "SomeClass.method",                        # method twin
            "y_jnp": ["target", "why the signatures differ"],  # waiver
        }

    Unregistered kernels default to the strip-`_jnp` convention. The rule
    checks (1) the twin def exists somewhere in the scanned engine source,
    (2) parameter names match exactly (ignoring a leading `self`) unless
    the registry entry carries a signature waiver string, and (3) at least
    one test file mentions both the kernel and its twin's terminal name.
    """

    id = "twin-drift"
    description = ("*_jnp kernel without a registered numpy twin, with a "
                   "drifted signature, or without a parity test "
                   "referencing both")

    def finalize(self, project: Project):
        defs = self._collect_defs(project)
        test_texts = [ctx.text for ctx in project.contexts
                      if project.is_test(ctx.rel)]
        for ctx in project.contexts:
            if not project.is_src(ctx.rel) or project.is_test(ctx.rel):
                continue
            registry = self._registry(ctx.tree)
            for node in ast.iter_child_nodes(ctx.tree):
                if not isinstance(node, ast.FunctionDef):
                    continue
                if not node.name.endswith("_jnp") or \
                        node.name.startswith("_"):
                    continue
                if ctx.is_suppressed(self.id, node.lineno):
                    # still emitted (suppression is handled downstream);
                    # no extra work needed here
                    pass
                yield from self._check_kernel(
                    ctx, node, registry, defs, test_texts)

    def _check_kernel(self, ctx: FileContext, node: ast.FunctionDef,
                      registry: Dict[str, object], defs, test_texts):
        entry = registry.get(node.name, node.name[:-len("_jnp")])
        waiver = None
        if isinstance(entry, (list, tuple)):
            target, waiver = entry[0], (entry[1] if len(entry) > 1 else "")
        else:
            target = entry
        twin = defs.get(target)
        if twin is None:
            yield (ctx.rel, node.lineno, node.col_offset,
                   f"`{node.name}` has no numpy twin: no def `{target}` in "
                   "engine source — add the twin or register the real one "
                   "in __numpy_twins__")
            return
        twin_node, twin_rel = twin
        if waiver is None:
            jnp_params = self._params(node)
            twin_params = self._params(twin_node, drop_self=True)
            if jnp_params != twin_params:
                yield (ctx.rel, node.lineno, node.col_offset,
                       f"`{node.name}` signature drifted from twin "
                       f"`{target}` ({twin_rel}): {jnp_params} != "
                       f"{twin_params} — fix the drift or register a "
                       "signature waiver in __numpy_twins__")
        terminal = target.rsplit(".", 1)[-1]
        if not any(node.name in text and terminal in text
                   for text in test_texts):
            yield (ctx.rel, node.lineno, node.col_offset,
                   f"no parity test references both `{node.name}` and its "
                   f"twin `{terminal}` — add one to the test tier")

    @staticmethod
    def _params(node: ast.FunctionDef, drop_self: bool = False) -> Tuple:
        args = node.args
        names = [a.arg for a in args.posonlyargs + args.args
                 + args.kwonlyargs]
        if drop_self and names and names[0] in ("self", "cls"):
            names = names[1:]
        return tuple(names)

    @staticmethod
    def _registry(tree: ast.Module) -> Dict[str, object]:
        for node in ast.iter_child_nodes(tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == "__numpy_twins__":
                        try:
                            val = ast.literal_eval(node.value)
                        except ValueError:
                            return {}
                        return val if isinstance(val, dict) else {}
        return {}

    @staticmethod
    def _collect_defs(project: Project):
        """`name` / `Class.method` -> (def node, rel path) over engine
        source. First definition wins; collisions are fine because the rule
        only checks existence + parameter names."""
        out: Dict[str, Tuple[ast.FunctionDef, str]] = {}
        for ctx in project.contexts:
            if not project.is_src(ctx.rel) or project.is_test(ctx.rel):
                continue
            for node in ast.iter_child_nodes(ctx.tree):
                if isinstance(node, ast.FunctionDef):
                    out.setdefault(node.name, (node, ctx.rel))
                elif isinstance(node, ast.ClassDef):
                    for sub in ast.iter_child_nodes(node):
                        if isinstance(sub, ast.FunctionDef):
                            out.setdefault(
                                f"{node.name}.{sub.name}", (sub, ctx.rel))
        return out


ALL_RULES: Sequence[Rule] = (
    NoWallClock(),
    NoGlobalRng(),
    FmaHazard(),
    UnorderedIteration(),
    HotPathAlloc(),
    TwinDrift(),
)

RULES_BY_ID: Dict[str, Rule] = {r.id: r for r in ALL_RULES}


def default_rules(only: Optional[Iterable[str]] = None) -> List[Rule]:
    if only is None:
        return list(ALL_RULES)
    unknown = set(only) - set(RULES_BY_ID)
    if unknown:
        raise ValueError(
            f"unknown rule id(s) {sorted(unknown)}; "
            f"have {sorted(RULES_BY_ID)}")
    return [RULES_BY_ID[r] for r in only]
