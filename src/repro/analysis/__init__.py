"""tentlint: repo-native static analysis + runtime sanitizer.

This package only eagerly exposes the `@hot_path` marker (imported by hot
engine modules, so it must stay dependency-free and instant); the linter
(`repro.analysis.lint`), rule set (`repro.analysis.rules`), and runtime
sanitizer (`repro.analysis.sanitize`) are imported on demand.
"""
from .hotpath import hot_path, is_hot_path

__all__ = ["hot_path", "is_hot_path"]
