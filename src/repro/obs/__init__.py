"""Observability layer: flight recorder, decision provenance, metrics.

Zero-cost when off: engines/fabrics/clusters hold `_rec = None` until a
`FlightRecorder` is attached via `attach_recorder`, and every record site
is a single `is not None` guard per *batch* (never per slice). See
docs/OBSERVABILITY.md for the event schema and the explain-CLI walkthrough.
"""
from . import events
from .metrics import Counter, Histogram, MetricsRegistry
from .recorder import FlightRecorder
from .trace import export_chrome_trace, to_json, validate_trace

__all__ = [
    "Counter",
    "FlightRecorder",
    "Histogram",
    "MetricsRegistry",
    "events",
    "export_chrome_trace",
    "to_json",
    "validate_trace",
]
