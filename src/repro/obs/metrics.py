"""Metrics registry: counters, gauges, and histograms on the virtual clock.

This replaces the ad-hoc counter plumbing between engine, cluster, and
scenario runner: instead of each runner branch hand-assembling an `extra`
dict from engine attributes, subsystems *register* their metrics once
(`TentEngine.register_metrics`, `TentCluster.register_metrics`) and the
runner calls `collect()` — one code path for all three workload kinds.

Design constraints, in order:

- **Zero hot-path cost.** Engines keep their plain integer attributes
  (`self.waves += 1` stays a bare int add); the registry reads them lazily
  through gauge callables at `collect()` time. Nothing here runs while the
  simulation is stepping.
- **Deterministic order.** `collect()` returns keys in registration order
  (gauge groups expand in their producer's dict order), so reports built
  from the registry are byte-identical to hand-built dicts.
- **Virtual-clock timestamps.** The registry can hold a clock callable
  (e.g. `lambda: fabric.now`); `timestamped()` pairs a collection with the
  virtual time it was taken, and histogram observations may carry one.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class Counter:
    """A monotonically increasing value (explicit `inc`, not sampled)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Value distribution; observations optionally timestamped."""

    __slots__ = ("name", "_values", "_ts")

    def __init__(self, name: str):
        self.name = name
        self._values: List[float] = []
        self._ts: List[float] = []

    def observe(self, value: float, ts: Optional[float] = None) -> None:
        self._values.append(float(value))
        if ts is not None:
            self._ts.append(float(ts))

    @property
    def count(self) -> int:
        return len(self._values)

    def summary(self) -> Dict[str, float]:
        if not self._values:
            return {f"{self.name}_count": 0.0}
        arr = np.asarray(self._values)
        return {
            f"{self.name}_count": float(arr.size),
            f"{self.name}_mean": float(arr.mean()),
            f"{self.name}_p50": float(np.percentile(arr, 50)),
            f"{self.name}_p99": float(np.percentile(arr, 99)),
        }


class MetricsRegistry:
    """Ordered registry; `collect()` flattens everything to name -> float."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._clock = clock
        # (kind, producer) in registration order; kinds: counter | gauge |
        # group | histogram
        self._entries: List[Tuple[str, object]] = []
        self._counters: Dict[str, Counter] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
            self._entries.append(("counter", c))
        return c

    def gauge(self, name: str, fn: Callable[[], float]) -> None:
        self._entries.append(("gauge", (name, fn)))

    def gauge_group(self, fn: Callable[[], Dict[str, float]]) -> None:
        """A callable producing an ordered dict of name -> value. Lets one
        producer emit several related gauges from a single snapshot (the
        cluster reads its engine-summed counters once, not once per key)."""
        self._entries.append(("group", fn))

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name)
            self._entries.append(("histogram", h))
        return h

    def collect(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for kind, entry in self._entries:
            if kind == "counter":
                out[entry.name] = float(entry.value)
            elif kind == "gauge":
                name, fn = entry
                out[name] = float(fn())
            elif kind == "group":
                for name, value in entry().items():
                    out[name] = float(value)
            else:  # histogram
                out.update(entry.summary())
        return out

    def timestamped(self) -> Tuple[float, Dict[str, float]]:
        now = float(self._clock()) if self._clock is not None else 0.0
        return now, self.collect()
