"""Chrome-trace / Perfetto JSON exporter for the flight recorder.

`export_chrome_trace` turns a `FlightRecorder` into the Trace Event Format
dict that both `chrome://tracing` and https://ui.perfetto.dev load directly;
`to_json` serializes it canonically (sorted keys, compact separators,
trailing newline) so that identical recordings produce byte-identical files
— the property the trace-determinism tests pin.

Layout: each engine is a Perfetto *process* (the fabric is pid 1; engines
get pids in first-appearance order), and event categories are fixed
*threads* within it:

    tid 1  slices     completed slice spans (scheduled -> drained)
    tid 2  scheduler  wave picks, scalar posts/reroutes, substitutions
    tid 3  batches    declared intents, application batch done/fail
    tid 4  control    exclusions, readmissions, link faults, gossip, churn
    tid 5  serving    request phase spans (admit/fetch/prefill/handoff/decode)

Virtual-clock seconds become trace microseconds (x 1e6). Spans are "X"
complete events; point events are "i" instants (thread scope); a final "C"
counter sample carries a metrics collection when one is supplied.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from . import events as EV

_TID_SLICES = 1
_TID_SCHED = 2
_TID_BATCH = 3
_TID_CONTROL = 4
_TID_SERVING = 5

_TID_NAMES = {
    _TID_SLICES: "slices",
    _TID_SCHED: "scheduler",
    _TID_BATCH: "batches",
    _TID_CONTROL: "control",
    _TID_SERVING: "serving",
}

_FABRIC_PID = 1


def export_chrome_trace(recorder, metrics: Optional[Dict[str, float]] = None
                        ) -> dict:
    """Build a Trace Event Format document from a recorder's events."""
    pids: Dict[str, int] = {"fabric": _FABRIC_PID}

    def pid_for(name: str) -> int:
        p = pids.get(name)
        if p is None:
            p = pids[name] = len(pids) + 1
        return p

    body: List[dict] = []
    last_us = 0.0
    n_events = 0
    for ts, kind, pl in recorder.events():
        n_events += 1
        us = float(ts) * 1e6
        if us > last_us:
            last_us = us
        if kind == EV.COMPLETE:
            pid = pid_for(pl["engine"])
            for sid, link, sched, ln in zip(pl["slices"], pl["links"],
                                            pl["scheduled"], pl["lengths"]):
                t0 = float(sched) * 1e6
                body.append({"ph": "X", "pid": pid, "tid": _TID_SLICES,
                             "ts": t0, "dur": max(us - t0, 0.0),
                             "name": f"slice {int(sid)}", "cat": "slice",
                             "args": {"link": int(link), "bytes": int(ln)}})
        elif kind == EV.WAVE:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_SCHED, us,
                                 f"wave n={len(pl['slices'])}", "wave",
                                 {"slices": len(pl["slices"]),
                                  "rr": int(pl["inputs"]["rr"])}))
        elif kind == EV.POST:
            pid = pid_for(pl["engine"])
            name = "reroute" if pl["attempt"] > 0 else "post"
            body.append(_instant(pid, _TID_SCHED, us, name, "post",
                                 {"slice": int(pl["slice"]),
                                  "link": int(pl["link"]),
                                  "hop": int(pl["hop"]),
                                  "attempt": int(pl["attempt"])}))
        elif kind == EV.FAIL:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_SCHED, us, "slice-fail", "fail",
                                 {"slice": int(pl["slice"]),
                                  "link": int(pl["link"]),
                                  "attempt": int(pl["attempt"])}))
        elif kind == EV.SUBSTITUTE:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_SCHED, us, "substitute-backend",
                                 "substitute",
                                 {"slice": int(pl["slice"]),
                                  "batch": int(pl["batch"])}))
        elif kind == EV.INTENT:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_BATCH, us,
                                 f"intent batch {int(pl['batch'])}", "intent",
                                 {"batch": int(pl["batch"]),
                                  "transfers": int(pl["transfers"]),
                                  "slices": int(pl["slices"]),
                                  "bytes": int(pl["bytes"])}))
        elif kind == EV.BATCH_DONE:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_BATCH, us,
                                 f"batch {int(pl['batch'])} done",
                                 "batch_done",
                                 {"batch": int(pl["batch"]),
                                  "bytes": int(pl["bytes"])}))
        elif kind == EV.BATCH_FAIL:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_BATCH, us,
                                 f"batch {int(pl['batch'])} FAILED",
                                 "batch_fail",
                                 {"batch": int(pl["batch"]),
                                  "error": str(pl["error"])}))
        elif kind == EV.EXCLUDE:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_CONTROL, us,
                                 f"exclude link {int(pl['link'])}", "health",
                                 {"link": int(pl["link"]),
                                  "explicit": bool(pl["explicit"])}))
        elif kind == EV.READMIT:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_CONTROL, us,
                                 f"readmit link {int(pl['link'])}", "health",
                                 {"link": int(pl["link"]),
                                  "verified": bool(pl["verified"])}))
        elif kind == EV.LINK_FAIL:
            body.append(_instant(_FABRIC_PID, _TID_CONTROL, us,
                                 f"link {int(pl['link'])} FAIL", "fault",
                                 {"link": int(pl["link"]),
                                  "until": float(pl["until"])}))
        elif kind == EV.DEGRADE:
            body.append(_instant(_FABRIC_PID, _TID_CONTROL, us,
                                 f"link {int(pl['link'])} degrade", "fault",
                                 {"link": int(pl["link"]),
                                  "until": float(pl["until"]),
                                  "factor": float(pl["factor"])}))
        elif kind == EV.RUMOR_SENT:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_CONTROL, us, "rumor-send",
                                 "gossip",
                                 {"link": int(pl["link"]),
                                  "version": int(pl["version"]),
                                  "exclude": bool(pl["exclude"]),
                                  "peers": int(pl["peers"])}))
        elif kind == EV.RUMOR_RECV:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_CONTROL, us, "rumor-apply",
                                 "gossip",
                                 {"link": int(pl["link"]),
                                  "version": int(pl["version"]),
                                  "exclude": bool(pl["exclude"])}))
        elif kind == EV.ANTI_ENTROPY:
            body.append(_instant(_FABRIC_PID, _TID_CONTROL, us,
                                 "anti-entropy", "gossip",
                                 {"members": int(pl["members"])}))
        elif kind == EV.ENGINE_JOIN:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_CONTROL, us, "join", "churn", {}))
        elif kind == EV.ENGINE_LEAVE:
            pid = pid_for(pl["engine"])
            body.append(_instant(pid, _TID_CONTROL, us, "leave", "churn", {}))
        elif kind == EV.PHASE:
            pid = pid_for(pl["engine"])
            t0 = float(pl["t0"]) * 1e6
            args = {"client": int(pl["client"]), "turn": int(pl["turn"])}
            if "bytes" in pl:
                args["bytes"] = int(pl["bytes"])
            if "ttft" in pl:
                args["ttft_ms"] = float(pl["ttft"]) * 1e3
            body.append({"ph": "X", "pid": pid, "tid": _TID_SERVING,
                         "ts": t0, "dur": max(us - t0, 0.0),
                         "name": str(pl["phase"]), "cat": "serving",
                         "args": args})

    meta: List[dict] = []
    for name, pid in sorted(pids.items(), key=lambda kv: kv[1]):
        meta.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                     "args": {"name": name}})
        for tid, tname in _TID_NAMES.items():
            meta.append({"ph": "M", "pid": pid, "tid": tid,
                         "name": "thread_name", "args": {"name": tname}})

    if metrics:
        body.append({"ph": "C", "pid": _FABRIC_PID, "tid": 0, "ts": last_us,
                     "name": "metrics",
                     "args": {k: float(v) for k, v in metrics.items()}})

    return {
        "displayTimeUnit": "ms",
        "traceEvents": meta + body,
        "otherData": {
            "generator": "repro.obs",
            "events": n_events,
            "dropped": int(recorder.dropped),
        },
    }


def _instant(pid: int, tid: int, us: float, name: str, cat: str,
             args: dict) -> dict:
    return {"ph": "i", "s": "t", "pid": pid, "tid": tid, "ts": us,
            "name": name, "cat": cat, "args": args}


def to_json(doc: dict) -> str:
    """Canonical serialization: identical docs -> identical bytes."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"


def validate_trace(doc: dict) -> List[str]:
    """Check Trace Event Format invariants Perfetto relies on. Returns a
    list of problems (empty = loadable)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "i", "C", "M"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        for field in ("pid", "tid", "name"):
            if field not in ev:
                problems.append(f"event {i} ({ph}): missing {field!r}")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                problems.append(f"event {i} ({ph}): bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} (X): bad dur {dur!r}")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            problems.append(f"event {i} (i): bad scope {ev.get('s')!r}")
        args = ev.get("args", {})
        if not isinstance(args, dict):
            problems.append(f"event {i}: args not a dict")
        else:
            for k, v in args.items():
                if not isinstance(v, (int, float, str, bool)):
                    problems.append(
                        f"event {i}: args[{k!r}] has non-JSON-scalar "
                        f"type {type(v).__name__}")
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as exc:  # pragma: no cover
        problems.append(f"document not JSON-serializable: {exc}")
    return problems
