"""Array-backed flight recorder for the spray engine.

The recorder is a fixed-capacity ring buffer of `(ts, kind, payload)`
records on the virtual clock. Timestamps and kinds live in preallocated
numpy arrays (`float64` / `int16`); payloads are per-kind dicts held in a
parallel list. One `append` is a couple of array stores plus a list slot
write — cheap enough that instrumented code records at *batch* granularity
(one append per wave, per drain run, per gossip rumor) without disturbing
the vectorized hot path.

Zero-cost-when-off contract: nothing in this module is ever touched unless
a recorder is attached. Instrumented call sites hold `self._rec = None` by
default and guard every record with a single `rec = self._rec` load and
`is not None` branch per batch — the pattern the hot-path bench gates pin.

Recording is strictly passive: the recorder never schedules fabric events,
never mutates engine state, and payloads only reference freshly-built or
immutable values, so attaching a recorder cannot perturb a simulation
(pinned by the tracing-ON/OFF report-parity tests).

Identity interning: raw `Slice.slice_id` / batch ids come from process-
global counters and differ between two runs in the same process. `sid()`
and `bid()` map them to dense ids in first-seen order — deterministic for a
given spec + seed — and all read-side payloads and exports use only the
dense ids. Interning is *deferred off the hot path*: record sites store
`Slice` references under the `slice`/`slices` payload keys (the identity
fields — slice_id, batch_id, src_offset, length — are immutable), and the
first read (`events()`) interns them in event order, so the engine's timed
path never pays the per-slice dict work (~300us per 512-slice wave).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import numpy as np

from .events import KIND_NAMES


class FlightRecorder:
    """Ring buffer of structured events with dense slice/batch interning."""

    __slots__ = ("capacity", "_ts", "_kind", "_payload", "_n",
                 "_sids", "_bids", "_slice_meta", "_norm_upto")

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._kind = np.zeros(self.capacity, dtype=np.int16)
        self._payload: List[object] = [None] * self.capacity
        self._n = 0  # total appends ever; ring slot is _n % capacity
        self._sids: Dict[int, int] = {}
        self._bids: Dict[int, int] = {}
        # per dense sid: (dense batch id, src_offset, length)
        self._slice_meta: List[Tuple[int, int, int]] = []
        self._norm_upto = 0  # total-append watermark of lazy interning

    # -- recording ---------------------------------------------------------

    def append(self, kind: int, ts: float, payload: dict) -> None:
        i = self._n % self.capacity
        self._ts[i] = ts
        self._kind[i] = kind
        self._payload[i] = payload
        self._n += 1

    def sid(self, sl) -> int:
        """Dense id for a slice (interned on first sight, meta retained)."""
        m = self._sids
        s = m.get(sl.slice_id)
        if s is None:
            s = m[sl.slice_id] = len(m)
            self._slice_meta.append((self.bid(sl.batch_id),
                                     sl.src_offset, sl.length))
        return s

    def bid(self, batch_id: int) -> int:
        """Dense id for an application batch."""
        m = self._bids
        b = m.get(batch_id)
        if b is None:
            b = m[batch_id] = len(m)
        return b

    # -- reading -----------------------------------------------------------

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def dropped(self) -> int:
        """Events overwritten because the ring wrapped."""
        return max(0, self._n - self.capacity)

    def _normalize(self) -> None:
        """Intern `Slice` references left in payloads by the hot path into
        dense ids, oldest retained event first. Appends may continue after a
        read; the watermark makes later reads intern only the new tail, so
        first-seen order — and with it trace byte-determinism — holds no
        matter when or how often the recorder is read."""
        cap = self.capacity
        start = max(self._norm_upto, self._n - cap)
        if start >= self._n:
            return
        sid = self.sid
        for k in range(start, self._n):
            pl = self._payload[k % cap]
            sls = pl.get("slices")
            # INTENT reuses the key for a plain int count; WAVE/COMPLETE
            # store lists (of Slice refs pre-normalization)
            if type(sls) is list and sls and not isinstance(sls[0], int):
                pl["slices"] = [sid(s) for s in sls]
            sl = pl.get("slice")
            if sl is not None and not isinstance(sl, int):
                pl["slice"] = sid(sl)
        self._norm_upto = self._n

    def events(self) -> Iterator[Tuple[float, int, dict]]:
        """Retained events, oldest first (wraparound-aware). Payload slice
        references are interned to dense ids on first read."""
        self._normalize()
        cap = self.capacity
        for k in range(max(0, self._n - cap), self._n):
            i = k % cap
            yield float(self._ts[i]), int(self._kind[i]), self._payload[i]

    def slice_info(self, sid: int) -> Tuple[int, int, int]:
        """(dense batch id, src_offset, length) for a dense slice id."""
        return self._slice_meta[sid]

    def n_slices(self) -> int:
        return len(self._sids)

    def n_batches(self) -> int:
        return len(self._bids)

    def counts(self) -> Dict[str, int]:
        """Retained-event count per kind name (for summaries/tests)."""
        out: Dict[str, int] = {}
        kinds = self._kind if self._n >= self.capacity \
            else self._kind[:self._n]
        vals, freq = np.unique(kinds, return_counts=True)
        for v, f in zip(vals, freq):
            out[KIND_NAMES.get(int(v), f"kind_{int(v)}")] = int(f)
        return out
