"""Post-mortem explainer: per-slice causal chains and healing timelines.

Runs a named scenario with a flight recorder attached, then reconstructs —
from the trace alone — the story the aggregate report can't tell:

    PYTHONPATH=src python -m repro.obs.explain \
        --scenario multi_engine_incast_flap --slice 12

prints slice 12's causal chain (intent -> wave pick with the per-candidate
score breakdown -> posts/failures/reroutes -> completion), and

    PYTHONPATH=src python -m repro.obs.explain \
        --scenario lossy_gossip_flap --healing

prints the healing timeline (fault onset -> first failure -> last reroute ->
recovered) with the trace-derived heal time that the tests cross-check
against the runner's stall matrix. `--trace-out` additionally writes the
Perfetto/Chrome trace JSON.

`replay_wave` is the provenance core: it re-runs Algorithm 1
(`tent_choose_wave`, scheduler.py) on the pre-charge inputs snapshot the
recorder stored with each WAVE event, reproducing every per-candidate score
the engine computed — and asserts the replayed picks equal the recorded
ones, so the printed breakdowns are guaranteed to be the real decision, not
a reenactment that drifted.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import events as EV
from .recorder import FlightRecorder
from .trace import export_chrome_trace, to_json

# mirror of ScenarioRunner.NEVER_RECOVERED_MS (scenarios/runner.py)
NEVER_RECOVERED_MS = 1e12


class ProvenanceError(AssertionError):
    """The replayed Algorithm 1 run disagreed with the recorded choices."""


def replay_wave(payload: dict) -> List[dict]:
    """Re-run Algorithm 1 over one recorded wave's pre-charge inputs.

    Returns one provenance dict per slice: the full per-candidate score
    vector at decision time, the gamma window, whether the all-excluded
    fallback fired, the chosen rail, and its post-charge queue. Performs
    the same IEEE-double operations in the same order as
    `repro.core.scheduler.tent_choose_wave` and raises `ProvenanceError`
    if any replayed pick differs from the recorded one.
    """
    inp = payload["inputs"]
    q = [int(v) for v in np.asarray(inp["queued"])]
    gl = [float(v) for v in np.asarray(inp["glocal"], dtype=np.float64)]
    gr = [float(v) for v in np.asarray(inp["gremote"], dtype=np.float64)]
    bw = [float(v) for v in np.asarray(inp["bandwidth"], dtype=np.float64)]
    b0 = [float(v) for v in np.asarray(inp["beta0"], dtype=np.float64)]
    b1 = [float(v) for v in np.asarray(inp["beta1"], dtype=np.float64)]
    pen = [float(v) for v in np.asarray(inp["penalty"], dtype=np.float64)]
    exc = [bool(v) for v in np.asarray(inp["excluded"])]
    lens = [int(v) for v in np.asarray(payload["lengths"])]
    recorded = [int(v) for v in np.asarray(payload["choices"])]
    sids = list(payload["slices"])
    rr = int(inp["rr"])
    gamma = float(inp["gamma"])
    n_cands = len(q)
    inf = float("inf")
    one_plus_gamma = 1.0 + gamma
    rails = range(n_cands)

    def score(d: int, length: int) -> float:
        return pen[d] * (b0[d] + b1[d] * (((q[d] + gl[d]) + gr[d]) + length) / bw[d])

    out: List[dict] = []
    s: list = []
    cur_len = None
    infeasible_from = None
    for k in range(len(lens)):
        length = lens[k]
        if infeasible_from is not None:
            chosen = -1
            entry = {"slice": int(sids[k]), "length": length, "scores": None,
                     "fallback": False, "window": [], "chosen": -1,
                     "queued_after": None, "infeasible": True}
        else:
            if length != cur_len:
                cur_len = length
                s = [inf if exc[d] else score(d, length) for d in rails]
            s_min = min(s)
            if s_min == inf:
                fb = [pen[d] * (b0[d] + b1[d] * (q[d] + length) / bw[d])
                      for d in rails]
                fb_min = min(fb)
                if fb_min == inf:
                    infeasible_from = k
                    chosen = -1
                    entry = {"slice": int(sids[k]), "length": length,
                             "scores": list(fb), "fallback": True,
                             "window": [], "chosen": -1,
                             "queued_after": None, "infeasible": True}
                else:
                    window = [d for d in rails
                              if fb[d] <= one_plus_gamma * fb_min]
                    chosen = window[rr % len(window)]
                    rr += 1
                    q[chosen] += length
                    if not exc[chosen]:
                        s[chosen] = score(chosen, length)
                    entry = {"slice": int(sids[k]), "length": length,
                             "scores": list(fb), "fallback": True,
                             "window": window, "chosen": chosen,
                             "queued_after": q[chosen], "infeasible": False}
            else:
                threshold = one_plus_gamma * s_min
                scores_now = list(s)
                window = [d for d in rails if s[d] <= threshold]
                chosen = window[rr % len(window)]
                rr += 1
                q[chosen] += length
                s[chosen] = score(chosen, length)
                entry = {"slice": int(sids[k]), "length": length,
                         "scores": scores_now, "fallback": False,
                         "window": window, "chosen": chosen,
                         "queued_after": q[chosen], "infeasible": False}
        if chosen != recorded[k]:
            raise ProvenanceError(
                f"wave replay diverged at slice index {k} "
                f"(sid {sids[k]}): replayed rail {chosen}, "
                f"recorded {recorded[k]}")
        entry["link"] = (int(inp["local_links"][chosen])
                         if chosen >= 0 else -1)
        out.append(entry)
    return out


def slice_chain(recorder: FlightRecorder,
                events: Sequence[Tuple[float, int, dict]],
                sid: int) -> List[Tuple[float, str, dict]]:
    """Every event touching dense slice id `sid`, in virtual-clock order:
    the declaring intent, the wave that scheduled it (with its index within
    the wave), posts/failures/substitutions, and the drain that completed
    it."""
    if sid >= recorder.n_slices():
        raise ValueError(
            f"slice {sid} not in trace (have {recorder.n_slices()} slices)")
    bid, _, _ = recorder.slice_info(sid)
    steps: List[Tuple[float, str, dict]] = []
    for ts, kind, pl in events:
        if kind == EV.INTENT and pl["batch"] == bid:
            steps.append((ts, "intent", pl))
        elif kind == EV.WAVE and sid in pl["slices"]:
            k = list(pl["slices"]).index(sid)
            steps.append((ts, "wave", {"payload": pl, "index": k}))
        elif kind == EV.POST and pl["slice"] == sid:
            steps.append((ts, "reroute" if pl["attempt"] > 0 else "post", pl))
        elif kind == EV.FAIL and pl["slice"] == sid:
            steps.append((ts, "fail", pl))
        elif kind == EV.SUBSTITUTE and pl["slice"] == sid:
            steps.append((ts, "substitute", pl))
        elif kind == EV.COMPLETE and sid in pl["slices"]:
            i = list(pl["slices"]).index(sid)
            steps.append((ts, "complete",
                          {"link": int(pl["links"][i]),
                           "scheduled": float(pl["scheduled"][i]),
                           "t_pred": float(pl["t_pred"][i]),
                           "length": int(pl["lengths"][i])}))
        elif kind == EV.BATCH_DONE and pl["batch"] == bid:
            steps.append((ts, "batch_done", pl))
    return steps


def healing_timeline(events: Sequence[Tuple[float, int, dict]], *,
                     exclude_engines: Sequence[str] = ()) -> dict:
    """Reconstruct the healing story from the trace alone.

    Fault onsets are the LINK_FAIL firings; recovery per onset is the first
    application-batch completion at/after it, over batches from engines not
    in `exclude_engines` (cluster incast scenarios pass the contender engine
    here so the set of batches equals the workload completions the runner's
    stall matrix is computed from — the cross-check test asserts `heal_ms`
    equals `ScenarioReport.stall_ms` exactly). Also surfaces the paper's
    first-failure -> last-reroute -> recovered chain.
    """
    onsets = sorted({ts for ts, k, _ in events if k == EV.LINK_FAIL})
    done = sorted(ts for ts, k, pl in events
                  if k == EV.BATCH_DONE and pl["engine"] not in exclude_engines)
    fail_ts = [ts for ts, k, _ in events if k == EV.FAIL]
    reroute_ts = [ts for ts, k, pl in events
                  if k == EV.POST and pl["attempt"] > 0]
    done_arr = np.asarray(done)
    recoveries: List[Optional[float]] = []
    worst = 0.0
    never = False
    for onset in onsets:
        i = int(np.searchsorted(done_arr, onset))
        if i >= len(done):
            never = True
            recoveries.append(None)
            continue
        recoveries.append(done[i])
        # same accumulation as ScenarioRunner._stall_ms
        worst = max(worst, done[i] - onset)
    if not onsets:
        heal_ms = -1.0
    elif never:
        heal_ms = NEVER_RECOVERED_MS
    else:
        heal_ms = worst * 1e3
    first_failure = min(fail_ts + onsets) if (fail_ts or onsets) else None
    return {
        "onsets": onsets,
        "recoveries": recoveries,
        "heal_ms": heal_ms,
        "first_failure": first_failure,
        "last_reroute": max(reroute_ts) if reroute_ts else None,
        "n_failures": len(fail_ts),
        "n_reroutes": len(reroute_ts),
    }


# -- rendering ---------------------------------------------------------------

def _fmt_scores(entry: dict) -> str:
    if entry["scores"] is None:
        return "    (wave already infeasible; no scores computed)"
    rows = []
    for d, sc in enumerate(entry["scores"]):
        marks = []
        if d == entry["chosen"]:
            marks.append("<= chosen")
        elif d in entry["window"]:
            marks.append("in window")
        rows.append(f"    rail {d}: score {sc:.6e} {' '.join(marks)}".rstrip())
    if entry["fallback"]:
        rows.append("    (all rails excluded -> unmasked-cost fallback)")
    return "\n".join(rows)


def print_slice_chain(recorder: FlightRecorder, events, sid: int,
                      out=None) -> None:
    # resolve the stream at call time so stdout redirection/capture works
    out = out if out is not None else sys.stdout
    bid, off, length = recorder.slice_info(sid)
    print(f"slice {sid}: {length} B at offset {off} of batch {bid}",
          file=out)
    for ts, step, pl in slice_chain(recorder, events, sid):
        ms = ts * 1e3
        if step == "intent":
            print(f"  {ms:10.4f} ms  intent: batch {pl['batch']} declared "
                  f"({pl['transfers']} transfers, {pl['slices']} slices, "
                  f"{pl['bytes']} B)", file=out)
        elif step == "wave":
            prov = replay_wave(pl["payload"])
            entry = prov[pl["index"]]
            where = (f"rail {entry['chosen']} (link {entry['link']})"
                     if entry["chosen"] >= 0 else "infeasible")
            print(f"  {ms:10.4f} ms  wave pick "
                  f"(slice {pl['index'] + 1}/{len(prov)} of wave): {where}",
                  file=out)
            print(_fmt_scores(entry), file=out)
        elif step in ("post", "reroute"):
            print(f"  {ms:10.4f} ms  {step}: link {pl['link']} "
                  f"hop {pl['hop']} attempt {pl['attempt']} "
                  f"(predicted {pl['t_pred'] * 1e3:.4f} ms)", file=out)
        elif step == "fail":
            print(f"  {ms:10.4f} ms  FAIL on link {pl['link']} "
                  f"(attempt {pl['attempt']})", file=out)
        elif step == "substitute":
            print(f"  {ms:10.4f} ms  backend substituted "
                  f"(batch {pl['batch']})", file=out)
        elif step == "complete":
            print(f"  {ms:10.4f} ms  complete on link {pl['link']} "
                  f"(scheduled {pl['scheduled'] * 1e3:.4f} ms, "
                  f"predicted {pl['t_pred'] * 1e3:.4f} ms)", file=out)
        elif step == "batch_done":
            print(f"  {ms:10.4f} ms  batch {pl['batch']} done "
                  f"({pl['bytes']} B)", file=out)


def print_healing(h: dict, out=None) -> None:
    out = out if out is not None else sys.stdout
    if not h["onsets"]:
        print("no link failures in trace", file=out)
        return
    for onset, rec in zip(h["onsets"], h["recoveries"]):
        when = f"{rec * 1e3:.4f} ms" if rec is not None else "NEVER"
        print(f"  fault onset {onset * 1e3:.4f} ms -> recovered {when}",
              file=out)
    ff = h["first_failure"]
    lr = h["last_reroute"]
    print(f"  first failure event : "
          f"{ff * 1e3:.4f} ms" if ff is not None else
          "  first failure event : -", file=out)
    print(f"  last reroute posted : "
          f"{lr * 1e3:.4f} ms" if lr is not None else
          "  last reroute posted : -", file=out)
    print(f"  failures={h['n_failures']} reroutes={h['n_reroutes']}",
          file=out)
    verdict = "PASS" if h["heal_ms"] < 50.0 else "FAIL"
    print(f"  trace-derived heal time: {h['heal_ms']:.4f} ms "
          f"(sub-50 ms claim: {verdict})", file=out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.explain",
        description="Run a scenario with the flight recorder attached and "
                    "explain what happened from the trace.")
    ap.add_argument("--scenario", required=True,
                    help="named scenario from the library")
    ap.add_argument("--policy", default=None,
                    help="policy to run (default: the spec's primary)")
    ap.add_argument("--slice", type=int, default=None, metavar="SID",
                    help="print this dense slice id's causal chain")
    ap.add_argument("--healing", action="store_true",
                    help="print the healing timeline")
    ap.add_argument("--exclude-engines", default="cache", metavar="NAMES",
                    help="comma-separated engines whose batches don't count "
                         "as workload completions for --healing "
                         "(default: the incast contender)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the Perfetto/Chrome trace JSON here")
    ap.add_argument("--capacity", type=int, default=1 << 18,
                    help="flight-recorder ring capacity")
    args = ap.parse_args(argv)

    from ..scenarios import ScenarioRunner, get
    spec = get(args.scenario)
    policy = args.policy or spec.policies[0]
    rec = FlightRecorder(capacity=args.capacity)
    report = ScenarioRunner(spec).run_policy(policy, recorder=rec)
    events = list(rec.events())

    print(f"{spec.name} [{policy}]: {len(rec)} events retained "
          f"({rec.dropped} dropped), {rec.n_slices()} slices, "
          f"{rec.n_batches()} batches")
    counts = rec.counts()
    print("  " + "  ".join(f"{k}={v}" for k, v in sorted(counts.items())))
    print(f"  throughput {report.throughput / 1e9:.3f} GB/s, "
          f"stall {report.stall_ms:.3f} ms")

    if args.slice is not None:
        print()
        print_slice_chain(rec, events, args.slice)
    if args.healing:
        print()
        excl = tuple(e for e in args.exclude_engines.split(",") if e)
        print_healing(healing_timeline(events, exclude_engines=excl))
    if args.trace_out:
        doc = export_chrome_trace(rec)
        with open(args.trace_out, "w") as f:
            f.write(to_json(doc))
        print(f"\ntrace written to {args.trace_out} "
              f"({len(doc['traceEvents'])} trace events)")
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
