"""Flight-recorder event kinds.

Every record the `FlightRecorder` holds is `(ts, kind, payload)`: a virtual-
clock timestamp, one of the integer kinds below, and a payload dict whose
shape is fixed per kind (documented in docs/OBSERVABILITY.md). Kinds are
plain ints so the recorder can keep them in a preallocated int16 array;
`KIND_NAMES` maps them back for rendering.

Payload identity rules: slices and application batches appear only as the
recorder's *dense interned ids* (`FlightRecorder.sid` / `FlightRecorder.bid`,
assigned in first-seen order along the virtual clock), never as the raw
process-global `slice_id`/`batch_id` counters — those counters keep running
across runs in one process, and the exported trace must be byte-identical
for the same spec + seed no matter how many runs came before.
"""
from __future__ import annotations

INTENT = 1        # a declarative batch was submitted (one per submit_transfer)
WAVE = 2          # one vectorized wave chosen (with full decision provenance)
POST = 3          # one scalar-path slice posted (retry / hop / substitution)
COMPLETE = 4      # a run of slice completions drained (one per drain batch)
FAIL = 5          # one slice's wire operation failed
SUBSTITUTE = 6    # a transfer's whole backend was substituted
BATCH_DONE = 7    # an application batch completed
BATCH_FAIL = 8    # an application batch surfaced a failure
EXCLUDE = 9       # a rail was soft-excluded (implicit or explicit)
READMIT = 10      # an excluded rail was re-admitted (blind or probe-verified)
LINK_FAIL = 11    # a scheduled link failure fired on the fabric
DEGRADE = 12      # a degradation window was installed on a link
RUMOR_SENT = 13   # membership gossiped an exclusion/readmission rumor
RUMOR_RECV = 14   # a peer applied a rumor to its local health state
ANTI_ENTROPY = 15 # one anti-entropy reconciliation round ran
ENGINE_JOIN = 16  # an engine joined the running cluster
ENGINE_LEAVE = 17 # an engine left the running cluster
PHASE = 18        # a serving request finished one phase (span: t0 -> ts)

KIND_NAMES = {
    INTENT: "intent",
    WAVE: "wave",
    POST: "post",
    COMPLETE: "complete",
    FAIL: "fail",
    SUBSTITUTE: "substitute",
    BATCH_DONE: "batch_done",
    BATCH_FAIL: "batch_fail",
    EXCLUDE: "exclude",
    READMIT: "readmit",
    LINK_FAIL: "link_fail",
    DEGRADE: "degrade",
    RUMOR_SENT: "rumor_sent",
    RUMOR_RECV: "rumor_recv",
    ANTI_ENTROPY: "anti_entropy",
    ENGINE_JOIN: "engine_join",
    ENGINE_LEAVE: "engine_leave",
    PHASE: "phase",
}
