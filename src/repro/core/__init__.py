"""TENT core: declarative slice-spraying data-movement engine (the paper's
primary contribution), plus the discrete-event fabric it executes on in this
reproduction."""
from .engine import BatchResult, EngineConfig, TentEngine
from .calqueue import CalendarQueue
from .fabric import FAR_WINDOW, Fabric, FabricConfig
from .jit_core import (
    EngineJitCore,
    SprayProgram,
    jax_available,
    make_draws,
    simulate_spray_ref,
    spray_single,
    spray_sweep,
)
from .plan import (
    Orchestrator,
    RouteOption,
    Stage,
    StageCandidates,
    TransportPlan,
    build_stage_candidates,
)
from .resilience import HealthConfig, HealthMonitor
from .scheduler import (
    Candidate,
    HashPolicy,
    PinnedPolicy,
    Policy,
    RoundRobinPolicy,
    StaticBest2Policy,
    TentPolicy,
    make_policy,
    tent_choose_jnp,
    tent_choose_wave,
    tent_choose_wave_jnp,
    tent_scores_jnp,
)
from .segments import Segment, SegmentManager, device_segment, file_segment, host_segment
from .slicing import decompose
from .telemetry import LinkTelemetry, TelemetryStore
from .topology import DEFAULT_TIER_PENALTY, FabricSpec, LinkDesc, NodeSpec, Topology
from .types import (
    BatchState,
    LinkClass,
    Location,
    MemoryKind,
    Slice,
    SliceState,
    TentError,
    TransferRequest,
)

__all__ = [
    "BatchResult", "CalendarQueue", "EngineConfig", "TentEngine", "FAR_WINDOW",
    "Fabric", "FabricConfig",
    "EngineJitCore", "SprayProgram", "jax_available", "make_draws",
    "simulate_spray_ref", "spray_single", "spray_sweep", "Orchestrator",
    "RouteOption", "Stage", "StageCandidates", "TransportPlan",
    "build_stage_candidates", "HealthConfig", "HealthMonitor",
    "Candidate", "HashPolicy", "PinnedPolicy", "Policy", "RoundRobinPolicy",
    "StaticBest2Policy", "TentPolicy", "make_policy", "tent_choose_jnp",
    "tent_choose_wave", "tent_choose_wave_jnp",
    "tent_scores_jnp", "Segment", "SegmentManager", "device_segment",
    "file_segment", "host_segment", "decompose", "LinkTelemetry",
    "TelemetryStore", "DEFAULT_TIER_PENALTY", "FabricSpec", "LinkDesc",
    "NodeSpec", "Topology", "BatchState", "LinkClass", "Location",
    "MemoryKind", "Slice", "SliceState", "TentError", "TransferRequest",
]
