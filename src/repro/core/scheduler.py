"""Slice scheduling policies.

`TentPolicy` implements the paper's Algorithm 1 (telemetry-driven slice
scheduling) exactly: score every reachable candidate with the predictive
cost model times a topology-tier penalty, keep the candidates within a
tolerance window gamma of the best score, and round-robin among them; then
charge the chosen device's local queue.

The baseline policies reproduce the engines the paper compares against:
  * RoundRobinPolicy  — Mooncake TE's state-blind fixed-size striping (§2.2)
  * HashPolicy        — Mooncake TE's hashing variant
  * StaticBest2Policy — NIXL/UCX: stripe across the statically best K NICs
  * PinnedPolicy      — UCCL-P2P: each memory region is bound to one NIC

All policies share the same interface so the engine (and TEBench) can swap
them without touching anything else — that swap *is* the paper's ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .telemetry import LinkTelemetry, TelemetryStore
from .topology import DEFAULT_TIER_PENALTY
from .types import NO_ELIGIBLE_DEVICE, TentError


@dataclasses.dataclass
class Candidate:
    """One schedulable device (local link) with its affinity tier and, for
    two-resource paths, the remote endpoint's telemetry. The remote side
    carries the cluster-level signals: diffused receiver load and failure
    rumors from peer engines (paper §4.2)."""

    telemetry: LinkTelemetry
    tier: int
    remote: Optional[LinkTelemetry] = None

    @property
    def link_id(self) -> int:
        return self.telemetry.desc.link_id


class Policy:
    name = "abstract"

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        raise NotImplementedError

    def reset(self) -> None:  # pragma: no cover - most policies are stateless
        pass


class TentPolicy(Policy):
    """Algorithm 1: Telemetry-Driven Slice Scheduling."""

    name = "tent"

    def __init__(
        self,
        *,
        tier_penalty: Optional[Dict[int, float]] = None,
        gamma: float = 0.05,
        store: Optional[TelemetryStore] = None,
    ):
        self.tier_penalty = dict(tier_penalty or DEFAULT_TIER_PENALTY)
        self.gamma = gamma
        self.store = store
        self._rr = 0

    def scores(self, candidates: Sequence[Candidate], length: int) -> List[float]:
        out = []
        for c in candidates:
            tl = c.telemetry
            if tl.excluded or (c.remote is not None and c.remote.excluded):
                # soft exclusion (paper §4.3); a remote exclusion typically
                # arrives as a failure rumor from a peer engine (§4.2)
                out.append(float("inf"))
                continue
            queued = (
                self.store.effective_queue(tl) if self.store is not None else float(tl.queued_bytes)
            )
            if self.store is not None and c.remote is not None:
                # diffused receiver-side pressure: other engines' in-flight
                # bytes converging on the remote endpoint this path pairs with
                queued += self.store.remote_pressure(c.remote.desc.link_id)
            t_hat = tl.beta0 + tl.beta1 * (queued + length) / tl.desc.bandwidth
            out.append(self.tier_penalty.get(c.tier, float("inf")) * t_hat)
        return out

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        if not candidates:
            raise TentError(NO_ELIGIBLE_DEVICE, "empty candidate set")
        s = self.scores(candidates, length)
        s_min = min(s)
        if s_min == float("inf"):
            # Soft exclusion must not deadlock: when every rail is excluded
            # (e.g. a single-link hop under degradation), fall back to the
            # cost model over tier-feasible rails, ignoring exclusion.
            s = [
                self.tier_penalty.get(c.tier, float("inf"))
                * (c.telemetry.beta0 + c.telemetry.beta1
                   * (c.telemetry.queued_bytes + length) / c.telemetry.desc.bandwidth)
                for c in candidates
            ]
            s_min = min(s)
            if s_min == float("inf"):
                raise TentError(NO_ELIGIBLE_DEVICE, "no tier-feasible candidates")
        window = [c for c, sc in zip(candidates, s) if sc <= (1 + self.gamma) * s_min]
        chosen = window[self._rr % len(window)]
        self._rr += 1
        chosen.telemetry.on_schedule(length)  # line 11: A_d* += L
        return chosen

    def choose_wave(self, sc, lengths):
        """Algorithm 1 over a whole wave of same-stage slices at once.

        `sc` is a `repro.core.plan.StageCandidates` (the cached, array-
        annotated candidate set for one plan stage); `lengths` the pending
        slices' byte counts, in dispatch order. One gather per array pulls
        the candidates' live telemetry out of the store's struct-of-arrays
        state, `tent_choose_wave` replays the per-slice choose/charge
        sequence on those arrays (bit-identical to calling `choose` once per
        slice, including the round-robin counter and the sequential line-11
        queue charges), and one scatter writes the charged queues back.

        Returns `(choices, queued_at_schedule)`: per-slice candidate indices
        (-1 from the first slice with no tier-feasible rail onward — the
        engine routes those through the scalar substitution path) and the
        per-slice post-charge queue depths the completion-side EWMA update
        needs."""
        store = self.store
        slots = sc.local_slot
        excluded = store.excluded_arr[slots]
        if sc.remote_any:
            excluded = excluded | (sc.has_remote & store.excluded_arr[sc.remote_slot_safe])
        if store.global_weight > 0.0:
            glocal = store.foreign_load_array(sc.local_links)
            gremote = store.foreign_load_array(sc.remote_links)
        else:
            glocal = gremote = sc.zeros
        choices, queued_at, queued_out, rr = tent_choose_wave(
            store.queued_arr[slots], glocal, gremote, sc.bandwidth,
            store.beta0_arr[slots], store.beta1_arr[slots], sc.penalty,
            excluded, lengths, self._rr, self.gamma)
        store.queued_arr[slots] = queued_out  # line 11 charges, applied
        self._rr = rr
        return choices, queued_at

    def wave_inputs(self, sc) -> dict:
        """Pre-charge snapshot of everything `choose_wave` is about to read
        — the decision-provenance record the flight recorder (repro.obs)
        stores with each WAVE event. Must be taken *before* `choose_wave`
        runs (the line-11 charges mutate the queue array);
        `repro.obs.explain.replay_wave` re-runs Algorithm 1 on this snapshot
        and cross-checks that it reproduces the recorded choices exactly.
        Every array is a fresh copy (fancy-index gathers / explicit copies),
        so later simulation steps cannot retroactively rewrite history."""
        store = self.store
        slots = sc.local_slot
        excluded = store.excluded_arr[slots]
        if sc.remote_any:
            excluded = excluded | (sc.has_remote & store.excluded_arr[sc.remote_slot_safe])
        if store.global_weight > 0.0:
            glocal = store.foreign_load_array(sc.local_links)
            gremote = store.foreign_load_array(sc.remote_links)
        else:
            glocal = np.array(sc.zeros, dtype=np.float64)
            gremote = np.array(sc.zeros, dtype=np.float64)
        return {
            "queued": store.queued_arr[slots],
            "glocal": glocal,
            "gremote": gremote,
            "bandwidth": np.array(sc.bandwidth, dtype=np.float64),
            "beta0": store.beta0_arr[slots],
            "beta1": store.beta1_arr[slots],
            "penalty": np.array(sc.penalty, dtype=np.float64),
            "excluded": excluded,
            "rr": self._rr,
            "gamma": self.gamma,
            "local_links": list(sc.local_links),
            "remote_links": list(sc.remote_links),
        }


class RoundRobinPolicy(Policy):
    """Mooncake TE-style state-blind striping: fixed rotation over the rails
    permitted by static NUMA priority, ignoring congestion signals."""

    name = "round_robin"

    def __init__(self, *, max_tier: int = 3):
        self.max_tier = max_tier
        self._rr = 0

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        # state-blind: no exclusion filtering (TE has no telemetry loop)
        elig = [c for c in candidates if c.tier <= self.max_tier]
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no round-robin candidates")
        chosen = elig[self._rr % len(elig)]
        self._rr += 1
        chosen.telemetry.on_schedule(length)
        return chosen


class HashPolicy(Policy):
    """Static hashing on the slice ordinal (Mooncake TE hashing mode)."""

    name = "hash"

    def __init__(self) -> None:
        self._n = 0

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        elig = list(candidates)  # state-blind
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no hash candidates")
        self._n += 1
        idx = (self._n * 2654435761) % len(elig)
        chosen = elig[idx]
        chosen.telemetry.on_schedule(length)
        return chosen


class StaticBest2Policy(Policy):
    """NIXL/UCX-style: rank NICs by static transport properties and stripe
    large transfers over the best K only; small blocks use a single NIC."""

    name = "static_best2"

    def __init__(self, *, k: int = 2, multirail_threshold: int = 8 * 1024 * 1024):
        self.k = k
        self.multirail_threshold = multirail_threshold
        self._rr = 0

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        elig = list(candidates)  # static transport properties only
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no static candidates")
        ranked = sorted(elig, key=lambda c: (c.tier, -c.telemetry.desc.bandwidth, c.link_id))
        if length < self.multirail_threshold:
            chosen = ranked[0]
        else:
            top = ranked[: self.k]
            chosen = top[self._rr % len(top)]
            self._rr += 1
        chosen.telemetry.on_schedule(length)
        return chosen


class PinnedPolicy(Policy):
    """UCCL-P2P-style: each registered region is pinned to exactly one NIC
    (its tier-1 / lowest-id rail); no cross-NIC aggregation."""

    name = "pinned"

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        elig = list(candidates)  # fixed region->NIC binding
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no pinned candidates")
        chosen = min(elig, key=lambda c: (c.tier, c.link_id))
        chosen.telemetry.on_schedule(length)
        return chosen


POLICIES = {
    p.name: p
    for p in (TentPolicy, RoundRobinPolicy, HashPolicy, StaticBest2Policy, PinnedPolicy)
}


def make_policy(name: str, **kwargs) -> Policy:
    try:
        return POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; have {sorted(POLICIES)}") from None


# ---------------------------------------------------------------------------
# Vectorized wave scheduling (numpy, float64) — the engine's hot path.
#
# `tent_choose_wave` replays Algorithm 1 for a whole batch of pending slices
# against one candidate set. Per slice it performs the *same* float64
# operations, in the same order, as the scalar `TentPolicy.choose`, so the
# two paths pick bit-identical rails; the speedup comes from scoring all
# rails with a few array operations and never materializing per-slice
# candidate objects. Line 11's sequential queue charge is preserved by
# carrying the integer queue vector through the batch (`queued` evolves
# slice by slice; the omega-blended global terms are frozen for the wave —
# no event can change them while the dispatch loop runs).
# ---------------------------------------------------------------------------

def tent_choose_wave(queued, global_local, global_remote, bandwidth, beta0,
                     beta1, penalty, excluded, lengths, rr, gamma=0.05):
    """Batched Algorithm 1 on one candidate set (numpy float64 reference).

    Arguments are per-candidate arrays: integer local queues (bytes), the
    omega-discounted local/remote global-load terms, nominal bandwidth, the
    Eq. 1 betas, tier penalties (inf = tier-infeasible), and the soft-
    exclusion mask; `lengths` holds the wave's slice sizes in dispatch
    order, `rr` the policy's round-robin counter.

    Returns `(choices, queued_at_schedule, queued_out, rr_out)`. A slice
    whose candidates are all tier-infeasible gets choice -1 and *stops the
    wave* (entries from there on stay -1, uncharged) — feasibility is a
    static property of the candidate set, so every later slice of the wave
    would fail the same way and must go through the scalar substitution
    path instead.
    """
    # Work on plain Python floats/ints: every operation below is the same
    # IEEE-double operation, in the same order, that the scalar path
    # performs, and at rail counts of ~8 the interpreter beats per-op numpy
    # dispatch. The win over calling `choose` per slice is *incremental
    # rescoring*: after slice k charges rail c, only s[c] changes for slice
    # k+1 (as long as the slice length is unchanged — elephant decomposition
    # yields at most two distinct lengths per wave), so the steady state does
    # O(1) float work per slice plus one min/window scan.
    q = [int(v) for v in np.asarray(queued)]
    gl = [float(v) for v in np.asarray(global_local, dtype=np.float64)]
    gr = [float(v) for v in np.asarray(global_remote, dtype=np.float64)]
    bw = [float(v) for v in np.asarray(bandwidth, dtype=np.float64)]
    b0 = [float(v) for v in np.asarray(beta0, dtype=np.float64)]
    b1 = [float(v) for v in np.asarray(beta1, dtype=np.float64)]
    pen = [float(v) for v in np.asarray(penalty, dtype=np.float64)]
    exc = [bool(v) for v in np.asarray(excluded)]
    lens = [int(v) for v in np.asarray(lengths)]
    n_cands = len(q)
    n = len(lens)
    choices = np.full(n, -1, dtype=np.int64)
    queued_at = np.zeros(n, dtype=np.int64)
    inf = float("inf")
    one_plus_gamma = 1.0 + gamma
    rails = range(n_cands)

    def score(d: int, length: int) -> float:
        # same association order as the scalar path: (A + gl) + gr, then +L
        return pen[d] * (b0[d] + b1[d] * (((q[d] + gl[d]) + gr[d]) + length) / bw[d])

    s: list = []
    cur_len = None
    for k in range(n):
        length = lens[k]
        if length != cur_len:
            cur_len = length
            s = [inf if exc[d] else score(d, length) for d in rails]
        s_min = min(s)
        if s_min == inf:
            # soft exclusion must not deadlock (see TentPolicy.choose):
            # re-score the raw local cost model ignoring exclusion
            fb = [pen[d] * (b0[d] + b1[d] * (q[d] + length) / bw[d]) for d in rails]
            fb_min = min(fb)
            if fb_min == inf:
                break  # tier-infeasible: this and all later slices are -1
            window = [d for d in rails if fb[d] <= one_plus_gamma * fb_min]
            chosen = window[rr % len(window)]
            rr += 1
            q[chosen] += length  # line 11: A_d* += L
            if not exc[chosen]:
                s[chosen] = score(chosen, length)
        else:
            threshold = one_plus_gamma * s_min
            window = [d for d in rails if s[d] <= threshold]
            chosen = window[rr % len(window)]
            rr += 1
            q[chosen] += length  # line 11: A_d* += L
            s[chosen] = score(chosen, length)  # only the charged rail moved
        choices[k] = chosen
        queued_at[k] = q[chosen]
    return choices, queued_at, np.asarray(q, dtype=np.int64), rr


# ---------------------------------------------------------------------------
# Vectorized scoring (jnp) — parity-tested mirrors of the scalar policy and
# the numpy wave kernel, for batch scoring in the JAX-side serving planner
# and accelerator-resident scheduling experiments. Note: bit-exact parity
# with the float64 scalar path requires running these under
# `jax.experimental.enable_x64` (the parity tests do); at float32 the gamma
# window can round differently on exact ties.
# ---------------------------------------------------------------------------

# Kernel-twin registry for the `twin-drift` lint rule: every public *_jnp
# kernel maps to its numpy twin; a [target, reason] entry waives the
# parameter-name match where the two sides expose deliberately different
# APIs (object/store views vs flat arrays).
__numpy_twins__ = {
    "tent_scores_jnp": ["TentPolicy.scores",
                        "candidate-object API vs flat array inputs"],
    "tent_choose_jnp": ["TentPolicy.choose",
                        "candidate-object API vs flat array inputs"],
    "tent_choose_wave_jnp": "tent_choose_wave",
    "tent_on_complete_many_jnp": [
        "TelemetryStore.on_complete_many",
        "carries EWMA state as arrays; the twin reads the store's views"],
    "tent_choose_wave_padded_jnp": [
        "tent_choose_wave",
        "padded fixed-shape variant adds the `valid` mask"],
}


def tent_scores_jnp(queued, bandwidth, beta0, beta1, penalty, length):
    """score_d = P_tier(d) * (beta0_d + beta1_d * (A_d + L) / B_d)."""
    import jax.numpy as jnp

    queued = jnp.asarray(queued, dtype=float)
    bandwidth = jnp.asarray(bandwidth, dtype=float)
    beta0 = jnp.asarray(beta0, dtype=float)
    beta1 = jnp.asarray(beta1, dtype=float)
    penalty = jnp.asarray(penalty, dtype=float)
    t_hat = beta0 + beta1 * (queued + length) / bandwidth
    return penalty * t_hat


def tent_choose_jnp(queued, bandwidth, beta0, beta1, penalty, length, rr,
                    gamma=0.05, *, excluded=None):
    """Pure-JAX argmin-with-tolerance-window selection (round-robin among the
    near-ties indexed by `rr`). Returns the chosen device index.

    With `excluded` (a boolean mask) the soft-exclusion semantics of
    `TentPolicy.choose` apply: excluded rails score inf, and when everything
    is excluded the unmasked cost model breaks the deadlock. Returns -1 when
    no candidate is tier-feasible at all (where the scalar policy raises)."""
    import jax.numpy as jnp

    s = tent_scores_jnp(queued, bandwidth, beta0, beta1, penalty, length)
    if excluded is not None:
        masked = jnp.where(jnp.asarray(excluded, dtype=bool), jnp.inf, s)
        # all-excluded fallback: ignore the mask, keep the cost model
        s = jnp.where(jnp.isinf(jnp.min(masked)), s, masked)
    s_min = jnp.min(s)
    in_window = s <= (1.0 + gamma) * s_min
    n_win = jnp.sum(in_window)
    k = jnp.asarray(rr, dtype=jnp.int32) % jnp.maximum(n_win, 1).astype(jnp.int32)
    order = jnp.cumsum(in_window.astype(jnp.int32)) - 1  # rank within window
    match = jnp.where(in_window & (order == k), jnp.arange(s.shape[0]), s.shape[0])
    return jnp.where(jnp.isinf(s_min), -1, jnp.min(match))


def tent_choose_wave_jnp(queued, global_local, global_remote, bandwidth,
                         beta0, beta1, penalty, excluded, lengths, rr,
                         gamma=0.05):
    """One-call JAX twin of `tent_choose_wave`: a `lax.scan` over the wave
    carries the charged queue vector and the round-robin counter, so the
    whole batch is scheduled in a single dispatch. Returns
    `(choices, queued_at_schedule, queued_out, rr_out)` like the numpy
    kernel (infeasible slices yield -1, charge nothing, and leave `rr`
    untouched)."""
    import jax
    import jax.numpy as jnp

    q0 = jnp.asarray(queued, dtype=float)
    glocal = jnp.asarray(global_local, dtype=float)
    gremote = jnp.asarray(global_remote, dtype=float)
    bandwidth = jnp.asarray(bandwidth, dtype=float)
    beta0 = jnp.asarray(beta0, dtype=float)
    beta1 = jnp.asarray(beta1, dtype=float)
    penalty = jnp.asarray(penalty, dtype=float)
    ex = jnp.asarray(excluded, dtype=bool)
    lengths = jnp.asarray(lengths, dtype=float)
    arange = jnp.arange(q0.shape[0])

    def step(carry, length):
        q, rr_ = carry
        q_eff = (q + glocal) + gremote
        s = penalty * (beta0 + beta1 * (q_eff + length) / bandwidth)
        s = jnp.where(ex, jnp.inf, s)
        fallback = penalty * (beta0 + beta1 * (q + length) / bandwidth)
        s = jnp.where(jnp.isinf(jnp.min(s)), fallback, s)
        s_min = jnp.min(s)
        ok = jnp.isfinite(s_min)
        in_window = s <= (1.0 + gamma) * s_min
        n_win = jnp.sum(in_window)
        k = (rr_ % jnp.maximum(n_win, 1)).astype(jnp.int32)
        order = jnp.cumsum(in_window.astype(jnp.int32)) - 1
        match = jnp.where(in_window & (order == k), arange, s.shape[0])
        chosen = jnp.min(match)
        safe = jnp.where(ok, chosen, 0)
        q = q.at[safe].add(jnp.where(ok, length, 0.0))
        return (q, rr_ + ok.astype(rr_.dtype)), (
            jnp.where(ok, chosen, -1), jnp.where(ok, q[safe], 0.0))

    (q_out, rr_out), (choices, queued_at) = jax.lax.scan(
        step, (q0, jnp.asarray(rr, dtype=jnp.int32)), lengths)
    return choices, queued_at, q_out, rr_out


def tent_on_complete_many_jnp(beta0, beta1, queued, ewma_service, completions,
                              ewma_alpha, beta0_alpha, bandwidth,
                              slots, lengths, queued_at, t_obs):
    """One-call JAX twin of `TelemetryStore.on_complete_many`: a `lax.scan`
    over the completion batch applies the Eq. 1 EWMA feedback update one
    completion at a time with `.at[slot]` scatters, so repeated slots within
    a batch see exactly the sequential per-slot recurrence the scalar
    `LinkTelemetry.on_complete` produces (parity is bit-exact under
    `jax.experimental.enable_x64`, like the other kernels in this section).
    Array arguments are full per-slot state vectors; `slots`/`lengths`/
    `queued_at`/`t_obs` describe the batch in drain order. Returns the
    updated `(beta0, beta1, queued, ewma_service, completions)` arrays."""
    import jax
    import jax.numpy as jnp

    b0 = jnp.asarray(beta0, dtype=float)
    b1 = jnp.asarray(beta1, dtype=float)
    q = jnp.asarray(queued, dtype=float)
    ew = jnp.asarray(ewma_service, dtype=float)
    comp = jnp.asarray(completions)
    alpha = jnp.asarray(ewma_alpha, dtype=float)
    b0a = jnp.asarray(beta0_alpha, dtype=float)
    bw = jnp.asarray(bandwidth, dtype=float)
    batch = (jnp.asarray(slots, dtype=jnp.int32),
             jnp.asarray(lengths, dtype=float),
             jnp.asarray(queued_at, dtype=float),
             jnp.asarray(t_obs, dtype=float))

    def step(carry, inp):
        b0_, b1_, q_, ew_, comp_ = carry
        d, length, qas, tob = inp
        # Every EWMA blend below is a `u*v + w*z` chain. Inside the
        # compiled scan body, a multiply feeding an add/sub gets contracted
        # into a single-rounded fma, breaking bit-parity with the scalar
        # numpy recurrence by one ulp (optimization_barrier does NOT stop
        # this — the backend contracts through it). Dividing each product
        # by `one` — a traced value the compiler cannot fold, always
        # exactly 1.0, and division by 1.0 is exact — forces a separate
        # IEEE rounding per product: a division result feeding an add is
        # not a contraction candidate.
        one = jnp.where(d >= 0, 1.0, 2.0)
        a = alpha[d]
        x = (qas + length) / bw[d]
        sample = jnp.clip(
            (tob - b0_[d]) / jnp.where(x > 0, x, 1.0), 0.05, 1e4)
        b1d = jnp.where(
            x > 0,
            ((1 - a) * b1_[d]) / one + (a * sample) / one,
            b1_[d])
        resid = jnp.maximum(0.0, tob - (b1d * x) / one)
        b0d = ((1 - b0a[d]) * b0_[d]) / one + (b0a[d] * resid) / one
        return (
            b0_.at[d].set(b0d),
            b1_.at[d].set(b1d),
            q_.at[d].set(jnp.maximum(0.0, q_[d] - length)),
            ew_.at[d].set(((1 - a) * ew_[d]) / one + (a * tob) / one),
            comp_.at[d].add(1),
        ), None

    (b0, b1, q, ew, comp), _ = jax.lax.scan(step, (b0, b1, q, ew, comp), batch)
    return b0, b1, q, ew, comp


def tent_choose_wave_padded_jnp(queued, global_local, global_remote, bandwidth,
                                beta0, beta1, penalty, excluded, lengths,
                                valid, rr, gamma):
    """Fixed-shape variant of `tent_choose_wave_jnp` for the jitted engine
    core (`repro.core.jit_core`): both axes are padded up to a shape bucket
    so one compiled kernel serves every wave of a scenario.

    Padded *candidates* carry `penalty=inf` and `excluded=True`: they score
    inf under the normal mask and inf again under the all-excluded fallback
    (the raw cost model keeps the inf penalty), so they can never enter the
    gamma window. Padded *slices* are masked by `valid`: they charge
    nothing, leave the round-robin counter untouched, and emit
    choice -1 / queued_at 0 — the caller slices them off. On the valid
    prefix the outputs are bit-identical to the unpadded twin, and
    therefore to the numpy `tent_choose_wave`, under
    `jax.experimental.enable_x64`."""
    import jax
    import jax.numpy as jnp

    q0 = jnp.asarray(queued, dtype=float)
    glocal = jnp.asarray(global_local, dtype=float)
    gremote = jnp.asarray(global_remote, dtype=float)
    bandwidth = jnp.asarray(bandwidth, dtype=float)
    beta0 = jnp.asarray(beta0, dtype=float)
    beta1 = jnp.asarray(beta1, dtype=float)
    penalty = jnp.asarray(penalty, dtype=float)
    ex = jnp.asarray(excluded, dtype=bool)
    lengths = jnp.asarray(lengths, dtype=float)
    valid = jnp.asarray(valid, dtype=bool)
    arange = jnp.arange(q0.shape[0])

    def step(carry, inp):
        q, rr_ = carry
        length, v = inp
        q_eff = (q + glocal) + gremote
        s = penalty * (beta0 + beta1 * (q_eff + length) / bandwidth)
        s = jnp.where(ex, jnp.inf, s)
        fallback = penalty * (beta0 + beta1 * (q + length) / bandwidth)
        s = jnp.where(jnp.isinf(jnp.min(s)), fallback, s)
        s_min = jnp.min(s)
        ok = jnp.isfinite(s_min) & v
        in_window = s <= (1.0 + gamma) * s_min
        n_win = jnp.sum(in_window)
        k = (rr_ % jnp.maximum(n_win, 1)).astype(jnp.int32)
        order = jnp.cumsum(in_window.astype(jnp.int32)) - 1
        match = jnp.where(in_window & (order == k), arange, s.shape[0])
        chosen = jnp.min(match)
        safe = jnp.where(ok, chosen, 0)
        q = q.at[safe].add(jnp.where(ok, length, 0.0))
        return (q, rr_ + ok.astype(rr_.dtype)), (
            jnp.where(ok, chosen, -1), jnp.where(ok, q[safe], 0.0))

    (q_out, rr_out), (choices, queued_at) = jax.lax.scan(
        step, (q0, jnp.asarray(rr, dtype=jnp.int32)), (lengths, valid))
    return choices, queued_at, q_out, rr_out
