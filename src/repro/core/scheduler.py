"""Slice scheduling policies.

`TentPolicy` implements the paper's Algorithm 1 (telemetry-driven slice
scheduling) exactly: score every reachable candidate with the predictive
cost model times a topology-tier penalty, keep the candidates within a
tolerance window gamma of the best score, and round-robin among them; then
charge the chosen device's local queue.

The baseline policies reproduce the engines the paper compares against:
  * RoundRobinPolicy  — Mooncake TE's state-blind fixed-size striping (§2.2)
  * HashPolicy        — Mooncake TE's hashing variant
  * StaticBest2Policy — NIXL/UCX: stripe across the statically best K NICs
  * PinnedPolicy      — UCCL-P2P: each memory region is bound to one NIC

All policies share the same interface so the engine (and TEBench) can swap
them without touching anything else — that swap *is* the paper's ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .telemetry import LinkTelemetry, TelemetryStore
from .topology import DEFAULT_TIER_PENALTY
from .types import NO_ELIGIBLE_DEVICE, TentError


@dataclasses.dataclass
class Candidate:
    """One schedulable device (local link) with its affinity tier and, for
    two-resource paths, the remote endpoint's telemetry. The remote side
    carries the cluster-level signals: diffused receiver load and failure
    rumors from peer engines (paper §4.2)."""

    telemetry: LinkTelemetry
    tier: int
    remote: Optional[LinkTelemetry] = None

    @property
    def link_id(self) -> int:
        return self.telemetry.desc.link_id


class Policy:
    name = "abstract"

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        raise NotImplementedError

    def reset(self) -> None:  # pragma: no cover - most policies are stateless
        pass


class TentPolicy(Policy):
    """Algorithm 1: Telemetry-Driven Slice Scheduling."""

    name = "tent"

    def __init__(
        self,
        *,
        tier_penalty: Optional[Dict[int, float]] = None,
        gamma: float = 0.05,
        store: Optional[TelemetryStore] = None,
    ):
        self.tier_penalty = dict(tier_penalty or DEFAULT_TIER_PENALTY)
        self.gamma = gamma
        self.store = store
        self._rr = 0

    def scores(self, candidates: Sequence[Candidate], length: int) -> List[float]:
        out = []
        for c in candidates:
            tl = c.telemetry
            if tl.excluded or (c.remote is not None and c.remote.excluded):
                # soft exclusion (paper §4.3); a remote exclusion typically
                # arrives as a failure rumor from a peer engine (§4.2)
                out.append(float("inf"))
                continue
            queued = (
                self.store.effective_queue(tl) if self.store is not None else float(tl.queued_bytes)
            )
            if self.store is not None and c.remote is not None:
                # diffused receiver-side pressure: other engines' in-flight
                # bytes converging on the remote endpoint this path pairs with
                queued += self.store.remote_pressure(c.remote.desc.link_id)
            t_hat = tl.beta0 + tl.beta1 * (queued + length) / tl.desc.bandwidth
            out.append(self.tier_penalty.get(c.tier, float("inf")) * t_hat)
        return out

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        if not candidates:
            raise TentError(NO_ELIGIBLE_DEVICE, "empty candidate set")
        s = self.scores(candidates, length)
        s_min = min(s)
        if s_min == float("inf"):
            # Soft exclusion must not deadlock: when every rail is excluded
            # (e.g. a single-link hop under degradation), fall back to the
            # cost model over tier-feasible rails, ignoring exclusion.
            s = [
                self.tier_penalty.get(c.tier, float("inf"))
                * (c.telemetry.beta0 + c.telemetry.beta1
                   * (c.telemetry.queued_bytes + length) / c.telemetry.desc.bandwidth)
                for c in candidates
            ]
            s_min = min(s)
            if s_min == float("inf"):
                raise TentError(NO_ELIGIBLE_DEVICE, "no tier-feasible candidates")
        window = [c for c, sc in zip(candidates, s) if sc <= (1 + self.gamma) * s_min]
        chosen = window[self._rr % len(window)]
        self._rr += 1
        chosen.telemetry.on_schedule(length)  # line 11: A_d* += L
        return chosen


class RoundRobinPolicy(Policy):
    """Mooncake TE-style state-blind striping: fixed rotation over the rails
    permitted by static NUMA priority, ignoring congestion signals."""

    name = "round_robin"

    def __init__(self, *, max_tier: int = 3):
        self.max_tier = max_tier
        self._rr = 0

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        # state-blind: no exclusion filtering (TE has no telemetry loop)
        elig = [c for c in candidates if c.tier <= self.max_tier]
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no round-robin candidates")
        chosen = elig[self._rr % len(elig)]
        self._rr += 1
        chosen.telemetry.on_schedule(length)
        return chosen


class HashPolicy(Policy):
    """Static hashing on the slice ordinal (Mooncake TE hashing mode)."""

    name = "hash"

    def __init__(self) -> None:
        self._n = 0

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        elig = list(candidates)  # state-blind
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no hash candidates")
        self._n += 1
        idx = (self._n * 2654435761) % len(elig)
        chosen = elig[idx]
        chosen.telemetry.on_schedule(length)
        return chosen


class StaticBest2Policy(Policy):
    """NIXL/UCX-style: rank NICs by static transport properties and stripe
    large transfers over the best K only; small blocks use a single NIC."""

    name = "static_best2"

    def __init__(self, *, k: int = 2, multirail_threshold: int = 8 * 1024 * 1024):
        self.k = k
        self.multirail_threshold = multirail_threshold
        self._rr = 0

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        elig = list(candidates)  # static transport properties only
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no static candidates")
        ranked = sorted(elig, key=lambda c: (c.tier, -c.telemetry.desc.bandwidth, c.link_id))
        if length < self.multirail_threshold:
            chosen = ranked[0]
        else:
            top = ranked[: self.k]
            chosen = top[self._rr % len(top)]
            self._rr += 1
        chosen.telemetry.on_schedule(length)
        return chosen


class PinnedPolicy(Policy):
    """UCCL-P2P-style: each registered region is pinned to exactly one NIC
    (its tier-1 / lowest-id rail); no cross-NIC aggregation."""

    name = "pinned"

    def choose(self, candidates: Sequence[Candidate], length: int) -> Candidate:
        elig = list(candidates)  # fixed region->NIC binding
        if not elig:
            raise TentError(NO_ELIGIBLE_DEVICE, "no pinned candidates")
        chosen = min(elig, key=lambda c: (c.tier, c.link_id))
        chosen.telemetry.on_schedule(length)
        return chosen


POLICIES = {
    p.name: p
    for p in (TentPolicy, RoundRobinPolicy, HashPolicy, StaticBest2Policy, PinnedPolicy)
}


def make_policy(name: str, **kwargs) -> Policy:
    try:
        return POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; have {sorted(POLICIES)}") from None


# ---------------------------------------------------------------------------
# Vectorized scoring (jnp) — used for parity tests and for batch scoring in
# the JAX-side serving planner. Mirrors TentPolicy.scores exactly.
# ---------------------------------------------------------------------------

def tent_scores_jnp(queued, bandwidth, beta0, beta1, penalty, length):
    """score_d = P_tier(d) * (beta0_d + beta1_d * (A_d + L) / B_d)."""
    import jax.numpy as jnp

    queued = jnp.asarray(queued, dtype=jnp.float32)
    bandwidth = jnp.asarray(bandwidth, dtype=jnp.float32)
    beta0 = jnp.asarray(beta0, dtype=jnp.float32)
    beta1 = jnp.asarray(beta1, dtype=jnp.float32)
    penalty = jnp.asarray(penalty, dtype=jnp.float32)
    t_hat = beta0 + beta1 * (queued + length) / bandwidth
    return penalty * t_hat


def tent_choose_jnp(queued, bandwidth, beta0, beta1, penalty, length, rr, gamma=0.05):
    """Pure-JAX argmin-with-tolerance-window selection (round-robin among the
    near-ties indexed by `rr`). Returns the chosen device index."""
    import jax.numpy as jnp

    s = tent_scores_jnp(queued, bandwidth, beta0, beta1, penalty, length)
    s_min = jnp.min(s)
    in_window = s <= (1.0 + gamma) * s_min
    n_win = jnp.sum(in_window)
    k = jnp.asarray(rr, dtype=jnp.int32) % jnp.maximum(n_win, 1).astype(jnp.int32)
    order = jnp.cumsum(in_window.astype(jnp.int32)) - 1  # rank within window
    match = jnp.where(in_window & (order == k), jnp.arange(s.shape[0]), s.shape[0])
    return jnp.min(match)
