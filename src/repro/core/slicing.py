"""Slice decomposition (paper §4.2 "Slice Decomposition").

Elephant flows are split into slices with a configurable minimum size (64 KB
by default): small enough that no slice holds a rail for long (HoL
mitigation), large enough to amortize enqueue/completion costs. For extremely
large requests the total slice count is capped to bound control-plane
overhead. Every slice carries an *absolute* destination offset so that
out-of-order completion and idempotent re-execution need no CPU-side
reordering (paper §4.3 / §4.4).
"""
from __future__ import annotations

from typing import List

from .types import Slice, TransferRequest, next_slice_id

DEFAULT_SLICE_BYTES = 64 * 1024
DEFAULT_MAX_SLICES = 512


def decompose(
    req: TransferRequest,
    batch_id: int,
    *,
    slice_bytes: int = DEFAULT_SLICE_BYTES,
    max_slices: int = DEFAULT_MAX_SLICES,
) -> List[Slice]:
    """Split one declarative transfer into scheduling slices.

    Invariants (property-tested): slices tile [0, length) exactly, without
    overlap, preserving the src->dst offset correspondence; every slice is
    at least `slice_bytes` long except possibly when length < slice_bytes;
    at most `max_slices` slices are produced.
    """
    if slice_bytes <= 0:
        raise ValueError("slice_bytes must be positive")
    if max_slices <= 0:
        raise ValueError("max_slices must be positive")
    length = req.length
    n = min(max(1, length // slice_bytes), max_slices)
    base = length // n
    rem = length % n
    transfer_id, src_segment, dst_segment = req.transfer_id, req.src_segment, req.dst_segment
    src_base, dst_base = req.src_offset, req.dst_offset
    slices: List[Slice] = []
    append = slices.append
    off = 0
    for i in range(n):
        ln = base + (1 if i < rem else 0)
        append(Slice(next_slice_id(), transfer_id, batch_id,
                     src_segment, src_base + off, dst_segment, dst_base + off, ln))
        off += ln
    assert off == length
    return slices
