"""Core value types for the TENT data-movement engine.

The vocabulary here mirrors the paper (§3): *segments* name data, *slices*
are the unit of scheduling and isolation, *batches* are the unit of
application-visible completion.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

# ---------------------------------------------------------------------------
# Identifiers
# ---------------------------------------------------------------------------

_batch_ids = itertools.count(1)
_slice_ids = itertools.count(1)
_transfer_ids = itertools.count(1)


def next_batch_id() -> int:
    return next(_batch_ids)


def next_slice_id() -> int:
    return next(_slice_ids)


def next_transfer_id() -> int:
    return next(_transfer_ids)


class MemoryKind(enum.Enum):
    """Where a segment's bytes physically live (paper Fig. 4)."""

    HOST_DRAM = "host_dram"
    DEVICE_HBM = "device_hbm"
    FILE = "file"  # SSD / NVMe-oF via io_uring-style backend


class LinkClass(enum.Enum):
    """Physical interconnect classes unified by TENT (paper Fig. 1)."""

    RDMA = "rdma"  # multi-rail RoCE / IB NICs
    NVLINK = "nvlink"  # intra-node GPU-GPU
    MNNVL = "mnnvl"  # rack-scale multi-node NVLink
    PCIE = "pcie"  # host<->device staging hops
    TCP = "tcp"  # fallback
    SHM = "shm"  # intra-node host-host
    STORAGE = "storage"  # NVMe / io_uring lanes
    UB = "ub"  # Ascend unified bus (portability target)


@dataclasses.dataclass(frozen=True)
class Location:
    """Physical placement of a buffer: node, device, NUMA domain."""

    node: int
    kind: MemoryKind
    device: int = 0  # GPU ordinal for HBM, socket for DRAM, lun for FILE
    numa: int = 0

    def same_node(self, other: "Location") -> bool:
        return self.node == other.node


@dataclasses.dataclass(frozen=True)
class TransferRequest:
    """One entry of a declarative BatchTransfer: pure intent, no bindings."""

    transfer_id: int
    src_segment: int
    src_offset: int
    dst_segment: int
    dst_offset: int
    length: int

    def __post_init__(self) -> None:
        if self.length <= 0:
            raise ValueError(f"transfer length must be positive, got {self.length}")
        if self.src_offset < 0 or self.dst_offset < 0:
            raise ValueError("offsets must be non-negative")


class SliceState(enum.Enum):
    PENDING = "pending"
    INFLIGHT = "inflight"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass(slots=True)
class Slice:
    """Unit of scheduling/isolation. Writes to an *absolute* destination
    offset so re-execution is idempotent (paper §4.3)."""

    slice_id: int
    transfer_id: int
    batch_id: int
    src_segment: int
    src_offset: int
    dst_segment: int
    dst_offset: int
    length: int
    # --- execution state ---
    state: SliceState = SliceState.PENDING
    attempts: int = 0
    hop: int = 0  # current hop index for staged routes
    route_idx: int = 0  # which plan option this slice was issued on
    submitted_at: float = 0.0
    scheduled_link: Optional[int] = None
    completed_at: float = 0.0


class BatchState(enum.Enum):
    OPEN = "open"
    SUBMITTED = "submitted"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass
class TentError(Exception):
    code: str
    detail: str = ""

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"TentError({self.code}: {self.detail})"


NO_ELIGIBLE_DEVICE = "NoEligibleDevice"
UNREACHABLE = "Unreachable"
EXHAUSTED_RETRIES = "ExhaustedRetries"
