"""Phase 1: Dynamic Orchestration (paper §4.1).

The orchestrator resolves a declarative transfer into a *transport plan*: a
ranked list of route options (direct backends or synthesized staged routes),
each annotated with tier info. Binding is late — the plan retains multiple
candidates so later phases can steer slices away from congested/failed rails
and substitute whole backends without application involvement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .scheduler import Candidate
from .segments import Segment
from .transports import TransportBackend, WirePath
from .types import Location, MemoryKind, TentError, UNREACHABLE


@dataclasses.dataclass(frozen=True)
class Stage:
    """One hop of a (possibly multi-hop) route."""

    backend: str
    src: Location
    dst: Location


@dataclasses.dataclass
class RouteOption:
    """A complete way to realize the transfer: one or more stages, ranked by
    nominal aggregate bandwidth of its bottleneck stage."""

    stages: List[Stage]
    rank_bandwidth: float

    @property
    def direct(self) -> bool:
        return len(self.stages) == 1

    @property
    def backend_names(self) -> List[str]:
        return [s.backend for s in self.stages]


@dataclasses.dataclass
class TransportPlan:
    src: Location
    dst: Location
    options: List[RouteOption]  # ranked best-first
    route_idx: int = 0  # advanced by backend substitution (paper §4.3)

    @property
    def current(self) -> RouteOption:
        return self.options[self.route_idx]

    def substitute(self) -> bool:
        """Promote the next-best transport. Returns False when exhausted."""
        if self.route_idx + 1 < len(self.options):
            self.route_idx += 1
            return True
        return False


@dataclasses.dataclass
class StageCandidates:
    """The cached, array-annotated candidate set for one plan stage.

    A `Stage` is a pure (backend, src, dst) value, so its wire paths — and
    therefore its schedulable candidate set — are a static function of the
    topology. The engine builds this once per distinct stage and reuses it
    for every slice, instead of re-enumerating paths and re-allocating
    `Candidate` objects per slice as the pre-wave hot path did. Alongside
    the object lists (still consumed by the scalar policies and the retry
    chooser) it carries:

      * `path_by_link` — the link-id → WirePath index (O(1) lookup where
        `TentEngine._issue` used to linearly scan the path list);
      * per-candidate numpy arrays (store slots, bandwidth, tier penalty,
        remoteness masks) — everything `TentPolicy.choose_wave` needs to
        gather a wave's telemetry straight out of the store's
        struct-of-arrays state. The `local_slot`/`bandwidth` columns also
        seed each posted slice's `_InflightSlice` (slot + Eq. 1 prediction),
        which is what lets the batched completion drain gather a whole
        run's telemetry without ever re-resolving links;
      * `extra_latency` — the per-path submission latency with the engine's
        amortized posting overhead folded in, precomputed so the wave post
        loop does no arithmetic per slice.
    """

    stage: Stage
    paths: List[WirePath]
    cands: List[Candidate]
    path_by_link: Dict[int, WirePath]
    local_slot: np.ndarray  # store slots of the local (schedulable) links
    remote_slot_safe: np.ndarray  # remote store slots, 0 where pathless
    has_remote: np.ndarray  # bool mask: which candidates pair a remote NIC
    remote_any: bool
    local_links: Tuple[int, ...]
    remote_links: Tuple[Optional[int], ...]
    bandwidth: np.ndarray
    penalty: Optional[np.ndarray]  # tier penalties (None for non-TENT policies)
    extra_latency: Tuple[float, ...]
    zeros: np.ndarray


def build_stage_candidates(
    stage: Stage,
    backends: Dict[str, TransportBackend],
    store,
    *,
    tier_penalty: Optional[Dict[int, float]] = None,
    post_overhead: float = 0.0,
) -> StageCandidates:
    """Materialize one stage's candidate set with its scheduling arrays."""
    be = backends[stage.backend]
    paths = be.paths(stage.src, stage.dst)
    cands = [
        Candidate(
            store.ensure(p.local), p.tier,
            remote=store.ensure(p.remote) if p.remote is not None else None,
        )
        for p in paths
    ]
    n = len(paths)
    remote_slots = np.fromiter(
        (c.remote.slot if c.remote is not None else -1 for c in cands),
        dtype=np.int64, count=n)
    inf = float("inf")
    return StageCandidates(
        stage=stage,
        paths=paths,
        cands=cands,
        path_by_link={p.local.link_id: p for p in paths},
        local_slot=np.fromiter((c.telemetry.slot for c in cands),
                               dtype=np.int64, count=n),
        remote_slot_safe=np.maximum(remote_slots, 0),
        has_remote=remote_slots >= 0,
        remote_any=bool((remote_slots >= 0).any()),
        local_links=tuple(p.local.link_id for p in paths),
        remote_links=tuple(
            p.remote.link_id if p.remote is not None else None for p in paths),
        bandwidth=np.fromiter((p.local.bandwidth for p in paths),
                              dtype=np.float64, count=n),
        penalty=(np.fromiter((tier_penalty.get(p.tier, inf) for p in paths),
                             dtype=np.float64, count=n)
                 if tier_penalty is not None else None),
        extra_latency=tuple(p.extra_latency + post_overhead for p in paths),
        zeros=np.zeros(n, dtype=np.float64),
    )


def _staging_host(loc: Location) -> Location:
    """The internal host staging buffer location for a device/file endpoint."""
    if loc.kind == MemoryKind.DEVICE_HBM:
        return Location(node=loc.node, kind=MemoryKind.HOST_DRAM, device=0, numa=loc.numa)
    if loc.kind == MemoryKind.FILE:
        return Location(node=loc.node, kind=MemoryKind.HOST_DRAM, device=0, numa=0)
    return loc


class Orchestrator:
    """Enumerates feasible paths through the heterogeneous fabric and emits
    ranked transport plans. Pure control plane: no bytes move here."""

    def __init__(self, backends: Dict[str, TransportBackend]):
        self.backends = backends

    # -- public -------------------------------------------------------------
    def resolve(self, src_seg: Segment, dst_seg: Segment) -> TransportPlan:
        src, dst = src_seg.location, dst_seg.location
        options = self._direct_options(src, dst) + self._staged_options(src, dst)
        if not options:
            raise TentError(UNREACHABLE, f"no route {src} -> {dst}")
        options.sort(key=lambda o: (-o.rank_bandwidth, len(o.stages)))
        return TransportPlan(src=src, dst=dst, options=options)

    # -- direct -------------------------------------------------------------
    def _direct_options(self, src: Location, dst: Location) -> List[RouteOption]:
        out: List[RouteOption] = []
        for be in self.backends.values():
            if be.feasible(src, dst):
                bw = be.rank_bandwidth(src, dst)
                if bw > 0:
                    out.append(RouteOption([Stage(be.name, src, dst)], bw))
        return out

    # -- staged synthesis (paper §4.1: D2H -> H2H -> H2D pipelined) ----------
    def _staged_options(self, src: Location, dst: Location) -> List[RouteOption]:
        if src.node == dst.node and src.kind == dst.kind == MemoryKind.HOST_DRAM:
            return []
        hops: List[Stage] = []
        cur = src
        if src.kind != MemoryKind.HOST_DRAM:
            stage = _staging_host(src)
            be = self._hop_backend(cur, stage)
            if be is None:
                return []
            hops.append(Stage(be, cur, stage))
            cur = stage
        if cur.node != dst.node:
            remote_host = _staging_host(dst) if dst.kind != MemoryKind.HOST_DRAM else dst
            be = self._hop_backend(cur, remote_host)
            if be is None:
                return []
            hops.append(Stage(be, cur, remote_host))
            cur = remote_host
        if cur != dst:
            be = self._hop_backend(cur, dst)
            if be is None:
                return []
            hops.append(Stage(be, cur, dst))
        if len(hops) <= 1:
            return []
        # Bottleneck stage bandwidth ranks the whole staged route; staged
        # routes are always out-ranked by a feasible direct fast fabric.
        bw = min(self._hop_bw(s) for s in hops) * 0.9
        return [RouteOption(hops, bw)]

    def _hop_backend(self, src: Location, dst: Location) -> str | None:
        best, best_bw = None, 0.0
        for be in self.backends.values():
            if be.feasible(src, dst):
                bw = be.rank_bandwidth(src, dst)
                if bw > best_bw:
                    best, best_bw = be.name, bw
        return best

    def _hop_bw(self, stage: Stage) -> float:
        return self.backends[stage.backend].rank_bandwidth(stage.src, stage.dst)
