"""Deterministic discrete-event fabric simulator.

This is the "hardware" under the transport backends. Each physical link is a
serial resource with nominal bandwidth, base latency, a NUMA-crossing
submission cost, multiplicative time-varying degradation, stochastic service
jitter, and scheduled failures (flaps). Wire operations occupy a source link
and optionally a destination link (two-resource serialization models receiver
incast). The virtual clock makes the paper's latency/throughput/resilience
experiments exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from functools import partial
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import events as OBS
from .calqueue import DEFAULT_WIDTH, CalendarQueue
from .topology import LinkDesc, Topology

# completion callback: (ok, start_time, end_time, error_code) — or, for
# tagged posts, (tag, ok, start_time, end_time, error_code): a wave of ops
# shares ONE callback object and each op carries its own tag, so batched
# posting allocates no per-op closure.
Completion = Callable[[bool, float, float, str], None]

# batched completion sink: (ops, now) — every op in `ops` completed at the
# same virtual timestamp `now`; failed ones carry op.failed=True. Registered
# per shared completion callback via `Fabric.register_completion_sink`.
CompletionSink = Callable[[List["WireOp"], float], None]

# batched post spec: (src_link, dst_link, nbytes, extra_latency, bw_scale, tag)
PostSpec = Tuple[int, Optional[int], int, float, float, object]

_op_ids = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Event-loop configuration, following the `wave`/`wave_complete`/
    `jit_core` discipline: the default is the reference implementation, the
    alternative is bit-identical and regression-pinned.

    event_queue:
        "heap"     — one flat binary heap (the reference; O(log n) per op).
        "calendar" — bucketed timestamp wheel (`repro.core.calqueue`);
                     O(1) amortized push/pop, same pop order byte for byte.
                     Pays off once in-flight events reach 10^4-10^5+
                     (production-scale serving streams); at small scale the
                     constant factors roughly cancel.
    calendar_width:
        Initial bucket width in virtual seconds; 0 = library default. The
        wheel self-resizes, so this is a hint, not a tuning obligation.
    """

    event_queue: str = "heap"
    calendar_width: float = 0.0

    def __post_init__(self):
        if self.event_queue not in ("heap", "calendar"):
            raise ValueError(f"unknown event_queue {self.event_queue!r}")
        if self.calendar_width < 0:
            raise ValueError(
                f"calendar_width must be >= 0, got {self.calendar_width}")


@dataclasses.dataclass(slots=True)
class WireOp:
    op_id: int
    src_link: int
    dst_link: Optional[int]
    nbytes: int
    extra_latency: float
    on_complete: Completion
    start: float = 0.0
    end: float = 0.0
    cancelled: bool = False
    failed: bool = False
    tenant: Optional[str] = None  # posting engine on a shared fabric
    tag: object = None  # shared-callback correlation key (batched posts)


@dataclasses.dataclass
class _DegradeWindow:
    start: float
    end: float
    factor: float  # effective bandwidth multiplier in (0, 1]


# Padding sentinel for dense fault-schedule exports (`fault_window_arrays`):
# a window opening this far in the future is never active, never overlaps a
# transfer, and — unlike inf — survives arithmetic jitter without producing
# NaNs (inf - inf) in the Monte Carlo sweep's window perturbation.
FAR_WINDOW = 1e30


class LinkState:
    """Runtime state of one link."""

    def __init__(self, desc: LinkDesc, jitter: float, rng: np.random.Generator):
        self.desc = desc
        self.busy_until = 0.0
        self.failed = False
        self.fail_windows: List[Tuple[float, float]] = []
        self.degrade_windows: List[_DegradeWindow] = []
        self.jitter = jitter
        self.rng = rng
        self.outstanding: Dict[int, WireOp] = {}
        # telemetry the paper's per-NIC byte counters expose (§5.1.3)
        self.bytes_completed = 0
        self.ops_completed = 0
        self.ops_failed = 0
        # per-tenant split when several engines share the fabric (cluster)
        self.bytes_by_tenant: Dict[str, int] = {}

    def effective_bandwidth(self, t: float) -> float:
        # windows are sorted by start; expired ones are pruned as the clock
        # only moves forward (keeps this O(1) amortized under long schedules)
        while self.degrade_windows and self.degrade_windows[0].end <= t:
            self.degrade_windows.pop(0)
        bw = self.desc.bandwidth
        for w in self.degrade_windows:
            if w.start > t:
                break
            if w.start <= t < w.end:
                bw *= w.factor
        return bw

    def is_failed(self, t: float) -> bool:
        if self.failed:
            return True
        while self.fail_windows and self.fail_windows[0][1] <= t:
            self.fail_windows.pop(0)
        for s, e in self.fail_windows:
            if s > t:
                break
            if s <= t < e:
                return True
        return False


class Fabric:
    """Event-driven cluster fabric: links + virtual clock + fault schedule."""

    FAIL_DETECT_LATENCY = 200e-6  # completion-error surfacing delay (s)

    def __init__(self, topology: Topology, *, seed: int = 0, jitter: float = 0.02,
                 config: Optional["FabricConfig"] = None):
        self.topology = topology
        self.config = config or FabricConfig()
        self.now = 0.0
        # queue entries are (time, seq, item); `item` is either a zero-arg
        # callable or a WireOp whose completion is due (op entries avoid a
        # per-op `partial` allocation and let `step` recognize and group
        # same-timestamp completion runs for the batched drain)
        self._events: List[Tuple[float, int, object]] = []
        # calendar-queue alternative to the heap (FabricConfig.event_queue):
        # same (time, seq) pop order, O(1) amortized at serving-stream scale.
        # Exactly one of the two structures holds events; every loop site
        # branches on `self._cal is None` so the heap path stays verbatim.
        self._cal: Optional[CalendarQueue] = (
            CalendarQueue(self.config.calendar_width or DEFAULT_WIDTH)
            if self.config.event_queue == "calendar" else None)
        self._seq = itertools.count()
        self._rng = np.random.default_rng(seed)
        self._completion_sinks: Dict[object, CompletionSink] = {}
        # flight recorder (repro.obs); None = tracing off. Fabric-side
        # recording is passive (fault events only) and never touches the heap.
        self._rec = None
        self.links: Dict[int, LinkState] = {
            l.link_id: LinkState(l, jitter, np.random.default_rng(seed * 7919 + l.link_id))
            for l in topology.links
        }

    def attach_recorder(self, rec) -> None:
        self._rec = rec

    # -- event loop ----------------------------------------------------------
    def call_at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now:
            t = self.now
        if self._cal is None:
            heapq.heappush(self._events, (t, next(self._seq), fn))
        else:
            self._cal.push((t, next(self._seq), fn))

    def call_after(self, dt: float, fn: Callable[[], None]) -> None:
        self.call_at(self.now + dt, fn)

    def register_completion_sink(self, on_complete, sink: CompletionSink) -> None:
        """Route completions for ops posted with the shared callback
        `on_complete` through `sink(ops, now)` in whole batches: one call
        delivers every op completing at the same virtual timestamp whose
        completion events are adjacent in the queue (heap order is execution
        order, so grouping consecutive events cannot reorder anything
        relative to timers or other callbacks at the same instant). This is
        the drain half of the paper's batched feedback loop — the engine
        registers its multi-completion handler here when
        `EngineConfig.wave_complete` is on."""
        self._completion_sinks[on_complete] = sink

    def step(self) -> bool:
        if self._cal is not None:
            return self._step_calendar()
        events = self._events
        if not events:
            return False
        t, _, fn = heapq.heappop(events)
        self.now = max(self.now, t)
        if type(fn) is WireOp:
            sink = (self._completion_sinks.get(fn.on_complete)
                    if self._completion_sinks else None)
            if sink is None:
                self._complete(fn)
                return True
            batch = [fn]
            cb = fn.on_complete
            while events and events[0][0] == t and type(events[0][2]) is WireOp \
                    and events[0][2].on_complete == cb:
                batch.append(heapq.heappop(events)[2])
            self._complete_batch(batch, sink)
            return True
        fn()
        return True

    def _step_calendar(self) -> bool:
        """`step` on the calendar queue — same semantics, same batch grouping
        of same-timestamp same-callback completion runs, via peek/pop instead
        of heap indexing."""
        cal = self._cal
        if not cal:
            return False
        t, _, fn = cal.pop()
        self.now = max(self.now, t)
        if type(fn) is WireOp:
            sink = (self._completion_sinks.get(fn.on_complete)
                    if self._completion_sinks else None)
            if sink is None:
                self._complete(fn)
                return True
            batch = [fn]
            cb = fn.on_complete
            while cal:
                head = cal.peek()
                if head[0] != t or type(head[2]) is not WireOp \
                        or head[2].on_complete != cb:
                    break
                batch.append(cal.pop()[2])
            self._complete_batch(batch, sink)
            return True
        fn()
        return True

    def run_until_idle(self, *, max_events: int = 50_000_000) -> None:
        n = 0
        while self.step():
            n += 1
            if n > max_events:
                raise RuntimeError("fabric event budget exceeded (livelock?)")

    def run_until(self, t: float) -> None:
        cal = self._cal
        if cal is None:
            while self._events and self._events[0][0] <= t:
                self.step()
        else:
            while cal and cal.peek()[0] <= t:
                self._step_calendar()
        self.now = max(self.now, t)

    @property
    def idle(self) -> bool:
        if self._cal is not None:
            return not self._cal
        return not self._events

    # -- fault / degradation schedule -----------------------------------------
    def schedule_failure(self, link_id: int, at: float, recover_at: float) -> None:
        link = self.links[link_id]
        link.fail_windows.append((at, recover_at))
        link.fail_windows.sort()
        self.call_at(at, lambda: self._on_link_fail(link_id))

    def schedule_degradation(self, link_id: int, at: float, until: float, factor: float) -> None:
        assert 0 < factor <= 1.0
        wins = self.links[link_id].degrade_windows
        wins.append(_DegradeWindow(at, until, factor))
        wins.sort(key=lambda w: w.start)
        rec = self._rec
        if rec is not None:
            # degradations install no heap event (links consult their windows
            # lazily), so record at schedule time with the window's own ts
            rec.append(OBS.DEGRADE, at, {
                "link": link_id, "until": until, "factor": factor})

    def fault_window_arrays(self, link_ids: Optional[Sequence[int]] = None):
        """Dense, padded export of the installed fault program — the fabric
        hook the jitted Monte Carlo core (`repro.core.jit_core`) compiles
        deterministic fault schedules from.

        Returns a dict of float64 arrays over `link_ids` (default: all links
        in id order): `fail_start`/`fail_end` with shape `(L, Kf)` and
        `deg_start`/`deg_end`/`deg_factor` with shape `(L, Kd)`, where
        `Kf`/`Kd` are the per-link maxima (at least 1). Unused rows are
        padded with `FAR_WINDOW` starts/ends (factor 1.0), which no virtual
        timestamp ever reaches. Snapshot semantics: call before driving the
        clock — `is_failed`/`effective_bandwidth` prune expired windows
        lazily, so a mid-run export only sees the remaining schedule."""
        if link_ids is None:
            link_ids = sorted(self.links)
        states = [self.links[lid] for lid in link_ids]
        kf = max(1, max((len(s.fail_windows) for s in states), default=1))
        kd = max(1, max((len(s.degrade_windows) for s in states), default=1))
        n = len(states)
        out = {
            "link_ids": np.asarray(link_ids, dtype=np.int64),
            "fail_start": np.full((n, kf), FAR_WINDOW, dtype=np.float64),
            "fail_end": np.full((n, kf), FAR_WINDOW, dtype=np.float64),
            "deg_start": np.full((n, kd), FAR_WINDOW, dtype=np.float64),
            "deg_end": np.full((n, kd), FAR_WINDOW, dtype=np.float64),
            "deg_factor": np.ones((n, kd), dtype=np.float64),
        }
        for i, st in enumerate(states):
            for k, (s, e) in enumerate(st.fail_windows):
                out["fail_start"][i, k] = s
                out["fail_end"][i, k] = e
            for k, w in enumerate(st.degrade_windows):
                out["deg_start"][i, k] = w.start
                out["deg_end"][i, k] = w.end
                out["deg_factor"][i, k] = w.factor
        return out

    def _on_link_fail(self, link_id: int) -> None:
        """Abort all in-flight ops on the failed link (paper §2.3: a flapping
        NIC stops accepting work requests; in-flight transfers abort)."""
        link = self.links[link_id]
        rec = self._rec
        if rec is not None:
            until = next((e for s, e in link.fail_windows
                          if s <= self.now < e), -1.0)
            rec.append(OBS.LINK_FAIL, self.now, {
                "link": link_id, "until": until,
                "aborted": sum(1 for op in link.outstanding.values()
                               if not op.cancelled)})
        for op in list(link.outstanding.values()):
            if not op.cancelled:
                op.cancelled = True
                op.failed = True
                self._release(op)
                self.call_after(
                    self.FAIL_DETECT_LATENCY, partial(self._deliver_abort, op))
        link.busy_until = self.now

    def _deliver(self, op: WireOp, ok: bool, t0: float, t1: float, err: str) -> None:
        """Invoke an op's completion: tagged ops share one callback and get
        their tag back as the first argument; plain ops keep the legacy
        4-argument shape."""
        if op.tag is not None:
            op.on_complete(op.tag, ok, t0, t1, err)
        else:
            op.on_complete(ok, t0, t1, err)

    def _deliver_abort(self, op: WireOp) -> None:
        self._deliver(op, False, op.start, self.now, "LinkFailed")

    def _deliver_reject(self, op: WireOp) -> None:
        self._deliver(op, False, self.now, self.now, "LinkFailed")

    # -- data path -------------------------------------------------------------
    def post(
        self,
        src_link: int,
        dst_link: Optional[int],
        nbytes: int,
        on_complete: Completion,
        *,
        extra_latency: float = 0.0,
        bw_scale: float = 1.0,
        tenant: Optional[str] = None,
        tag: object = None,
    ) -> int:
        """Post one wire operation. Returns op id. Completion is delivered
        through the event loop (success or failure). `tenant` names the
        posting engine when several share this fabric (per-tenant byte
        accounting; the wire semantics are tenant-blind). With `tag`, the
        completion is invoked as `on_complete(tag, ok, t0, t1, err)` so many
        ops can share one callback object (no per-op closure)."""
        op = WireOp(
            op_id=next(_op_ids), src_link=src_link, dst_link=dst_link,
            nbytes=nbytes, extra_latency=extra_latency, on_complete=on_complete,
            tenant=tenant, tag=tag,
        )
        src = self.links[src_link]
        dst = self.links[dst_link] if dst_link is not None else None

        if src.is_failed(self.now) or (dst is not None and dst.is_failed(self.now)):
            # Immediate error completion after the detection delay.
            op.failed = True
            self.call_after(
                self.FAIL_DETECT_LATENCY, partial(self._deliver_reject, op))
            return op.op_id

        start = max(self.now, src.busy_until, dst.busy_until if dst else 0.0)
        bw = src.effective_bandwidth(start)
        if dst is not None:
            bw = min(bw, dst.effective_bandwidth(start))
        service = nbytes / (bw * bw_scale)
        if src.jitter > 0:
            service *= float(1.0 + abs(src.rng.normal(0.0, src.jitter)))
        lat = src.desc.base_latency + extra_latency
        # the link is busy for the serialization time only; propagation and
        # submission latency pipeline with the next op (real NICs/DMA do)
        busy_end = start + service
        end = busy_end + lat
        op.start, op.end = start, end
        src.busy_until = busy_end
        if dst is not None:
            dst.busy_until = busy_end
        src.outstanding[op.op_id] = op
        if dst is not None:
            dst.outstanding[op.op_id] = op
        self.call_at(end, op)  # op entry == its own completion event
        return op.op_id

    def post_many(
        self,
        specs: Iterable[PostSpec],
        on_complete: Callable,
        *,
        tenant: Optional[str] = None,
    ) -> None:
        """Post a wave of wire operations sharing one tagged completion
        callback: `on_complete(tag, ok, t0, t1, err)` fires once per op.
        Each spec is (src_link, dst_link, nbytes, extra_latency, bw_scale,
        tag). Semantically identical to posting the specs one by one — same
        busy-chain serialization, same jitter-draw order, same event order —
        but with the per-op overheads hoisted out of the loop: no caller
        closures, no per-op attribute lookups, one inlined fast path per op
        (paper §4.4's batched posting). This loop must stay in lockstep with
        `post`; the wave-vs-scalar bit-identity regression pins that.
        """
        specs = list(specs)
        links = self.links
        events = self._events
        cal = self._cal
        seq = self._seq
        now = self.now
        detect = self.FAIL_DETECT_LATENCY

        # Wave-constant precomputation. Failure status only depends on `now`,
        # so one check per distinct link covers the whole wave; and because a
        # seeded numpy Generator yields the same stream batched or one draw
        # at a time, each source link's jitter samples for the wave can be
        # drawn in one call and consumed in post order — the draw sequence
        # every link observes is bit-identical to one-by-one posting.
        failed: Dict[int, bool] = {}
        jitter_counts: Dict[int, int] = {}
        for spec in specs:
            src_link, dst_link = spec[0], spec[1]
            f = failed.get(src_link)
            if f is None:
                f = failed[src_link] = links[src_link].is_failed(now)
            if dst_link is not None:
                fd = failed.get(dst_link)
                if fd is None:
                    fd = failed[dst_link] = links[dst_link].is_failed(now)
                f = f or fd
            if not f and links[src_link].jitter > 0:
                jitter_counts[src_link] = jitter_counts.get(src_link, 0) + 1
        jitter_draws = {
            lid: iter(links[lid].rng.normal(0.0, links[lid].jitter, size=cnt))
            for lid, cnt in jitter_counts.items()
        }

        for spec in specs:
            src_link, dst_link, nbytes, extra_latency, bw_scale, tag = spec
            op = WireOp(
                next(_op_ids), src_link, dst_link, nbytes, extra_latency,
                on_complete, 0.0, 0.0, False, False, tenant, tag,
            )
            src = links[src_link]
            dst = links[dst_link] if dst_link is not None else None

            if failed[src_link] or (dst is not None and failed[dst_link]):
                op.failed = True
                entry = (now + detect, next(seq), partial(self._deliver_reject, op))
                if cal is None:
                    heapq.heappush(events, entry)
                else:
                    cal.push(entry)
                continue

            start = max(now, src.busy_until, dst.busy_until if dst else 0.0)
            bw = src.effective_bandwidth(start)
            if dst is not None:
                bw = min(bw, dst.effective_bandwidth(start))
            service = nbytes / (bw * bw_scale)
            if src.jitter > 0:
                service *= float(1.0 + abs(next(jitter_draws[src_link])))
            lat = src.desc.base_latency + extra_latency
            busy_end = start + service
            end = busy_end + lat
            op.start, op.end = start, end
            src.busy_until = busy_end
            if dst is not None:
                dst.busy_until = busy_end
            src.outstanding[op.op_id] = op
            if dst is not None:
                dst.outstanding[op.op_id] = op
            if cal is None:
                heapq.heappush(events, (max(end, now), next(seq), op))
            else:
                cal.push((max(end, now), next(seq), op))

    def _complete(self, op: WireOp) -> None:
        if op.cancelled:
            return
        # A failure window may have opened after posting but before completion.
        src = self.links[op.src_link]
        dst = self.links[op.dst_link] if op.dst_link is not None else None
        mid_fail = any(
            l.is_failed(op.end) or l.is_failed(op.start)
            for l in ([src] + ([dst] if dst else []))
        )
        self._release(op)
        if mid_fail:
            src.ops_failed += 1
            self._deliver(op, False, op.start, self.now, "LinkFailed")
            return
        src.bytes_completed += op.nbytes
        src.ops_completed += 1
        if op.tenant is not None:
            src.bytes_by_tenant[op.tenant] = src.bytes_by_tenant.get(op.tenant, 0) + op.nbytes
        self._deliver(op, True, op.start, self.now, "")

    def _complete_batch(self, ops: List[WireOp], sink: CompletionSink) -> None:
        """Per-op completion accounting for one same-timestamp batch, then a
        single sink call. Semantically `_complete` run over the batch in heap
        order, with delivery deferred to the end: the per-op bookkeeping
        (mid-failure detection, release, byte counters) touches no state a
        later op's bookkeeping reads, and anything the sink posts lands at a
        strictly later (or later-seq same-time) heap position than every op
        already in this batch — so deferral cannot reorder the simulation.
        The only hoisted work is the failure-window probe: links with no
        schedule at all (the common case) skip the window scan entirely."""
        now = self.now
        links = self.links
        out = None  # lazily diverges from `ops` only when cancelled ops hide
        for idx, op in enumerate(ops):
            if op.cancelled:
                # aborted by a link failure; its delivery is already queued
                if out is None:
                    out = ops[:idx]
                continue
            if out is not None:
                out.append(op)
            src = links[op.src_link]
            dst = links[op.dst_link] if op.dst_link is not None else None
            if src.failed or src.fail_windows or (
                    dst is not None and (dst.failed or dst.fail_windows)):
                mid_fail = any(
                    l.is_failed(op.end) or l.is_failed(op.start)
                    for l in ([src] + ([dst] if dst else []))
                )
            else:
                mid_fail = False
            src.outstanding.pop(op.op_id, None)
            if dst is not None:
                dst.outstanding.pop(op.op_id, None)
            if mid_fail:
                src.ops_failed += 1
                op.failed = True
            else:
                src.bytes_completed += op.nbytes
                src.ops_completed += 1
                if op.tenant is not None:
                    src.bytes_by_tenant[op.tenant] = (
                        src.bytes_by_tenant.get(op.tenant, 0) + op.nbytes)
        if out is None:
            out = ops
        if out:
            sink(out, now)

    def _release(self, op: WireOp) -> None:
        self.links[op.src_link].outstanding.pop(op.op_id, None)
        if op.dst_link is not None:
            self.links[op.dst_link].outstanding.pop(op.op_id, None)

    # -- introspection -----------------------------------------------------------
    def link(self, link_id: int) -> LinkState:
        return self.links[link_id]

    def bytes_by_link(self) -> Dict[int, int]:
        return {i: l.bytes_completed for i, l in self.links.items()}

    def bytes_by_tenant(self) -> Dict[str, int]:
        """Completed bytes per posting engine across all links (multi-engine
        clusters share one fabric; this splits the wire traffic by owner)."""
        out: Dict[str, int] = {}
        for l in self.links.values():
            for tenant, b in l.bytes_by_tenant.items():
                out[tenant] = out.get(tenant, 0) + b
        return out
