"""Phase 3: Proactive dual-layer resilience (paper §4.3).

Link layer: implicit (telemetry: predicted completion times growing vs
peers) and explicit (completion errors) signals drive *soft exclusion* —
the rail's cost becomes infinite and it leaves the candidate set without
heavyweight reconfiguration. A background prober sends lightweight
heartbeat slices to excluded rails and gradually re-admits responsive ones.

Transport layer: when a whole backend turns fatal, the orchestrator promotes
the next-best transport from the Phase-1 plan (backend substitution).

Slice layer: failures surface as per-slice errors; because slices write to
absolute destination offsets, re-execution is idempotent. Retries bypass the
predictive model and prioritize reliability (low tier, few failures), but
their bytes are still charged to the global queue statistics so recovery
traffic cannot starve unrelated flows.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

from ..obs import events as OBS
from .scheduler import Candidate
from .telemetry import TelemetryStore


@dataclasses.dataclass
class HealthConfig:
    degrade_ratio: float = 4.0  # observed/predicted ratio that counts as slow
    degrade_min_time: float = 2e-3  # absolute floor: microsecond noise is not degradation
    degrade_consecutive: int = 3  # consecutive slow slices before exclusion
    probe_interval: float = 0.05  # seconds between heartbeat rounds
    probe_bytes: int = 64 * 1024  # lightweight heartbeat slice
    retry_limit: int = 8


class HealthMonitor:
    """Tracks rail health and drives exclusion / probing / re-admission."""

    def __init__(self, store: TelemetryStore, cfg: HealthConfig):
        self.store = store
        self.cfg = cfg
        self.exclusions = 0
        self.readmissions = 0
        # Cluster hooks: repro.cluster.ClusterMembership subscribes here to
        # turn one engine's local observation into a cluster-wide rumor.
        self.on_exclude: Callable[[int], None] | None = None
        self.on_readmit: Callable[[int], None] | None = None
        # flight recorder (repro.obs); attached with the owning engine's
        # clock and name so health transitions carry virtual timestamps
        self._rec = None
        self._clock = None
        self._owner = ""

    def attach_recorder(self, rec, clock, *, owner: str = "") -> None:
        self._rec = rec
        self._clock = clock
        self._owner = owner

    # -- implicit signal (paper: the telemetry loop naturally detects
    # struggling rails as predicted completion times grow) -------------------
    def observe(self, link_id: int, t_obs: float, t_pred: float) -> None:
        tl = self.store.maybe(link_id)
        if tl is None or tl.excluded:
            return
        if t_pred > 0 and t_obs > self.cfg.degrade_ratio * t_pred and t_obs > self.cfg.degrade_min_time:
            tl.consecutive_slow += 1
            if tl.consecutive_slow >= self.cfg.degrade_consecutive:
                self.exclude(link_id)
        else:
            tl.consecutive_slow = 0

    def observe_many(self, slots, link_ids, t_obs, t_pred) -> bool:
        """`observe` over one completion batch (store slots + link ids +
        observed/predicted times in drain order). The common all-healthy case
        is one vectorized predicate plus one scatter (reset the slow streaks
        of the non-excluded rails); as soon as any sample trips the slow
        predicate the whole batch falls back to per-item `observe` in exact
        order, because consecutive-slow streaks and the exclusion they
        escalate into are order-sensitive. Returns True when any of the
        batch's rails is excluded afterwards (the engine's cue to arm the
        probe timer, exactly like the per-item `tl.excluded` check)."""
        store = self.store
        excluded = store.excluded_arr[slots]
        cfg = self.cfg
        slow = (t_pred > 0) & (t_obs > cfg.degrade_ratio * t_pred) \
            & (t_obs > cfg.degrade_min_time) & ~excluded
        if not slow.any():
            live = ~excluded
            store.slow_arr[slots[live] if not live.all() else slots] = 0
            return bool(excluded.any())
        for lid, to, tp in zip(link_ids, t_obs, t_pred):
            self.observe(lid, float(to), float(tp))
        return bool(store.excluded_arr[slots].any())

    # -- explicit signal (completion failures / timeouts) ---------------------
    def on_explicit_failure(self, link_id: int) -> None:
        tl = self.store.maybe(link_id)
        if tl is not None:
            tl.on_failure()
        self.exclude(link_id, explicit=True)

    def on_path_failure(self, local_link: int, remote_link: int | None) -> None:
        """A wire path died. The engine cannot tell which side failed, so
        both endpoints become suspects: the local rail leaves the candidate
        set as before, and the remote endpoint is soft-excluded too, which
        keeps *other* local rails pairing with it out of the spray. Both are
        probed and re-admitted independently."""
        self.on_explicit_failure(local_link)
        if remote_link is not None:
            self.exclude(remote_link, explicit=True)

    def exclude(self, link_id: int, *, explicit: bool = False) -> bool:
        """Soft exclusion. Only *explicit* failures are worth a cluster
        rumor: a wire error is a fact about the link, while an implicit
        (slow-rail) exclusion is one engine's congestion estimate — that
        signal already travels through the global load table, and gossiping
        it too makes every engine herd off rails that are merely busy.

        The rumor hook fires on *every* explicit failure, even when the link
        is already excluded: an implicit exclusion escalating to a wire
        error is news the cluster has not heard yet (the membership layer
        deduplicates repeat rumors for the same outage).

        Returns True when the link's exclusion state actually changed."""
        tl = self.store.maybe(link_id)
        if tl is None:
            return False
        changed = not tl.excluded
        if changed:
            tl.excluded = True
            self.exclusions += 1
            rec = self._rec
            if rec is not None:
                rec.append(OBS.EXCLUDE, self._clock.now, {
                    "engine": self._owner, "link": link_id,
                    "explicit": explicit})
        elif not explicit:
            return False
        if explicit and self.on_exclude is not None:
            self.on_exclude(link_id)
        return changed

    def readmit(self, link_id: int, *, verified: bool = False) -> bool:
        """Re-admit an excluded rail. Only *verified* readmissions (a probe
        actually succeeded, `verified=True`) are gossiped to the cluster —
        the periodic state reset re-admits blindly by design, and blindly
        clearing a failure rumor cluster-wide mid-outage would make every
        engine take the same failure storm at once.

        Returns True when the link was actually re-admitted."""
        tl = self.store.maybe(link_id)
        if tl is not None and tl.excluded:
            tl.excluded = False
            tl.reset()
            self.readmissions += 1
            rec = self._rec
            if rec is not None:
                rec.append(OBS.READMIT, self._clock.now, {
                    "engine": self._owner, "link": link_id,
                    "verified": verified})
            if verified and self.on_readmit is not None:
                self.on_readmit(link_id)
            return True
        return False

    def apply_remote(self, link_id: int, *, excluded: bool) -> bool:
        """Apply another engine's opinion about a link — the single entry
        point for cluster rumors and anti-entropy merges. Deliberately the
        weakest form of both transitions: a non-explicit exclude and a
        non-verified readmit, so applying remote state can never fire the
        gossip hooks back (no echo) and never outranks this engine's own
        explicit observations. Returns True when local state changed."""
        if excluded:
            return self.exclude(link_id)
        return self.readmit(link_id)

    def excluded_links(self) -> List[int]:
        # one vectorized scan of the store's exclusion array (the monitor's
        # writes land there directly through the LinkTelemetry views)
        return self.store.excluded_link_ids()

    # -- retry path selection (reliability over latency) ----------------------
    def choose_retry(
        self, candidates: Sequence[Candidate], exclude_links: Sequence[int]
    ) -> Candidate | None:
        elig = [
            c
            for c in candidates
            if not c.telemetry.excluded and c.link_id not in exclude_links
            and not (c.remote is not None and c.remote.excluded)
            and c.tier < 99
        ]
        if not elig:
            # everything excluded: retry on the least-failed rail anyway
            # (liveness over latency); the prober will sort the rest out.
            elig = [c for c in candidates if c.link_id not in exclude_links and c.tier < 99]
        if not elig:
            return None
        best = min(elig, key=lambda c: (c.tier, c.telemetry.failures, c.link_id))
        return best
