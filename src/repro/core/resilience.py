"""Phase 3: Proactive dual-layer resilience (paper §4.3).

Link layer: implicit (telemetry: predicted completion times growing vs
peers) and explicit (completion errors) signals drive *soft exclusion* —
the rail's cost becomes infinite and it leaves the candidate set without
heavyweight reconfiguration. A background prober sends lightweight
heartbeat slices to excluded rails and gradually re-admits responsive ones.

Transport layer: when a whole backend turns fatal, the orchestrator promotes
the next-best transport from the Phase-1 plan (backend substitution).

Slice layer: failures surface as per-slice errors; because slices write to
absolute destination offsets, re-execution is idempotent. Retries bypass the
predictive model and prioritize reliability (low tier, few failures), but
their bytes are still charged to the global queue statistics so recovery
traffic cannot starve unrelated flows.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

from .scheduler import Candidate
from .telemetry import TelemetryStore


@dataclasses.dataclass
class HealthConfig:
    degrade_ratio: float = 4.0  # observed/predicted ratio that counts as slow
    degrade_min_time: float = 2e-3  # absolute floor: microsecond noise is not degradation
    degrade_consecutive: int = 3  # consecutive slow slices before exclusion
    probe_interval: float = 0.05  # seconds between heartbeat rounds
    probe_bytes: int = 64 * 1024  # lightweight heartbeat slice
    retry_limit: int = 8


class HealthMonitor:
    """Tracks rail health and drives exclusion / probing / re-admission."""

    def __init__(self, store: TelemetryStore, cfg: HealthConfig):
        self.store = store
        self.cfg = cfg
        self.exclusions = 0
        self.readmissions = 0

    # -- implicit signal (paper: the telemetry loop naturally detects
    # struggling rails as predicted completion times grow) -------------------
    def observe(self, link_id: int, t_obs: float, t_pred: float) -> None:
        tl = self.store.maybe(link_id)
        if tl is None or tl.excluded:
            return
        if t_pred > 0 and t_obs > self.cfg.degrade_ratio * t_pred and t_obs > self.cfg.degrade_min_time:
            tl.consecutive_slow += 1
            if tl.consecutive_slow >= self.cfg.degrade_consecutive:
                self.exclude(link_id)
        else:
            tl.consecutive_slow = 0

    # -- explicit signal (completion failures / timeouts) ---------------------
    def on_explicit_failure(self, link_id: int) -> None:
        tl = self.store.maybe(link_id)
        if tl is not None:
            tl.on_failure()
        self.exclude(link_id)

    def exclude(self, link_id: int) -> None:
        tl = self.store.maybe(link_id)
        if tl is not None and not tl.excluded:
            tl.excluded = True
            self.exclusions += 1

    def readmit(self, link_id: int) -> None:
        tl = self.store.maybe(link_id)
        if tl is not None and tl.excluded:
            tl.excluded = False
            tl.reset()
            self.readmissions += 1

    def excluded_links(self) -> List[int]:
        return [lid for lid, tl in self.store.items() if tl.excluded]

    # -- retry path selection (reliability over latency) ----------------------
    def choose_retry(
        self, candidates: Sequence[Candidate], exclude_links: Sequence[int]
    ) -> Candidate | None:
        elig = [
            c
            for c in candidates
            if not c.telemetry.excluded and c.link_id not in exclude_links
            and c.tier < 99
        ]
        if not elig:
            # everything excluded: retry on the least-failed rail anyway
            # (liveness over latency); the prober will sort the rest out.
            elig = [c for c in candidates if c.link_id not in exclude_links and c.tier < 99]
        if not elig:
            return None
        best = min(elig, key=lambda c: (c.tier, c.telemetry.failures, c.link_id))
        return best
