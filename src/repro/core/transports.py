"""Pluggable transport backends (paper §3.2).

Each fabric (RDMA, NVLink, MNNVL, Ascend UB, TCP, SHM, PCIe staging, file
I/O) is a thin backend conforming to one interface: it declares feasibility
for a (src, dst) location pair and enumerates the *wire paths* (schedulable
local device + remote endpoint + affinity tier) that could carry a slice.
All mechanism (queueing, service time, failures) lives in the fabric
simulator; all policy (which path a slice takes) lives in the scheduler.
That separation is the paper's point: backends stay under ~100 lines here,
mirroring the <800 LOC claim for production backends.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from .topology import LinkDesc, Topology
from .types import LinkClass, Location, MemoryKind


@dataclasses.dataclass(frozen=True)
class WirePath:
    """A concrete way to carry a slice: the local schedulable device, the
    remote endpoint it pairs with (two-resource serialization), the affinity
    tier for Algorithm 1's penalty, and submission-side latency."""

    backend: str
    local: LinkDesc
    remote: Optional[LinkDesc]
    tier: int
    extra_latency: float = 0.0
    bw_factor: float = 1.0  # path-level derating (e.g. cross-NUMA UPI hop)


class TransportBackend:
    name = "abstract"
    link_class: LinkClass = LinkClass.TCP

    def __init__(self, topology: Topology):
        self.topo = topology
        self.spec = topology.spec

    def feasible(self, src: Location, dst: Location) -> bool:
        raise NotImplementedError

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        raise NotImplementedError

    # Nominal aggregate bandwidth for route ranking.
    def rank_bandwidth(self, src: Location, dst: Location) -> float:
        ps = self.paths(src, dst)
        return sum(p.local.bandwidth for p in ps if p.tier <= 2)

    def _src_numa(self, src: Location) -> int:
        if src.kind == MemoryKind.DEVICE_HBM:
            return self.spec.node.gpu_numa(src.device)
        return src.numa


class RdmaBackend(TransportBackend):
    """Multi-rail RDMA. With GPUDirect, HBM endpoints are directly reachable;
    otherwise only host memory is (the orchestrator then stages via PCIe)."""

    name = "rdma"
    link_class = LinkClass.RDMA

    def _endpoint_ok(self, loc: Location) -> bool:
        if loc.kind == MemoryKind.HOST_DRAM:
            return True
        return loc.kind == MemoryKind.DEVICE_HBM and self.spec.has_gpudirect

    def feasible(self, src: Location, dst: Location) -> bool:
        return self._endpoint_ok(src) and self._endpoint_ok(dst) and src.node != dst.node

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        out: List[WirePath] = []
        src_numa = self._src_numa(src)
        for nic in self.topo.rdma_nics(src.node):
            tier = self.topo.nic_tier(src, nic)
            remote = self.topo.remote_nic_for(dst, nic)
            cross = nic.numa != src_numa
            extra = self.spec.cross_numa_latency if cross else 0.0
            bwf = self.spec.cross_numa_bw_factor if cross else 1.0
            out.append(WirePath(self.name, nic, remote, tier, extra, bwf))
        return out


class NvlinkBackend(TransportBackend):
    name = "nvlink"
    link_class = LinkClass.NVLINK

    def feasible(self, src: Location, dst: Location) -> bool:
        return (
            self.spec.has_nvlink
            and src.kind == MemoryKind.DEVICE_HBM
            and dst.kind == MemoryKind.DEVICE_HBM
            and src.node == dst.node
            and src.device != dst.device
        )

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        a = self.topo.nvlink(src.node, src.device)
        b = self.topo.nvlink(dst.node, dst.device)
        if a is None or b is None:
            return []
        return [WirePath(self.name, a, b, 1)]


class MnnvlBackend(TransportBackend):
    """Rack-scale Multi-Node NVLink: GPU-to-GPU only, no host paths (§2.1)."""

    name = "mnnvl"
    link_class = LinkClass.MNNVL

    def feasible(self, src: Location, dst: Location) -> bool:
        return (
            self.spec.has_mnnvl
            and src.kind == MemoryKind.DEVICE_HBM
            and dst.kind == MemoryKind.DEVICE_HBM
            and (src.node, src.device) != (dst.node, dst.device)
        )

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        a = self.topo.mnnvl(src.node, src.device)
        b = self.topo.mnnvl(dst.node, dst.device)
        if a is None or b is None:
            return []
        return [WirePath(self.name, a, b, 1)]


class UbBackend(TransportBackend):
    """Ascend unified-bus fabric (portability target, Table 4)."""

    name = "ub"
    link_class = LinkClass.UB

    def feasible(self, src: Location, dst: Location) -> bool:
        return (
            self.spec.has_ub
            and src.kind == MemoryKind.DEVICE_HBM
            and dst.kind == MemoryKind.DEVICE_HBM
            and (src.node, src.device) != (dst.node, dst.device)
        )

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        a = self.topo.ub(src.node, src.device)
        b = self.topo.ub(dst.node, dst.device)
        if a is None or b is None:
            return []
        return [WirePath(self.name, a, b, 1)]


class PcieBackend(TransportBackend):
    """Host<->device copies within a node (the D2H/H2D hops of staged routes)."""

    name = "pcie"
    link_class = LinkClass.PCIE

    def feasible(self, src: Location, dst: Location) -> bool:
        kinds = {src.kind, dst.kind}
        return (
            src.node == dst.node
            and kinds == {MemoryKind.HOST_DRAM, MemoryKind.DEVICE_HBM}
        )

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        gpu_loc = src if src.kind == MemoryKind.DEVICE_HBM else dst
        host_loc = dst if src.kind == MemoryKind.DEVICE_HBM else src
        link = self.topo.pcie(gpu_loc.node, gpu_loc.device)
        tier = 1 if self.spec.node.gpu_numa(gpu_loc.device) == host_loc.numa else 2
        return [WirePath(self.name, link, None, tier)]


class ShmBackend(TransportBackend):
    name = "shm"
    link_class = LinkClass.SHM

    def feasible(self, src: Location, dst: Location) -> bool:
        return (
            src.node == dst.node
            and src.kind == MemoryKind.HOST_DRAM
            and dst.kind == MemoryKind.HOST_DRAM
        )

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        return [WirePath(self.name, self.topo.shm(src.node), None, 1)]


class TcpBackend(TransportBackend):
    """Legacy fallback: host-to-host over the datacenter network."""

    name = "tcp"
    link_class = LinkClass.TCP

    def feasible(self, src: Location, dst: Location) -> bool:
        return (
            src.node != dst.node
            and src.kind == MemoryKind.HOST_DRAM
            and dst.kind == MemoryKind.HOST_DRAM
        )

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        return [
            WirePath(self.name, self.topo.tcp(src.node), self.topo.tcp(dst.node), 2)
        ]


class FileBackend(TransportBackend):
    """io_uring-style storage lanes. Host<->file on the same node; GPU<->file
    directly when GPUDirect Storage is available (Table 4's GPU->File row)."""

    name = "file"
    link_class = LinkClass.STORAGE

    def feasible(self, src: Location, dst: Location) -> bool:
        kinds = (src.kind, dst.kind)
        if src.node != dst.node or MemoryKind.FILE not in kinds:
            return False
        other = dst.kind if src.kind == MemoryKind.FILE else src.kind
        if other == MemoryKind.HOST_DRAM:
            return True
        return other == MemoryKind.DEVICE_HBM and self.spec.has_gpudirect

    def paths(self, src: Location, dst: Location) -> List[WirePath]:
        return [WirePath(self.name, self.topo.storage(src.node), None, 1)]


ALL_BACKENDS = [
    RdmaBackend,
    NvlinkBackend,
    MnnvlBackend,
    UbBackend,
    PcieBackend,
    ShmBackend,
    TcpBackend,
    FileBackend,
]


def load_backends(topology: Topology) -> dict:
    """Dynamic backend registry (the paper loads these as plugins)."""
    return {cls.name: cls(topology) for cls in ALL_BACKENDS}
