"""Segment abstraction (paper §3.1).

A segment is a logical data region mapped to one or more contiguous buffers,
independent of the storage medium. Applications interact exclusively with
segment identifiers, offsets, and lengths. Internally each segment carries
device-specific metadata (RDMA keys, GPU handles, file descriptors) in a
normalized structure that only the owning backend interprets.

In this reproduction buffers are numpy byte arrays so that transfers move
*real bytes* and data integrity is testable end to end.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import numpy as np

from .types import Location, MemoryKind

_segment_ids = itertools.count(1)


@dataclasses.dataclass
class Buffer:
    """One contiguous region backing (part of) a segment."""

    start: int  # offset of this buffer within the segment
    length: int
    data: np.ndarray  # uint8 view; the actual bytes

    def __post_init__(self) -> None:
        assert self.data.dtype == np.uint8
        assert self.data.size == self.length


@dataclasses.dataclass
class Segment:
    """A logical, transport-agnostic data region (paper Fig. 4)."""

    segment_id: int
    location: Location
    buffers: List[Buffer]
    # Normalized per-backend metadata: backend name -> opaque dict.
    # e.g. {"rdma": {"rkey": ..., "registered_nics": [...]}, "nvlink": {...}}
    backend_metadata: Dict[str, dict] = dataclasses.field(default_factory=dict)
    # Transport capabilities derived from topology at registration time.
    transports: List[str] = dataclasses.field(default_factory=list)
    name: str = ""
    # Phantom segments carry timing/bookkeeping but no backing bytes — used
    # by large-scale simulations where allocating the real pool (tens of GB)
    # is pointless. Data-integrity tests always use materialized segments.
    phantom_length: int = 0

    @property
    def phantom(self) -> bool:
        return self.phantom_length > 0

    @property
    def length(self) -> int:
        if self.phantom:
            return self.phantom_length
        return sum(b.length for b in self.buffers)

    # -- byte access (used by transport backends only; the core engine and
    # applications never touch raw bytes) ----------------------------------
    def read(self, offset: int, length: int) -> np.ndarray:
        self._check_range(offset, length)
        if self.phantom:
            return np.zeros(length, dtype=np.uint8)
        out = np.empty(length, dtype=np.uint8)
        done = 0
        for buf in self.buffers:
            lo = max(offset, buf.start)
            hi = min(offset + length, buf.start + buf.length)
            if lo < hi:
                out[lo - offset : hi - offset] = buf.data[lo - buf.start : hi - buf.start]
                done += hi - lo
        assert done == length
        return out

    def write(self, offset: int, payload: np.ndarray) -> None:
        length = payload.size
        self._check_range(offset, length)
        if self.phantom:
            return
        for buf in self.buffers:
            lo = max(offset, buf.start)
            hi = min(offset + length, buf.start + buf.length)
            if lo < hi:
                buf.data[lo - buf.start : hi - buf.start] = payload[lo - offset : hi - offset]

    def _check_range(self, offset: int, length: int) -> None:
        if offset < 0 or offset + length > self.length:
            raise IndexError(
                f"segment {self.segment_id}: range [{offset}, {offset + length}) "
                f"out of bounds (len={self.length})"
            )


class SegmentManager:
    """Registry of segments plus their metadata lifecycle (paper §3.1).

    The manager is the "global ground truth" consulted by the orchestrator:
    where data resides and which transports remain available. Remote metadata
    retrieval is modelled by the registry being cluster-global (the paper's
    engine fetches it on demand over the control plane).
    """

    def __init__(self) -> None:
        self._segments: Dict[int, Segment] = {}

    def register(
        self,
        location: Location,
        length: int,
        *,
        name: str = "",
        n_buffers: int = 1,
        init: Optional[np.ndarray] = None,
        materialize: bool = True,
    ) -> Segment:
        if length <= 0:
            raise ValueError("segment length must be positive")
        if n_buffers < 1 or n_buffers > length:
            raise ValueError("bad buffer count")
        seg_id = next(_segment_ids)
        if not materialize:
            seg = Segment(segment_id=seg_id, location=location, buffers=[],
                          name=name, phantom_length=length)
            self._segments[seg_id] = seg
            return seg
        buffers: List[Buffer] = []
        # Split into roughly equal contiguous buffers (multi-buffer segments
        # model e.g. per-layer KV page groups registered together).
        base = length // n_buffers
        start = 0
        for i in range(n_buffers):
            blen = base + (length - base * n_buffers if i == n_buffers - 1 else 0)
            data = np.zeros(blen, dtype=np.uint8)
            if init is not None:
                data[:] = init[start : start + blen]
            buffers.append(Buffer(start=start, length=blen, data=data))
            start += blen
        seg = Segment(segment_id=seg_id, location=location, buffers=buffers, name=name)
        self._segments[seg_id] = seg
        return seg

    def deregister(self, segment_id: int) -> None:
        self._segments.pop(segment_id, None)

    def get(self, segment_id: int) -> Segment:
        try:
            return self._segments[segment_id]
        except KeyError:
            raise KeyError(f"unknown segment {segment_id}") from None

    def attach_metadata(self, segment_id: int, backend: str, meta: dict) -> None:
        self.get(segment_id).backend_metadata[backend] = meta

    def set_transports(self, segment_id: int, transports: List[str]) -> None:
        self.get(segment_id).transports = list(transports)

    def all_segments(self) -> List[Segment]:
        return list(self._segments.values())


def host_segment(mgr: SegmentManager, node: int, length: int, *, numa: int = 0, name: str = "") -> Segment:
    return mgr.register(Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa), length, name=name)


def device_segment(mgr: SegmentManager, node: int, gpu: int, length: int, *, numa: int = 0, name: str = "") -> Segment:
    return mgr.register(Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu, numa=numa), length, name=name)


def file_segment(mgr: SegmentManager, node: int, length: int, *, name: str = "") -> Segment:
    return mgr.register(Location(node=node, kind=MemoryKind.FILE, device=0, numa=0), length, name=name)
