"""Fused lax.scan simulation core (`EngineConfig.jit_core` + Monte Carlo).

Two layers share this module:

1. `EngineJitCore` — the engine-side adapter behind `EngineConfig.jit_core`.
   It routes the two telemetry-array kernels of the closed loop — the wave
   chooser (`TentPolicy.choose_wave`) and the batched completion drain
   (`TelemetryStore.on_complete_many`) — through jitted, shape-bucketed
   `lax.scan` kernels (`tent_choose_wave_padded_jnp`,
   `tent_on_complete_many_jnp`). Arrays are padded to power-of-two buckets
   so one compiled kernel serves every wave/drain of a scenario, and all
   kernels run under `jax.experimental.enable_x64`, so results are
   bit-identical to the numpy path (pinned in tests/test_jit_parity.py).
   The scalar/wave Python path stays in charge of everything stateful —
   staged hops, retries, substitutions, app callbacks — exactly as before;
   the adapter only replaces the arithmetic inside two already-batched
   call sites, selected per-batch by an online-tuned crossover that mirrors
   the `WAVE_MIN` tuner.

2. `SprayProgram` / `simulate_spray` — a fully fused model of the spray
   closed loop for Monte Carlo fault sweeps: wave-choose -> busy-chain
   post -> fault check (+ one masked retry) -> completion-ordered EWMA
   drain, all inside one nested `lax.scan` over fixed-shape rail/slice
   arrays, with the fabric's deterministic fault schedule compiled into
   per-rail window arrays (`Fabric.fault_window_arrays`) and per-seed
   jitters applied to fault onset/duration/depth. `vmap` over seed keys
   yields whole healing-time/throughput distributions in one dispatch
   (`spray_sweep`); `simulate_spray_ref` is the op-for-op numpy twin the
   property tests pin the jax path against, bit-exact at float64.

The model the MC layer runs is deliberately the *skeleton* of the engine,
not the engine: one plan stage, uniform slice length, one retry attempt,
round-granular clock advancement. Scenarios that need staged hops, backend
substitution chains, or app callbacks keep the full event-driven
`ScenarioRunner` path — the same scalar-fallback contract the engine-side
adapter follows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from .fabric import FAR_WINDOW
from ..analysis import hot_path
from .scheduler import tent_choose_wave_padded_jnp, tent_on_complete_many_jnp

__all__ = [
    "EngineJitCore",
    "SprayProgram",
    "jax_available",
    "make_draws",
    "simulate_spray_ref",
    "spray_single",
    "spray_sweep",
    "JIT_MIN",
    "JIT_MIN_FLOOR",
    "JIT_MIN_CEIL",
]


def jax_available() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - environment without jax
        return False
    return True


def _x64():
    from jax.experimental import enable_x64

    return enable_x64()


def _bucket(n: int, floor: int = 8) -> int:
    """Next power-of-two shape bucket (>= floor): bounds the number of
    distinct compiled kernel shapes per scenario to O(log max_batch)."""
    return max(floor, 1 << (max(int(n), 1) - 1).bit_length())


# ---------------------------------------------------------------------------
# Engine-side adapter (`EngineConfig.jit_core`)
# ---------------------------------------------------------------------------

# Batches shorter than this stay on the numpy kernels: a jax dispatch costs
# ~10-50x a small numpy gather, so the jitted path only pays off on fat
# waves/drains (elephant scenarios routinely run 64-256). Mirroring the
# WAVE_MIN tuner, the crossover adapts online from the same run-length /
# drain-size EWMAs unless traffic is inconclusive — and because both paths
# compute bit-identical results, the tuner can only ever change cost, never
# a scheduling decision.
JIT_MIN = 32
JIT_MIN_FLOOR = 16
JIT_MIN_CEIL = 64

_ENGINE_KERNELS: Optional[dict] = None


def _engine_kernels() -> dict:
    global _ENGINE_KERNELS
    if _ENGINE_KERNELS is None:
        import jax

        _ENGINE_KERNELS = {
            "choose": jax.jit(tent_choose_wave_padded_jnp),
            "drain": jax.jit(tent_on_complete_many_jnp),
        }
    return _ENGINE_KERNELS


class EngineJitCore:
    """Routes `choose_wave` / `on_complete_many` through jitted fixed-shape
    kernels, bit-identically to the numpy path. Stateless beyond counters
    and the tuned crossover: all telemetry state stays in the store's
    struct-of-arrays, gathered/scattered per call through the telemetry
    transport hooks, so the scalar path can take over at any batch."""

    def __init__(self, policy, store):
        self.policy = policy
        self.store = store
        self.min_batch = JIT_MIN
        self.waves = 0  # batches actually dispatched through the jitted chooser
        self.drains = 0  # batches actually dispatched through the jitted drain

    def tune(self, signal: float) -> None:
        """Online crossover tuning, same shape as `TentEngine._tune_wave_min`
        and driven by the same structural signal (run-length / drain-size
        EWMAs — never wall clock, so it stays deterministic)."""
        if signal >= 2.0 * JIT_MIN:
            self.min_batch = JIT_MIN_FLOOR
        elif signal <= 0.5 * JIT_MIN:
            self.min_batch = JIT_MIN_CEIL
        else:
            self.min_batch = JIT_MIN

    # -- wave chooser --------------------------------------------------------
    @hot_path
    def choose_wave(self, sc, lengths):
        """Jitted twin of `TentPolicy.choose_wave`: same gathers, same
        write-backs, padded to shape buckets. Returns int64
        `(choices, queued_at)` exactly like the numpy kernel."""
        policy, store = self.policy, self.store
        slots = sc.local_slot
        excluded = store.excluded_arr[slots]
        if sc.remote_any:
            excluded = excluded | (
                sc.has_remote & store.excluded_arr[sc.remote_slot_safe])
        if store.global_weight > 0.0:
            glocal = store.foreign_load_array(sc.local_links)
            gremote = store.foreign_load_array(sc.remote_links)
        else:
            glocal = gremote = sc.zeros
        n_c, n_s = len(slots), len(lengths)
        pc, ps = _bucket(n_c), _bucket(n_s)
        # candidate axis: pads score inf in both the masked and the
        # all-excluded-fallback pass (penalty inf + excluded)
        q = np.zeros(pc, dtype=np.float64)
        q[:n_c] = store.queued_arr[slots]
        gl = np.zeros(pc, dtype=np.float64)
        gl[:n_c] = glocal
        gr = np.zeros(pc, dtype=np.float64)
        gr[:n_c] = gremote
        bw = np.ones(pc, dtype=np.float64)
        bw[:n_c] = sc.bandwidth
        b0 = np.zeros(pc, dtype=np.float64)
        b0[:n_c] = store.beta0_arr[slots]
        b1 = np.ones(pc, dtype=np.float64)
        b1[:n_c] = store.beta1_arr[slots]
        pen = np.full(pc, np.inf, dtype=np.float64)
        pen[:n_c] = sc.penalty
        ex = np.ones(pc, dtype=bool)
        ex[:n_c] = excluded
        ln = np.zeros(ps, dtype=np.float64)
        ln[:n_s] = lengths
        valid = np.zeros(ps, dtype=bool)
        valid[:n_s] = True
        kern = _engine_kernels()["choose"]
        with _x64():
            c_j, qa_j, qo_j, rr_j = kern(
                q, gl, gr, bw, b0, b1, pen, ex, ln, valid,
                policy._rr, policy.gamma)
            choices = np.asarray(c_j)[:n_s].astype(np.int64)
            queued_at = np.asarray(qa_j)[:n_s].astype(np.int64)
            queued_out = np.asarray(qo_j)[:n_c].astype(np.int64)
            rr = int(rr_j)
        store.queued_arr[slots] = queued_out  # line 11 charges, applied
        policy._rr = rr
        self.waves += 1
        return choices, queued_at

    # -- completion drain ----------------------------------------------------
    @hot_path
    def on_complete_many(self, slots, lengths, queued_at, t_obs) -> None:
        """Jitted twin of `TelemetryStore.on_complete_many`: full state
        vectors travel through the telemetry transport hooks; batch padding
        scatters into the store's scratch row (slot `n`), which the
        write-back discards."""
        store = self.store
        n = store.n
        ps = _bucket(n + 1)  # >= n+1: row n is the scratch slot
        m = len(slots)
        pm = _bucket(m)
        state = store.gather_complete_state(ps)
        sl = np.full(pm, n, dtype=np.int64)
        sl[:m] = slots
        ln = np.zeros(pm, dtype=np.float64)
        ln[:m] = lengths
        qa = np.zeros(pm, dtype=np.float64)
        qa[:m] = queued_at
        to = np.zeros(pm, dtype=np.float64)
        to[:m] = t_obs
        kern = _engine_kernels()["drain"]
        with _x64():
            b0o, b1o, qo, ewo, co = kern(*state, sl, ln, qa, to)
            out = tuple(np.asarray(a) for a in (b0o, b1o, qo, ewo, co))
        store.scatter_complete_state(*out)
        self.drains += 1


# ---------------------------------------------------------------------------
# Fused Monte Carlo spray model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SprayProgram:
    """Fixed-shape compilation of one spray scenario: D rails (the resolved
    plan stage's candidate paths), `rounds` waves of `wave` slices of
    `length` bytes each, with the fabric's fault/degradation schedule as
    dense per-rail window arrays (src- and dst-side degradations kept
    separate because the fabric takes the min of the two effective
    bandwidths). Built by `repro.scenarios.sweep.compile_spray_program`;
    consumed by `spray_single` / `spray_sweep` / `simulate_spray_ref`."""

    n_rails: int
    rounds: int
    wave: int
    length: float
    gamma: float
    detect: float  # Fabric.FAIL_DETECT_LATENCY
    jitter: float  # per-transfer service-jitter sigma (Fabric jitter)
    bw_score: np.ndarray  # (D,) local-link nominal bw — Algorithm 1 scoring
    bw_src: np.ndarray  # (D,) source-side nominal bw — service time
    bw_dst: np.ndarray  # (D,) dest-side nominal bw (inf when single-ended)
    penalty: np.ndarray  # (D,) tier penalties
    latency: np.ndarray  # (D,) wire latency added after the busy chain
    beta0: np.ndarray  # (D,) EWMA state priors (telemetry cold start)
    beta1: np.ndarray
    ewma_alpha: np.ndarray
    beta0_alpha: np.ndarray
    fail_start: np.ndarray  # (D, Kf) union of src+dst fail windows
    fail_end: np.ndarray
    degs_start: np.ndarray  # (D, Ks) source-side degradations
    degs_end: np.ndarray
    degs_factor: np.ndarray
    degd_start: np.ndarray  # (D, Kd) dest-side degradations
    degd_end: np.ndarray
    degd_factor: np.ndarray

    def __post_init__(self):
        if not np.isfinite(self.penalty).any():
            raise ValueError("SprayProgram needs >= 1 tier-feasible rail")


def _seed_key(base_seed: int, seed_index: int):
    import jax

    return jax.random.fold_in(jax.random.PRNGKey(base_seed), seed_index)


def _draws_jnp(p: SprayProgram, key):
    """Raw per-seed randomness, all drawn up front so the jax sim and the
    numpy ref consume identical bits: window-jitter uniforms in [-1, 1]
    (fault onset/duration, degradation onset/duration/depth) and the
    per-attempt service-jitter multipliers `1 + |N(0, sigma)|` (the fabric's
    per-transfer jitter law)."""
    import jax
    import jax.numpy as jnp

    kf, ks, kd, kj = jax.random.split(key, 4)
    uf = jax.random.uniform(
        kf, (p.n_rails, p.fail_start.shape[1], 2), minval=-1.0, maxval=1.0)
    us = jax.random.uniform(
        ks, (p.n_rails, p.degs_start.shape[1], 3), minval=-1.0, maxval=1.0)
    ud = jax.random.uniform(
        kd, (p.n_rails, p.degd_start.shape[1], 3), minval=-1.0, maxval=1.0)
    # |N| / (1/sigma), NOT 1 + |N|*sigma: XLA sinks this elementwise chain
    # into the consuming scan and FMA-contracts a+b*c there (single
    # rounding), which the eagerly-materialized `make_draws` copy and the
    # numpy twin cannot reproduce. A division result feeding the add is
    # contraction-proof, so eager and jitted draws stay bit-identical.
    inv_sigma = math.inf if p.jitter == 0 else 1.0 / float(p.jitter)
    jm = 1.0 + jnp.abs(
        jax.random.normal(kj, (p.rounds, p.wave, 2))) / inv_sigma
    return uf, us, ud, jm


def make_draws(p: SprayProgram, *, base_seed: int = 0,
               seed_index: int = 0) -> Dict[str, np.ndarray]:
    """Materialized numpy copy of one seed's raw draws — the common input
    feeding both `simulate_spray_ref` and the jax path in parity tests."""
    with _x64():
        uf, us, ud, jm = _draws_jnp(p, _seed_key(base_seed, seed_index))
        return {"uf": np.asarray(uf), "us": np.asarray(us),
                "ud": np.asarray(ud), "jm": np.asarray(jm)}


# Window jitter law (shared, op for op, by both backends): onsets scale
# multiplicatively (a window starting at 0 — e.g. a permanent rail derating
# — stays at 0), durations scale multiplicatively (a "forever" horizon
# stays forever), depths scale and clamp into (0, 1]. fj=0 reproduces the
# declared schedule exactly. Every multiply whose result would feed an add
# is routed through a division instead — same FMA-contraction defense as
# the jm draws above (the scale arithmetic gets fused into the jitted sim).


def _inv_fj(fj: float) -> float:
    return math.inf if fj == 0 else 1.0 / float(fj)


def _jitter_windows_np(start, end, u, fj):
    inv = _inv_fj(fj)
    s = np.maximum(0.0, start * (1.0 + u[..., 0] / inv))
    scale1 = 1.0 + u[..., 1] / inv
    e = s + (end - start) / (1.0 / scale1)
    return s, e


def _select_np(scores, rr, gamma):
    s_min = scores.min()
    in_w = scores <= (1.0 + gamma) * s_min
    n_w = int(in_w.sum())
    k = int(rr) % max(n_w, 1)
    order = np.cumsum(in_w.astype(np.int64)) - 1
    match = np.where(in_w & (order == k),
                     np.arange(scores.shape[0]), scores.shape[0])
    return int(match.min())


def simulate_spray_ref(p: SprayProgram, draws: Dict[str, np.ndarray], *,
                       policy: str = "tent",
                       fault_jitter: float = 0.0) -> Tuple[float, ...]:
    """Numpy twin of the fused jax sim, mirrored operation for operation at
    float64 (the parity tests assert exact equality). Returns
    `(throughput, healing_s, bytes_ok, lost, makespan)`."""
    if policy not in ("tent", "round_robin"):
        raise ValueError(f"unsupported sweep policy {policy!r}")
    D, R, W = p.n_rails, p.rounds, p.wave
    L = float(p.length)
    det = float(p.detect)
    gamma = float(p.gamma)
    fj = float(fault_jitter)
    uf, us, ud, jm = draws["uf"], draws["us"], draws["ud"], draws["jm"]
    inv = _inv_fj(fj)
    fs, fe = _jitter_windows_np(p.fail_start, p.fail_end, uf, fj)
    dss, dse = _jitter_windows_np(p.degs_start, p.degs_end, us, fj)
    dsf = np.clip(p.degs_factor * (1.0 + us[..., 2] / inv), 0.01, 1.0)
    dds, dde = _jitter_windows_np(p.degd_start, p.degd_end, ud, fj)
    ddf = np.clip(p.degd_factor * (1.0 + ud[..., 2] / inv), 0.01, 1.0)
    ext = lambda a, fill: np.concatenate(
        [np.asarray(a, dtype=np.float64), [fill]])
    bw_score = ext(p.bw_score, 1.0)
    bw_src = ext(p.bw_src, 1.0)
    bw_dst = ext(p.bw_dst, 1.0)
    pen = ext(p.penalty, np.inf)
    lat = ext(p.latency, 0.0)
    alpha = ext(p.ewma_alpha, 0.0)
    b0a = ext(p.beta0_alpha, 0.0)
    b0 = ext(p.beta0, 0.0)
    b1 = ext(p.beta1, 1.0)
    q = np.zeros(D + 1)
    busy = np.zeros(D + 1)
    rr = 0
    now = 0.0
    arange = np.arange(D + 1)

    def excluded_at(t):
        return np.any((fs + det <= t) & (t < fe + det), axis=1)

    def overlaps(d, s, e):
        return d < D and bool(np.any((fs[d] < e) & (fe[d] > s)))

    def degfac(start, end, fac, d, t):
        out = 1.0
        if d < D:
            for k in range(start.shape[1]):
                if start[d, k] <= t < end[d, k]:
                    out = out * fac[d, k]
        return out

    def effbw(d, t):
        return min(bw_src[d] * degfac(dss, dse, dsf, d, t),
                   bw_dst[d] * degfac(dds, dde, ddf, d, t))

    def choose(rr_, live=None):
        if policy == "tent":
            s = pen * (b0 + b1 * (q + L) / bw_score)
            mask = np.zeros(D + 1, dtype=bool)
            mask[:D] = excl if live is None else ~live
            mask[D] = True
            sx = np.where(mask, np.inf, s)
            se = sx if np.isfinite(sx.min()) else s
            return _select_np(se, rr_, gamma)
        rot = np.where(arange < D,
                       ((arange - rr_) % max(D, 1)).astype(np.float64), np.inf)
        if live is not None:
            rx = np.where(np.concatenate([live, [False]]), rot, np.inf)
            rot = rx if np.isfinite(rx.min()) else rot
        return _select_np(rot, 0, 0.0)

    ends_all = np.zeros((R, W))
    oks_all = np.zeros((R, W), dtype=bool)
    for r in range(R):
        excl = excluded_at(now)
        ds_r = np.zeros(W, dtype=np.int64)
        qat_r = np.zeros(W)
        for w in range(W):
            jm1, jm2 = float(jm[r, w, 0]), float(jm[r, w, 1])
            d1 = choose(rr)
            rr += 1
            q[d1] += L
            qat1 = q[d1]
            start1 = max(now, busy[d1])
            # service = (L * jm) / bw, NOT start + L/bw*jm: a multiply whose
            # result feeds the busy-chain add invites XLA's FMA contraction
            # inside lax.scan (single-rounded a+b*c), which numpy cannot
            # reproduce — a division result is contraction-proof. Mirrored
            # exactly in the jax twin.
            endb1 = start1 + (L * jm1) / effbw(d1, start1)
            f1 = overlaps(d1, start1, endb1)
            if not f1:
                busy[d1] = endb1
            else:
                q[d1] -= L
            t2 = start1 + det
            live = ~excluded_at(t2)
            d2 = choose(rr, live=live)
            if f1:
                rr += 1
                q[d2] += L
                qat2 = q[d2]
                start2 = max(t2, busy[d2])
                endb2 = start2 + (L * jm2) / effbw(d2, start2)
                f2 = overlaps(d2, start2, endb2)
                if not f2:
                    busy[d2] = endb2
                else:
                    q[d2] -= L
                ok = not f2
                d_f, endb_f, qat_f = d2, endb2, qat2
            else:
                ok = True
                d_f, endb_f, qat_f = d1, endb1, qat1
            ds_r[w] = d_f
            ends_all[r, w] = endb_f + lat[d_f]
            oks_all[r, w] = ok
            qat_r[w] = qat_f
        # completion-ordered EWMA drain (failures -> scratch row D)
        key_order = np.where(oks_all[r], ends_all[r], np.inf)
        order = np.argsort(key_order, kind="stable")
        for w in order:
            d = int(ds_r[w]) if oks_all[r, w] else D
            tob = ends_all[r, w] - now
            a = alpha[d]
            x = (qat_r[w] + L) / bw_score[d]
            sample = np.clip((tob - b0[d]) / (x if x > 0 else 1.0), 0.05, 1e4)
            if x > 0:
                b1[d] = (1 - a) * b1[d] + a * sample
            resid = max(0.0, tob - b1[d] * x)
            b0[d] = (1 - b0a[d]) * b0[d] + b0a[d] * resid
            q[d] = max(0.0, q[d] - L)
        if oks_all[r].any():
            now = float(np.max(np.where(oks_all[r], ends_all[r], -np.inf)))
        else:
            now = now + det
    total_ok = int(oks_all.sum())
    bytes_ok = total_ok * L
    makespan = now
    throughput = bytes_ok / max(makespan, 1e-12)
    ends_flat = np.where(oks_all, ends_all, np.inf).ravel()
    onsets = fs.ravel()
    valid = onsets < min(makespan, FAR_WINDOW * 0.5)
    healing = -1.0
    if valid.any():
        heal = np.full(onsets.shape, -np.inf)
        for i, o in enumerate(onsets):
            if valid[i]:
                after = ends_flat[ends_flat >= o]
                heal[i] = (after.min() - o) if after.size else np.inf
        healing = float(heal.max())
    lost = R * W - total_ok
    return (float(throughput), float(healing), float(bytes_ok),
            float(lost), float(makespan))


# -- jax twin ----------------------------------------------------------------

_SIM_CACHE: Dict[tuple, tuple] = {}


def _build_sim(p: SprayProgram, policy: str, fault_jitter: float):
    """One seed-key -> metrics function, closed over the program constants.
    Mirrors `simulate_spray_ref` op for op; every reduction that is
    float-order-sensitive (degradation factor products, the EWMA drain) is
    either statically unrolled or an explicit scan, so CPU results match
    the numpy twin bit for bit under x64."""
    import jax
    import jax.numpy as jnp

    if policy not in ("tent", "round_robin"):
        raise ValueError(f"unsupported sweep policy {policy!r}")
    D, R, W = p.n_rails, p.rounds, p.wave
    L = float(p.length)
    det = float(p.detect)
    gamma = float(p.gamma)
    fj = float(fault_jitter)

    def simulate(key):
        # All program constants materialize at trace time, inside the
        # caller's enable_x64 scope — hoisting them to build time would
        # commit them as float32 and silently demote the whole sim.
        FS = jnp.asarray(p.fail_start, dtype=float)
        FE = jnp.asarray(p.fail_end, dtype=float)
        DSS = jnp.asarray(p.degs_start, dtype=float)
        DSE = jnp.asarray(p.degs_end, dtype=float)
        DSF = jnp.asarray(p.degs_factor, dtype=float)
        DDS = jnp.asarray(p.degd_start, dtype=float)
        DDE = jnp.asarray(p.degd_end, dtype=float)
        DDF = jnp.asarray(p.degd_factor, dtype=float)
        ext = lambda a, fill: jnp.concatenate(
            [jnp.asarray(a, dtype=float), jnp.full((1,), fill)])
        bw_score = ext(p.bw_score, 1.0)
        bw_src = ext(p.bw_src, 1.0)
        bw_dst = ext(p.bw_dst, 1.0)
        pen = ext(p.penalty, jnp.inf)
        lat = ext(p.latency, 0.0)
        alpha = ext(p.ewma_alpha, 0.0)
        b0a = ext(p.beta0_alpha, 0.0)
        b0_init = ext(p.beta0, 0.0)
        b1_init = ext(p.beta1, 1.0)
        arange = jnp.arange(D + 1)

        def _select(scores, rr_, gamma_):
            s_min = jnp.min(scores)
            in_w = scores <= (1.0 + gamma_) * s_min
            n_w = jnp.sum(in_w)
            k = (rr_ % jnp.maximum(n_w, 1)).astype(jnp.int32)
            order = jnp.cumsum(in_w.astype(jnp.int64)) - 1
            return jnp.min(jnp.where(in_w & (order == k), arange, D + 1))

        uf, us, ud, jm = _draws_jnp(p, key)
        # Mirrors `_jitter_windows_np` op for op, with the same division
        # barriers so XLA cannot FMA-contract the scale arithmetic.
        inv = _inv_fj(fj)
        fs = jnp.maximum(0.0, FS * (1.0 + uf[..., 0] / inv))
        fe = fs + (FE - FS) / (1.0 / (1.0 + uf[..., 1] / inv))
        dss = jnp.maximum(0.0, DSS * (1.0 + us[..., 0] / inv))
        dse = dss + (DSE - DSS) / (1.0 / (1.0 + us[..., 1] / inv))
        dsf = jnp.clip(DSF * (1.0 + us[..., 2] / inv), 0.01, 1.0)
        dds = jnp.maximum(0.0, DDS * (1.0 + ud[..., 0] / inv))
        dde = dds + (DDE - DDS) / (1.0 / (1.0 + ud[..., 1] / inv))
        ddf = jnp.clip(DDF * (1.0 + ud[..., 2] / inv), 0.01, 1.0)

        def excluded_at(t):  # (D,) detect-shifted fault visibility
            return jnp.any((fs + det <= t) & (t < fe + det), axis=1)

        def overlaps(d, s, e):  # scratch row D has no windows -> False
            valid = d < D
            dc = jnp.minimum(d, D - 1)
            return valid & jnp.any((fs[dc] < e) & (fe[dc] > s))

        def degfac(start, end, fac, d, t):
            valid = d < D
            dc = jnp.minimum(d, D - 1)
            out = 1.0
            for k in range(start.shape[1]):  # static K: exact multiply order
                active = valid & (start[dc, k] <= t) & (t < end[dc, k])
                out = out * jnp.where(active, fac[dc, k], 1.0)
            return out

        def effbw(d, t):
            return jnp.minimum(
                bw_src[d] * degfac(dss, dse, dsf, d, t),
                bw_dst[d] * degfac(dds, dde, ddf, d, t))

        def choose(q, rr_, excl_e, live=None):
            if policy == "tent":
                s = pen * (b0_ref[0] + b1_ref[0] * (q + L) / bw_score)
                mask = excl_e if live is None else jnp.concatenate(
                    [~live, jnp.ones(1, dtype=bool)])
                sx = jnp.where(mask, jnp.inf, s)
                se = jnp.where(jnp.isinf(jnp.min(sx)), s, sx)
                return _select(se, rr_, gamma)
            rot = jnp.where(arange < D,
                            ((arange - rr_) % max(D, 1)).astype(float),
                            jnp.inf)
            if live is not None:
                rx = jnp.where(jnp.concatenate(
                    [live, jnp.zeros(1, dtype=bool)]), rot, jnp.inf)
                rot = jnp.where(jnp.isinf(jnp.min(rx)), rot, rx)
            return _select(rot, 0, 0.0)

        # b0/b1 are round-constant for scoring (the engine's chooser reads
        # telemetry that only the drain updates); a one-element list lets
        # the nested closures read the current round's vectors.
        b0_ref = [b0_init]
        b1_ref = [b1_init]

        def round_step(carry, jm_r):
            q, b0, b1, busy, rr, now = carry
            b0_ref[0] = b0
            b1_ref[0] = b1
            excl = excluded_at(now)
            excl_e = jnp.concatenate([excl, jnp.ones(1, dtype=bool)])

            def slice_step(c2, jm_w):
                q, busy, rr = c2
                jm1, jm2 = jm_w[0], jm_w[1]
                d1 = choose(q, rr, excl_e)
                rr = rr + 1
                q = q.at[d1].add(L)
                qat1 = q[d1]
                start1 = jnp.maximum(now, busy[d1])
                # (L * jm) / bw: see the numpy twin — keeps XLA from
                # FMA-contracting the busy-chain add inside the scan
                endb1 = start1 + (L * jm1) / effbw(d1, start1)
                f1 = overlaps(d1, start1, endb1)
                busy = busy.at[d1].set(jnp.where(f1, busy[d1], endb1))
                q = q.at[d1].add(jnp.where(f1, -L, 0.0))
                t2 = start1 + det
                live = ~excluded_at(t2)
                d2 = choose(q, rr, excl_e, live=live)
                rr = rr + f1.astype(rr.dtype)
                q = q.at[d2].add(jnp.where(f1, L, 0.0))
                qat2 = q[d2]
                start2 = jnp.maximum(t2, busy[d2])
                endb2 = start2 + (L * jm2) / effbw(d2, start2)
                f2 = overlaps(d2, start2, endb2)
                busy = busy.at[d2].set(
                    jnp.where(f1 & ~f2, endb2, busy[d2]))
                q = q.at[d2].add(jnp.where(f1 & f2, -L, 0.0))
                ok = ~(f1 & f2)
                d_f = jnp.where(f1, d2, d1)
                endb_f = jnp.where(f1, endb2, endb1)
                qat_f = jnp.where(f1, qat2, qat1)
                return (q, busy, rr), (d_f, endb_f + lat[d_f], ok, qat_f)

            (q, busy, rr), (ds, ends, oks, qats) = jax.lax.scan(
                slice_step, (q, busy, rr), jm_r)
            key_order = jnp.where(oks, ends, jnp.inf)
            order = jnp.argsort(key_order, stable=True)

            def drain_step(c3, inp):
                b0_, b1_, q_ = c3
                d, endt, qas, ok = inp
                du = jnp.where(ok, d, D)
                # `one` is a traced, always-1.0 divisor: dividing each EWMA
                # product by it forces a separate IEEE rounding, blocking
                # the backend's mul+add->fma contraction that would break
                # bit-parity with simulate_spray_ref (same defense as
                # tent_on_complete_many_jnp; exact, since x/1.0 == x).
                one = jnp.where(du >= 0, 1.0, 2.0)
                tob = endt - now
                a = alpha[du]
                x = (qas + L) / bw_score[du]
                sample = jnp.clip(
                    (tob - b0_[du]) / jnp.where(x > 0, x, 1.0), 0.05, 1e4)
                b1d = jnp.where(
                    x > 0,
                    ((1 - a) * b1_[du]) / one + (a * sample) / one,
                    b1_[du])
                resid = jnp.maximum(0.0, tob - (b1d * x) / one)
                b0d = ((1 - b0a[du]) * b0_[du]) / one + \
                    (b0a[du] * resid) / one
                return (b0_.at[du].set(b0d), b1_.at[du].set(b1d),
                        q_.at[du].set(jnp.maximum(0.0, q_[du] - L))), None

            (b0, b1, q), _ = jax.lax.scan(
                drain_step, (b0, b1, q),
                (ds[order], ends[order], qats[order], oks[order]))
            any_ok = jnp.any(oks)
            now2 = jnp.where(
                any_ok, jnp.max(jnp.where(oks, ends, -jnp.inf)), now + det)
            return (q, b0, b1, busy, rr, now2), (ends, oks)

        init = (jnp.zeros(D + 1), b0_init, b1_init, jnp.zeros(D + 1),
                jnp.asarray(0, dtype=jnp.int32), jnp.asarray(0.0))
        (q, b0, b1, busy, rr, now), (ends_all, oks_all) = jax.lax.scan(
            round_step, init, jm)
        total_ok = jnp.sum(oks_all)
        bytes_ok = total_ok * L
        makespan = now
        throughput = bytes_ok / jnp.maximum(makespan, 1e-12)
        ends_flat = jnp.where(oks_all, ends_all, jnp.inf).ravel()
        onsets = fs.ravel()
        valid = onsets < jnp.minimum(makespan, FAR_WINDOW * 0.5)

        def heal_one(o):
            after = jnp.min(
                jnp.where(ends_flat >= o, ends_flat, jnp.inf))
            return after - o

        heal = jax.lax.map(heal_one, onsets)
        healing = jnp.where(
            jnp.any(valid),
            jnp.max(jnp.where(valid, heal, -jnp.inf)), -1.0)
        lost = R * W - total_ok
        return (throughput, healing, bytes_ok,
                lost.astype(float), makespan)

    return simulate


def _sim_fns(p: SprayProgram, policy: str, fault_jitter: float):
    import jax

    cache_key = (id(p), policy, float(fault_jitter))
    hit = _SIM_CACHE.get(cache_key)
    if hit is not None and hit[0] is p:
        return hit[1], hit[2]
    simulate = _build_sim(p, policy, fault_jitter)
    single = jax.jit(simulate)
    sweep = jax.jit(jax.vmap(simulate))
    _SIM_CACHE[cache_key] = (p, single, sweep)
    return single, sweep


def spray_single(p: SprayProgram, *, base_seed: int = 0, seed_index: int = 0,
                 policy: str = "tent",
                 fault_jitter: float = 0.0) -> Tuple[float, ...]:
    """One independently-jitted seed:
    `(throughput, healing_s, bytes_ok, lost, makespan)`. Exact-equal to the
    matching lane of `spray_sweep` (pinned in tests/test_mc_sweep.py)."""
    single, _ = _sim_fns(p, policy, fault_jitter)
    with _x64():
        out = single(_seed_key(base_seed, seed_index))
        return tuple(float(np.asarray(v)) for v in out)


def spray_sweep(p: SprayProgram, n_seeds: int, *, base_seed: int = 0,
                policy: str = "tent",
                fault_jitter: float = 0.0) -> Dict[str, np.ndarray]:
    """The vmapped Monte Carlo sweep: `n_seeds` independent fault draws in
    one jit dispatch. Returns per-seed float64 arrays keyed `throughput`,
    `healing_s`, `bytes_ok`, `lost`, `makespan`."""
    import jax.numpy as jnp

    _, sweep = _sim_fns(p, policy, fault_jitter)
    with _x64():
        keys = jnp.stack(
            [_seed_key(base_seed, i) for i in range(n_seeds)])
        out = sweep(keys)
        arrs = [np.asarray(v) for v in out]
    return {"throughput": arrs[0], "healing_s": arrs[1],
            "bytes_ok": arrs[2], "lost": arrs[3], "makespan": arrs[4]}
