"""Per-link telemetry and the predictive completion-time model (paper Eq. 1).

    t_hat_d = beta0_d + beta1_d * (A_d + L) / B_d

`B_d` is the *nominal* link bandwidth from topology discovery; `A_d` is the
effective queue length in bytes (maintained by Algorithm 1 line 11); the
coefficients beta are dynamic correction factors absorbing incast, switch
congestion and silent degradation, updated by an EWMA filter from the
prediction error on every slice completion. A periodic state reset prevents
starvation of temporarily slow rails (paper §4.2 "Feedback").
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .topology import LinkDesc

DEFAULT_BETA0 = 0.0
DEFAULT_BETA1 = 1.0


@dataclasses.dataclass
class LinkTelemetry:
    desc: LinkDesc
    beta0: float = DEFAULT_BETA0
    beta0_prior: float = DEFAULT_BETA0  # topology-informed fixed-cost prior
    beta1: float = DEFAULT_BETA1
    queued_bytes: int = 0  # A_d
    ewma_alpha: float = 0.25
    beta0_alpha: float = 0.05
    # health signals
    consecutive_slow: int = 0
    excluded: bool = False
    # observability
    completions: int = 0
    failures: int = 0
    ewma_service_time: float = 0.0

    def predict(self, length: int) -> float:
        """Estimated completion time for a new slice of `length` bytes."""
        return self.beta0 + self.beta1 * (self.queued_bytes + length) / self.desc.bandwidth

    def on_schedule(self, length: int) -> None:
        self.queued_bytes += length

    def on_cancel(self, length: int) -> None:
        self.queued_bytes = max(0, self.queued_bytes - length)

    def on_complete(self, length: int, queued_at_schedule: int, t_obs: float) -> None:
        """EWMA update from the observed slice completion time.

        The normalized load x = (A_sched + L) / B is what Eq. 1 multiplied by
        beta1, so the per-sample estimate of beta1 is (t_obs - beta0)/x.
        beta0 absorbs the residual fixed cost with a slower filter.
        """
        self.queued_bytes = max(0, self.queued_bytes - length)
        self.completions += 1
        x = (queued_at_schedule + length) / self.desc.bandwidth
        if x > 0:
            sample = (t_obs - self.beta0) / x
            sample = min(max(sample, 0.05), 1e4)
            self.beta1 = (1 - self.ewma_alpha) * self.beta1 + self.ewma_alpha * sample
        resid = max(0.0, t_obs - self.beta1 * x)
        self.beta0 = (1 - self.beta0_alpha) * self.beta0 + self.beta0_alpha * resid
        a = self.ewma_alpha
        self.ewma_service_time = (1 - a) * self.ewma_service_time + a * t_obs

    def on_failure(self) -> None:
        self.failures += 1

    def reset(self) -> None:
        """Periodic state reset (paper §4.2): forget learned penalties so that
        recovered paths are re-integrated into the pool."""
        self.beta0 = self.beta0_prior
        self.beta1 = DEFAULT_BETA1
        self.consecutive_slow = 0


class TelemetryStore:
    """All per-link telemetry for one engine instance, plus the optional
    cross-process global load diffusion table (paper §4.2)."""

    def __init__(self) -> None:
        self._links: Dict[int, LinkTelemetry] = {}
        # Optional shared-memory analogue: link_id -> global queued bytes
        self.global_load: Dict[int, int] = {}
        self.global_weight: float = 0.0  # omega_d, disabled by default

    def ensure(self, desc: LinkDesc) -> LinkTelemetry:
        tl = self._links.get(desc.link_id)
        if tl is None:
            # Topology discovery seeds the fixed-cost term with the link's
            # known base latency so cold-start predictions aren't absurd.
            tl = LinkTelemetry(desc=desc, beta0=desc.base_latency, beta0_prior=desc.base_latency)
            self._links[desc.link_id] = tl
        return tl

    def get(self, link_id: int) -> LinkTelemetry:
        return self._links[link_id]

    def maybe(self, link_id: int):
        return self._links.get(link_id)

    def effective_queue(self, tl: LinkTelemetry) -> float:
        """Blend local queue with the global load factor when diffusion is on."""
        if self.global_weight <= 0.0:
            return float(tl.queued_bytes)
        g = float(self.global_load.get(tl.desc.link_id, 0))
        return (1 - self.global_weight) * tl.queued_bytes + self.global_weight * g

    def publish_global(self) -> None:
        for lid, tl in self._links.items():
            self.global_load[lid] = self.global_load.get(lid, 0) + tl.queued_bytes

    def reset_all(self) -> None:
        for tl in self._links.values():
            tl.reset()

    def items(self):
        return self._links.items()
