"""Per-link telemetry and the predictive completion-time model (paper Eq. 1).

    t_hat_d = beta0_d + beta1_d * (A_d + L) / B_d

`B_d` is the *nominal* link bandwidth from topology discovery; `A_d` is the
effective queue length in bytes (maintained by Algorithm 1 line 11); the
coefficients beta are dynamic correction factors absorbing incast, switch
congestion and silent degradation, updated by an EWMA filter from the
prediction error on every slice completion. A periodic state reset prevents
starvation of temporarily slow rails (paper §4.2 "Feedback").
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .topology import LinkDesc

DEFAULT_BETA0 = 0.0
DEFAULT_BETA1 = 1.0


@dataclasses.dataclass
class LinkTelemetry:
    desc: LinkDesc
    beta0: float = DEFAULT_BETA0
    beta0_prior: float = DEFAULT_BETA0  # topology-informed fixed-cost prior
    beta1: float = DEFAULT_BETA1
    queued_bytes: int = 0  # A_d
    ewma_alpha: float = 0.25
    beta0_alpha: float = 0.05
    # health signals
    consecutive_slow: int = 0
    excluded: bool = False
    # observability
    completions: int = 0
    failures: int = 0
    ewma_service_time: float = 0.0

    def predict(self, length: int) -> float:
        """Estimated completion time for a new slice of `length` bytes."""
        return self.beta0 + self.beta1 * (self.queued_bytes + length) / self.desc.bandwidth

    def on_schedule(self, length: int) -> None:
        self.queued_bytes += length

    def on_cancel(self, length: int) -> None:
        self.queued_bytes = max(0, self.queued_bytes - length)

    def on_complete(self, length: int, queued_at_schedule: int, t_obs: float) -> None:
        """EWMA update from the observed slice completion time.

        The normalized load x = (A_sched + L) / B is what Eq. 1 multiplied by
        beta1, so the per-sample estimate of beta1 is (t_obs - beta0)/x.
        beta0 absorbs the residual fixed cost with a slower filter.
        """
        self.queued_bytes = max(0, self.queued_bytes - length)
        self.completions += 1
        x = (queued_at_schedule + length) / self.desc.bandwidth
        if x > 0:
            sample = (t_obs - self.beta0) / x
            sample = min(max(sample, 0.05), 1e4)
            self.beta1 = (1 - self.ewma_alpha) * self.beta1 + self.ewma_alpha * sample
        resid = max(0.0, t_obs - self.beta1 * x)
        self.beta0 = (1 - self.beta0_alpha) * self.beta0 + self.beta0_alpha * resid
        a = self.ewma_alpha
        self.ewma_service_time = (1 - a) * self.ewma_service_time + a * t_obs

    def on_failure(self) -> None:
        self.failures += 1

    def reset(self) -> None:
        """Periodic state reset (paper §4.2): forget learned penalties so that
        recovered paths are re-integrated into the pool."""
        self.beta0 = self.beta0_prior
        self.beta1 = DEFAULT_BETA1
        self.consecutive_slow = 0


class TelemetryStore:
    """All per-link telemetry for one engine instance, plus the optional
    cross-process global load diffusion table (paper §4.2).

    The global table maps link_id -> queued bytes *other* engines have in
    flight on that link (populated by `repro.cluster.GlobalLoadTable` or by
    `publish_global` in shared-table setups). Because an engine schedules on
    its *local* NICs but contends with peers on the paired *remote* NICs
    (incast), the engine also tracks `remote_queued`: its own in-flight bytes
    charged against remote endpoints, so peers can see the receiver-side
    pressure through the diffusion table."""

    def __init__(self) -> None:
        self._links: Dict[int, LinkTelemetry] = {}
        # Shared-memory analogue: link_id -> queued bytes from OTHER engines
        self.global_load: Dict[int, int] = {}
        self.global_weight: float = 0.0  # omega_d, disabled by default
        # This engine's in-flight bytes charged to remote endpoints.
        self.remote_queued: Dict[int, int] = {}
        # Own contributions currently sitting in `global_load` (shared-table
        # mode via publish_global); subtracted on read so an engine never
        # double-counts its own load through the table.
        self._published: Dict[int, int] = {}

    def ensure(self, desc: LinkDesc) -> LinkTelemetry:
        tl = self._links.get(desc.link_id)
        if tl is None:
            # Topology discovery seeds the fixed-cost term with the link's
            # known base latency so cold-start predictions aren't absurd.
            tl = LinkTelemetry(desc=desc, beta0=desc.base_latency, beta0_prior=desc.base_latency)
            self._links[desc.link_id] = tl
        return tl

    def get(self, link_id: int) -> LinkTelemetry:
        return self._links[link_id]

    def maybe(self, link_id: int):
        return self._links.get(link_id)

    def effective_queue(self, tl: LinkTelemetry) -> float:
        """Local queue plus the omega-discounted global load factor. The
        local term is exact (this engine's own accounting); the global term
        is other engines' pressure, discounted by omega because the diffused
        table is periodic and therefore stale (paper §4.2)."""
        if self.global_weight <= 0.0:
            return float(tl.queued_bytes)
        return tl.queued_bytes + self.global_weight * self._foreign_load(tl.desc.link_id)

    def remote_pressure(self, link_id: int) -> float:
        """Omega-discounted global load on a path's *remote* endpoint — how
        hard other engines are hitting the receiver NIC this path pairs with.
        Zero when diffusion is off, so single-engine scoring is unchanged."""
        if self.global_weight <= 0.0:
            return 0.0
        return self.global_weight * self._foreign_load(link_id)

    def _foreign_load(self, link_id: int) -> float:
        """Other engines' bytes on a link: the table entry minus whatever
        this engine itself published into it (zero with the diffusion
        service, which already excludes own snapshots)."""
        g = self.global_load.get(link_id, 0) - self._published.get(link_id, 0)
        return float(max(g, 0))

    # -- cross-engine accounting (repro.cluster diffusion service) -----------
    def apply_global(self, agg: Dict[int, int]) -> None:
        """Replace the diffused global-load view wholesale. The cluster's
        `GlobalLoadTable` calls this every round (and on membership churn,
        when a departed engine's entries are garbage-collected) with the sum
        of the *other* live engines' in-horizon footprints — the single write
        point for everything `effective_queue`/`remote_pressure` read, so
        staleness pruning and departure GC cannot leave ghost pressure
        behind."""
        self.global_load = agg

    def clear_global(self) -> None:
        """Drop the diffused view entirely — what an engine leaving the
        cluster does on the way out, so a later re-attach (or standalone use)
        never schedules on a dead cluster's load table."""
        self.global_load = {}

    def charge_remote(self, link_id: int, length: int) -> None:
        self.remote_queued[link_id] = self.remote_queued.get(link_id, 0) + length

    def discharge_remote(self, link_id: int, length: int) -> None:
        left = self.remote_queued.get(link_id, 0) - length
        if left > 0:
            self.remote_queued[link_id] = left
        else:
            self.remote_queued.pop(link_id, None)

    def snapshot(self) -> Dict[int, int]:
        """This engine's total in-flight footprint per link (local queues
        plus remote-endpoint charges) — what it publishes to the cluster's
        global load table each diffusion round."""
        out = {lid: tl.queued_bytes for lid, tl in self._links.items() if tl.queued_bytes}
        for lid, q in self.remote_queued.items():
            if q:
                out[lid] = out.get(lid, 0) + q
        return out

    def publish_global(self) -> None:
        """Shared-table mode: several stores point at one `global_load` dict
        and each writes its own queue depths in. Publishing *replaces* this
        store's previous contribution (no unbounded accumulation), and reads
        subtract it via `_published`."""
        for lid, tl in self._links.items():
            prev = self._published.get(lid, 0)
            if tl.queued_bytes or prev:
                self.global_load[lid] = (
                    self.global_load.get(lid, 0) - prev + tl.queued_bytes)
                self._published[lid] = tl.queued_bytes

    def reset_all(self) -> None:
        for tl in self._links.values():
            tl.reset()

    def items(self):
        return self._links.items()
