"""Per-link telemetry and the predictive completion-time model (paper Eq. 1).

    t_hat_d = beta0_d + beta1_d * (A_d + L) / B_d

`B_d` is the *nominal* link bandwidth from topology discovery; `A_d` is the
effective queue length in bytes (maintained by Algorithm 1 line 11); the
coefficients beta are dynamic correction factors absorbing incast, switch
congestion and silent degradation, updated by an EWMA filter from the
prediction error on every slice completion. A periodic state reset prevents
starvation of temporarily slow rails (paper §4.2 "Feedback").

Storage layout: the store is struct-of-arrays. Every per-link quantity
(beta0/beta1/queued/excluded/health counters) lives in one contiguous numpy
array, indexed by a stable slot assigned at registration time (the
link-index map). `LinkTelemetry` is a thin *view* — an object carrying
(store, slot, desc) whose attributes read and write the arrays — so the
whole pre-existing per-link API keeps working, `HealthMonitor` exclusions
land directly in the arrays, and the wave scheduler
(`TentPolicy.choose_wave` / `tent_choose_wave`) can gather a candidate
set's entire state with a handful of fancy-indexing operations instead of
touching N Python objects per slice.

The cross-engine structures (`global_load`, `remote_queued`, `_published`)
deliberately stay dicts: they are written by *other* components (the
cluster's diffusion service replaces `global_load` wholesale each round;
shared-table mode aliases one dict across several stores), they are sparse,
and they are read once per wave, not once per slice — see the core README
for the vectorized/scalar split rationale.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .topology import LinkDesc
from ..analysis import hot_path

DEFAULT_BETA0 = 0.0
DEFAULT_BETA1 = 1.0
DEFAULT_EWMA_ALPHA = 0.25
DEFAULT_BETA0_ALPHA = 0.05


def _field(name: str, arr: str):
    """Property reading/writing one SoA array slot (the view mechanics)."""

    def get(self):
        return getattr(self.store, arr)[self.slot]

    def set(self, value):
        getattr(self.store, arr)[self.slot] = value

    get.__name__ = set.__name__ = name
    return property(get, set)


class LinkTelemetry:
    """View over one link's slot in a `TelemetryStore`'s arrays.

    Constructing one directly (without `_store`) allocates a private
    single-slot store, so standalone uses (unit tests, ad-hoc scoring) keep
    the old value-object ergonomics; `TelemetryStore.ensure` hands out views
    into the shared arrays."""

    __slots__ = ("desc", "store", "slot")

    def __init__(
        self,
        desc: LinkDesc,
        beta0: float = DEFAULT_BETA0,
        beta0_prior: float = DEFAULT_BETA0,
        beta1: float = DEFAULT_BETA1,
        queued_bytes: int = 0,
        ewma_alpha: float = DEFAULT_EWMA_ALPHA,
        beta0_alpha: float = DEFAULT_BETA0_ALPHA,
        consecutive_slow: int = 0,
        excluded: bool = False,
        completions: int = 0,
        failures: int = 0,
        ewma_service_time: float = 0.0,
        *,
        _store: Optional["TelemetryStore"] = None,
    ):
        self.desc = desc
        self.store = _store if _store is not None else TelemetryStore()
        self.slot = self.store._alloc(
            self, desc,
            beta0=beta0, beta0_prior=beta0_prior, beta1=beta1,
            queued_bytes=queued_bytes, ewma_alpha=ewma_alpha,
            beta0_alpha=beta0_alpha, consecutive_slow=consecutive_slow,
            excluded=excluded, completions=completions, failures=failures,
            ewma_service_time=ewma_service_time,
        )

    beta0 = _field("beta0", "beta0_arr")
    beta0_prior = _field("beta0_prior", "beta0_prior_arr")
    beta1 = _field("beta1", "beta1_arr")
    queued_bytes = _field("queued_bytes", "queued_arr")
    ewma_alpha = _field("ewma_alpha", "ewma_alpha_arr")
    beta0_alpha = _field("beta0_alpha", "beta0_alpha_arr")
    consecutive_slow = _field("consecutive_slow", "slow_arr")
    completions = _field("completions", "completions_arr")
    failures = _field("failures", "failures_arr")
    ewma_service_time = _field("ewma_service_time", "ewma_service_arr")

    @property
    def excluded(self) -> bool:
        return bool(self.store.excluded_arr[self.slot])

    @excluded.setter
    def excluded(self, value: bool) -> None:
        self.store.excluded_arr[self.slot] = bool(value)

    def __repr__(self) -> str:  # pragma: no cover - debugging convenience
        return (f"LinkTelemetry({self.desc.name}, beta0={float(self.beta0):.3g}, "
                f"beta1={float(self.beta1):.3g}, queued={int(self.queued_bytes)}, "
                f"excluded={self.excluded})")

    def predict(self, length: int) -> float:
        """Estimated completion time for a new slice of `length` bytes."""
        return self.beta0 + self.beta1 * (self.queued_bytes + length) / self.desc.bandwidth

    def on_schedule(self, length: int) -> None:
        self.store.queued_arr[self.slot] += length

    def on_cancel(self, length: int) -> None:
        s = self.store
        s.queued_arr[self.slot] = max(0, s.queued_arr[self.slot] - length)

    def on_complete(self, length: int, queued_at_schedule: int, t_obs: float) -> None:
        """EWMA update from the observed slice completion time.

        The normalized load x = (A_sched + L) / B is what Eq. 1 multiplied by
        beta1, so the per-sample estimate of beta1 is (t_obs - beta0)/x.
        beta0 absorbs the residual fixed cost with a slower filter.
        """
        s, i = self.store, self.slot
        s.queued_arr[i] = max(0, s.queued_arr[i] - length)
        s.completions_arr[i] += 1
        alpha = s.ewma_alpha_arr[i]
        x = (queued_at_schedule + length) / self.desc.bandwidth
        if x > 0:
            sample = (t_obs - s.beta0_arr[i]) / x
            sample = min(max(sample, 0.05), 1e4)
            s.beta1_arr[i] = (1 - alpha) * s.beta1_arr[i] + alpha * sample
        resid = max(0.0, t_obs - s.beta1_arr[i] * x)
        b0a = s.beta0_alpha_arr[i]
        s.beta0_arr[i] = (1 - b0a) * s.beta0_arr[i] + b0a * resid
        s.ewma_service_arr[i] = (1 - alpha) * s.ewma_service_arr[i] + alpha * t_obs

    def on_failure(self) -> None:
        self.store.failures_arr[self.slot] += 1

    def reset(self) -> None:
        """Periodic state reset (paper §4.2): forget learned penalties so that
        recovered paths are re-integrated into the pool."""
        s, i = self.store, self.slot
        s.beta0_arr[i] = s.beta0_prior_arr[i]
        s.beta1_arr[i] = DEFAULT_BETA1
        s.slow_arr[i] = 0


class TelemetryStore:
    """All per-link telemetry for one engine instance as struct-of-arrays,
    plus the optional cross-process global load diffusion table (paper §4.2).

    The global table maps link_id -> queued bytes *other* engines have in
    flight on that link (populated by `repro.cluster.GlobalLoadTable` or by
    `publish_global` in shared-table setups). Because an engine schedules on
    its *local* NICs but contends with peers on the paired *remote* NICs
    (incast), the engine also tracks `remote_queued`: its own in-flight bytes
    charged against remote endpoints, so peers can see the receiver-side
    pressure through the diffusion table."""

    _FLOAT_ARRS = ("beta0_arr", "beta0_prior_arr", "beta1_arr",
                   "ewma_alpha_arr", "beta0_alpha_arr", "ewma_service_arr",
                   "bandwidth_arr")
    _INT_ARRS = ("queued_arr", "slow_arr", "completions_arr", "failures_arr")

    def __init__(self) -> None:
        self.n = 0
        self._cap = 0
        for name in self._FLOAT_ARRS:
            setattr(self, name, np.empty(0, dtype=np.float64))
        for name in self._INT_ARRS:
            setattr(self, name, np.empty(0, dtype=np.int64))
        self.excluded_arr = np.empty(0, dtype=bool)
        self._slots: Dict[int, int] = {}  # link_id -> slot (stable index map)
        self._link_ids: List[int] = []  # slot -> link_id
        self._views: List[LinkTelemetry] = []  # slot -> view
        # Shared-memory analogue: link_id -> queued bytes from OTHER engines
        self.global_load: Dict[int, int] = {}
        self.global_weight: float = 0.0  # omega_d, disabled by default
        # This engine's in-flight bytes charged to remote endpoints.
        self.remote_queued: Dict[int, int] = {}
        # Own contributions currently sitting in `global_load` (shared-table
        # mode via publish_global); subtracted on read so an engine never
        # double-counts its own load through the table.
        self._published: Dict[int, int] = {}

    # -- slot allocation -----------------------------------------------------
    def _grow(self, need: int) -> None:
        cap = max(16, 2 * self._cap, need)
        for name in self._FLOAT_ARRS + self._INT_ARRS + ("excluded_arr",):
            old = getattr(self, name)
            new = np.zeros(cap, dtype=old.dtype)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)
        self._cap = cap

    def _alloc(self, view: LinkTelemetry, desc: LinkDesc, **init) -> int:
        if self.n >= self._cap:
            self._grow(self.n + 1)
        slot = self.n
        self.n += 1
        self.beta0_arr[slot] = init["beta0"]
        self.beta0_prior_arr[slot] = init["beta0_prior"]
        self.beta1_arr[slot] = init["beta1"]
        self.queued_arr[slot] = init["queued_bytes"]
        self.ewma_alpha_arr[slot] = init["ewma_alpha"]
        self.beta0_alpha_arr[slot] = init["beta0_alpha"]
        self.slow_arr[slot] = init["consecutive_slow"]
        self.completions_arr[slot] = init["completions"]
        self.failures_arr[slot] = init["failures"]
        self.ewma_service_arr[slot] = init["ewma_service_time"]
        # nominal bandwidth mirrored into the arrays (LinkDesc is frozen) so
        # the batched completion update never chases desc attributes
        self.bandwidth_arr[slot] = desc.bandwidth
        self.excluded_arr[slot] = init["excluded"]
        self._slots[desc.link_id] = slot
        self._link_ids.append(desc.link_id)
        self._views.append(view)
        return slot

    # -- registration / lookup ----------------------------------------------
    def ensure(self, desc: LinkDesc) -> LinkTelemetry:
        slot = self._slots.get(desc.link_id)
        if slot is None:
            # Topology discovery seeds the fixed-cost term with the link's
            # known base latency so cold-start predictions aren't absurd.
            return LinkTelemetry(
                desc=desc, beta0=desc.base_latency,
                beta0_prior=desc.base_latency, _store=self)
        return self._views[slot]

    def get(self, link_id: int) -> LinkTelemetry:
        return self._views[self._slots[link_id]]

    def maybe(self, link_id: int):
        slot = self._slots.get(link_id)
        return None if slot is None else self._views[slot]

    def slot_of(self, link_id: int) -> int:
        """Stable array index of a registered link (the link-index map)."""
        return self._slots[link_id]

    def effective_queue(self, tl: LinkTelemetry) -> float:
        """Local queue plus the omega-discounted global load factor. The
        local term is exact (this engine's own accounting); the global term
        is other engines' pressure, discounted by omega because the diffused
        table is periodic and therefore stale (paper §4.2)."""
        if self.global_weight <= 0.0:
            return float(tl.queued_bytes)
        return tl.queued_bytes + self.global_weight * self._foreign_load(tl.desc.link_id)

    def remote_pressure(self, link_id: int) -> float:
        """Omega-discounted global load on a path's *remote* endpoint — how
        hard other engines are hitting the receiver NIC this path pairs with.
        Zero when diffusion is off, so single-engine scoring is unchanged."""
        if self.global_weight <= 0.0:
            return 0.0
        return self.global_weight * self._foreign_load(link_id)

    def _foreign_load(self, link_id: int) -> float:
        """Other engines' bytes on a link: the table entry minus whatever
        this engine itself published into it (zero with the diffusion
        service, which already excludes own snapshots)."""
        g = self.global_load.get(link_id, 0) - self._published.get(link_id, 0)
        return float(max(g, 0))

    def foreign_load_array(self, link_ids) -> "np.ndarray":
        """Omega-weighted foreign load for a sequence of link ids (a `None`
        entry — a single-resource path with no remote endpoint — reads as
        0.0). One gather shared by the wave chooser and the decision-
        provenance snapshot (`TentPolicy.wave_inputs`), so recorded inputs
        are produced by the very code that scored the wave."""
        w = self.global_weight
        foreign = self._foreign_load
        return np.array([w * foreign(lid) if lid is not None else 0.0
                         for lid in link_ids])

    # -- cross-engine accounting (repro.cluster diffusion service) -----------
    def apply_global(self, agg: Dict[int, int]) -> None:
        """Replace the diffused global-load view wholesale. The cluster's
        `GlobalLoadTable` calls this every round (and on membership churn,
        when a departed engine's entries are garbage-collected) with the sum
        of the *other* live engines' in-horizon footprints — the single write
        point for everything `effective_queue`/`remote_pressure` read, so
        staleness pruning and departure GC cannot leave ghost pressure
        behind."""
        self.global_load = agg

    def clear_global(self) -> None:
        """Drop the diffused view entirely — what an engine leaving the
        cluster does on the way out, so a later re-attach (or standalone use)
        never schedules on a dead cluster's load table."""
        self.global_load = {}

    def charge_remote(self, link_id: int, length: int) -> None:
        self.remote_queued[link_id] = self.remote_queued.get(link_id, 0) + length

    def discharge_remote(self, link_id: int, length: int) -> None:
        left = self.remote_queued.get(link_id, 0) - length
        if left > 0:
            self.remote_queued[link_id] = left
        else:
            self.remote_queued.pop(link_id, None)

    def snapshot(self) -> Dict[int, int]:
        """This engine's total in-flight footprint per link (local queues
        plus remote-endpoint charges) — what it publishes to the cluster's
        global load table each diffusion round. One vectorized scan over the
        queue array instead of a per-link Python loop."""
        link_ids = self._link_ids
        queued = self.queued_arr
        out = {link_ids[i]: int(queued[i])
               for i in np.flatnonzero(queued[: self.n])}
        for lid, q in self.remote_queued.items():
            if q:
                out[lid] = out.get(lid, 0) + q
        return out

    def publish_global(self) -> None:
        """Shared-table mode: several stores point at one `global_load` dict
        and each writes their own queue depths in. Publishing *replaces* this
        store's previous contribution (no unbounded accumulation), and reads
        subtract it via `_published`."""
        for lid, slot in self._slots.items():
            prev = self._published.get(lid, 0)
            q = int(self.queued_arr[slot])
            if q or prev:
                self.global_load[lid] = self.global_load.get(lid, 0) - prev + q
                self._published[lid] = q

    # -- batched completion feedback (the drain half of the closed loop) -----
    @hot_path
    def on_complete_many(self, slots, lengths, queued_at_schedule, t_obs) -> None:
        """Vectorized twin of `LinkTelemetry.on_complete` over one completion
        batch, **exactly** (bit-for-bit) equal to looping `on_complete` in
        batch order.

        Per-slot the EWMA recurrence is order-sensitive, so repeated slots
        within one batch are applied in *occurrence rounds*: round r updates
        every slot's r-th occurrence, each round touches a slot at most once,
        and updates of distinct slots touch disjoint array elements — so the
        per-slot sequence is preserved while each round runs as whole-array
        float64 arithmetic (the same IEEE operations, in the same per-slot
        order, the scalar path performs). `slots`/`lengths`/
        `queued_at_schedule` are int64 arrays, `t_obs` float64, all in drain
        order."""
        slots = np.asarray(slots, dtype=np.int64)
        n = slots.shape[0]
        if n == 0:
            return
        lengths = np.asarray(lengths, dtype=np.int64)
        queued_at = np.asarray(queued_at_schedule, dtype=np.int64)
        t_obs = np.asarray(t_obs, dtype=np.float64)
        if n == 1:
            # single completion: the scalar view update beats any gather
            self._views[slots[0]].on_complete(
                int(lengths[0]), int(queued_at[0]), float(t_obs[0]))
            return
        order = np.argsort(slots, kind="stable")
        ss = slots[order]
        starts = np.empty(n, dtype=bool)
        starts[0] = True
        np.not_equal(ss[1:], ss[:-1], out=starts[1:])
        if starts.all():  # all slots distinct: one round, no indirection
            self._complete_round(slots, lengths, queued_at, t_obs)
            return
        idx = np.arange(n)
        rank = idx - np.maximum.accumulate(np.where(starts, idx, 0))
        for r in range(int(rank.max()) + 1):
            sel = order[rank == r]
            self._complete_round(
                slots[sel], lengths[sel], queued_at[sel], t_obs[sel])

    @hot_path

    def _complete_round(self, idx, lengths, queued_at, t_obs) -> None:
        """One round of the batched EWMA update: `idx` holds *distinct* store
        slots. Mirrors `LinkTelemetry.on_complete` operation for operation."""
        self.queued_arr[idx] = np.maximum(0, self.queued_arr[idx] - lengths)
        self.completions_arr[idx] += 1
        alpha = self.ewma_alpha_arr[idx]
        x = (queued_at + lengths) / self.bandwidth_arr[idx]
        b1 = self.beta1_arr[idx]
        pos = x > 0
        if pos.all():
            sample = (t_obs - self.beta0_arr[idx]) / x
            sample = np.minimum(np.maximum(sample, 0.05), 1e4)
            b1 = (1 - alpha) * b1 + alpha * sample
            self.beta1_arr[idx] = b1
        elif pos.any():
            p = np.flatnonzero(pos)
            ip = idx[p]
            sample = (t_obs[p] - self.beta0_arr[ip]) / x[p]
            sample = np.minimum(np.maximum(sample, 0.05), 1e4)
            b1p = (1 - alpha[p]) * b1[p] + alpha[p] * sample
            b1[p] = b1p
            self.beta1_arr[ip] = b1p
        resid = np.maximum(0.0, t_obs - b1 * x)
        b0a = self.beta0_alpha_arr[idx]
        self.beta0_arr[idx] = (1 - b0a) * self.beta0_arr[idx] + b0a * resid
        self.ewma_service_arr[idx] = (
            (1 - alpha) * self.ewma_service_arr[idx] + alpha * t_obs)

    # -- jitted-core state transport (repro.core.jit_core) -------------------
    def gather_complete_state(self, pad_to: int):
        """Padded float64 copies of everything `on_complete_many` reads or
        writes, in the argument order of `tent_on_complete_many_jnp`:
        `(beta0, beta1, queued, ewma_service, completions, ewma_alpha,
        beta0_alpha, bandwidth)`. `pad_to` must be > `self.n`: rows past `n`
        are inert scratch (alpha 0, bandwidth 1 — no NaNs, no visible
        updates), and row `n` is the designated scratch slot batch padding
        scatters into. Copies, never views — the kernel's write-back goes
        through `scatter_complete_state`."""
        n = self.n
        out = []
        for name, fill in (("beta0_arr", 0.0), ("beta1_arr", 1.0),
                           ("queued_arr", 0.0), ("ewma_service_arr", 0.0),
                           ("completions_arr", 0.0), ("ewma_alpha_arr", 0.0),
                           ("beta0_alpha_arr", 0.0), ("bandwidth_arr", 1.0)):
            arr = np.full(pad_to, fill, dtype=np.float64)
            arr[:n] = getattr(self, name)[:n]
            out.append(arr)
        return tuple(out)

    def scatter_complete_state(self, beta0, beta1, queued, ewma_service,
                               completions) -> None:
        """Write back the five state vectors `on_complete_many` mutates from
        a jitted-kernel result (padded rows ignored). Queue depths and
        completion counts travel as float64 but are exact — the engine's
        byte counts stay far below 2**53 — so the int64 cast round-trips
        bit-identically with the numpy path."""
        n = self.n
        self.beta0_arr[:n] = beta0[:n]
        self.beta1_arr[:n] = beta1[:n]
        self.queued_arr[:n] = np.asarray(queued[:n], dtype=np.float64).astype(np.int64)
        self.ewma_service_arr[:n] = ewma_service[:n]
        self.completions_arr[:n] = np.asarray(
            completions[:n], dtype=np.float64).astype(np.int64)

    # -- bulk state ----------------------------------------------------------
    def reset_all(self) -> None:
        n = self.n
        self.beta0_arr[:n] = self.beta0_prior_arr[:n]
        self.beta1_arr[:n] = DEFAULT_BETA1
        self.slow_arr[:n] = 0

    def excluded_link_ids(self) -> List[int]:
        """Link ids of all currently soft-excluded rails — one vectorized
        scan of the exclusion array (the prober polls this every round)."""
        link_ids = self._link_ids
        return [link_ids[i] for i in np.flatnonzero(self.excluded_arr[: self.n])]

    def items(self):
        # a re-iterable sequence, like the dict view this used to return
        return list(zip(self._link_ids, self._views))
