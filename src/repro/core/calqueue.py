"""Calendar queue: a bucketed timestamp wheel for the fabric event loop.

A binary heap pays O(log n) per push/pop; at 10^5+ in-flight operations
(production-scale serving streams) that log factor is most of the event
loop's cost. A calendar queue exploits what heaps cannot: simulation
timestamps are *almost sorted* — events land a bounded horizon ahead of the
clock. Entries hash into fixed-width time buckets (`bucket = int(t /
width)`); only the bucket currently being drained is kept heap-ordered, all
future buckets are unordered append-only lists, and a small index heap of
non-empty bucket ids finds the next bucket to drain. Push is O(1) amortized
(a list append for any future bucket), pop is O(1) amortized plus one
heapify per bucket crossed.

Ordering contract (the bit-parity requirement): entries are the fabric's
`(time, seq, item)` tuples and pop order is *exactly* ascending `(time,
seq)` — identical to `heapq` on one flat list — so a `Fabric` running on a
calendar queue replays the same event sequence, the same RNG draw order,
and therefore the same simulation, byte for byte (pinned across the full
scenario library in tests/test_calendar_parity.py). Ties on `time` drain in
`seq` (post) order because the tuples compare lexicographically inside the
current bucket's heap.

Two structural invariants make the exact ordering cheap to keep:

* the clock is monotonic and `Fabric.call_at` clamps `t >= now`, so a push
  can target the *current* bucket (it joins the current heap) but never an
  already-drained one;
* a peek may advance the wheel to a future bucket before the clock gets
  there (`run_until` probes the next event time); a later push landing
  *between* the clock and that bucket is routed into the current heap too
  (`bucket <= cur_id`), which preserves global order because every future
  bucket's entries are strictly later than the entire current bucket span.

Width adapts online: when a drained bucket exceeds `resize_threshold`
entries, the width shrinks 4x and the wheel rebuilds (O(n), amortized over
the pops that follow). A badly sized width never affects ordering — in the
degenerate one-bucket case the structure *is* a binary heap.
"""
from __future__ import annotations

import heapq
from ..analysis import hot_path
from typing import List, Optional, Tuple

__all__ = ["CalendarQueue", "DEFAULT_WIDTH", "RESIZE_THRESHOLD"]

# Default bucket width (virtual seconds). The library's scenarios span
# microsecond service times to multi-second serving streams; 1 ms buckets
# keep both regimes off the degenerate paths, and the resize rule below
# corrects the rest.
DEFAULT_WIDTH = 1e-3

# A drained bucket larger than this triggers a 4x width shrink + rebuild.
RESIZE_THRESHOLD = 4096

# Never shrink below this width: degenerate timestamp distributions (many
# events at one instant) would otherwise rebuild forever without ever
# thinning the bucket.
MIN_WIDTH = 1e-9

Entry = Tuple[float, int, object]


class CalendarQueue:
    """Min-priority queue over `(time, seq, item)` tuples with exact
    `heapq`-equivalent pop order. Supports the four operations the fabric
    event loop needs: `push`, `pop`, `peek`, and truthiness."""

    __slots__ = ("width", "buckets", "index", "cur", "cur_id", "_len",
                 "resize_threshold")

    def __init__(self, width: float = DEFAULT_WIDTH, *,
                 resize_threshold: int = RESIZE_THRESHOLD):
        if width <= 0:
            raise ValueError(f"bucket width must be > 0, got {width}")
        self.width = float(width)
        self.resize_threshold = int(resize_threshold)
        self.buckets: dict = {}  # bucket id -> unordered entry list
        self.index: List[int] = []  # heap of non-empty future bucket ids
        self.cur: List[Entry] = []  # the bucket being drained, heap-ordered
        self.cur_id: Optional[int] = None
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        return self._len > 0

    @hot_path

    def push(self, entry: Entry) -> None:
        bid = int(entry[0] / self.width)
        cur_id = self.cur_id
        if cur_id is not None and bid <= cur_id:
            # current-bucket (or pre-advanced-wheel) landing: joins the
            # ordered heap so it drains before every future bucket
            heapq.heappush(self.cur, entry)
        else:
            lst = self.buckets.get(bid)
            if lst is None:
                self.buckets[bid] = [entry]
                heapq.heappush(self.index, bid)
            else:
                lst.append(entry)
        self._len += 1

    @hot_path

    def pop(self) -> Entry:
        if not self.cur:
            self._advance()
        self._len -= 1
        return heapq.heappop(self.cur)

    def peek(self) -> Entry:
        if not self.cur:
            self._advance()
        return self.cur[0]

    # -- internals -----------------------------------------------------------
    def _advance(self) -> None:
        """Make the next non-empty bucket current (caller guarantees the
        queue is non-empty). Oversized buckets trigger a width shrink and a
        full rebuild before draining."""
        bid = heapq.heappop(self.index)
        lst = self.buckets.pop(bid)
        if len(lst) > self.resize_threshold and self.width > MIN_WIDTH:
            self.buckets[bid] = lst
            heapq.heappush(self.index, bid)
            self.width = max(self.width / 4.0, MIN_WIDTH)
            self._rebuild()
            self._advance()
            return
        heapq.heapify(lst)
        self.cur = lst
        self.cur_id = bid

    def _rebuild(self) -> None:
        """Redistribute every entry under the (new) width. Resets the wheel
        position; the next `_advance` re-derives it from the entries."""
        entries: List[Entry] = list(self.cur)
        for lst in self.buckets.values():
            entries.extend(lst)
        self.buckets.clear()
        self.index = []
        self.cur = []
        self.cur_id = None
        n = self._len
        for e in entries:
            self.push(e)
        self._len = n  # push() re-counted the existing entries
