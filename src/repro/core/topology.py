"""Tiered topology discovery and reachability map (paper §3.1).

At initialization TENT enumerates NICs, GPUs, storage devices and their
interconnects, classifying links into protocol-independent affinity tiers:

  tier-1  optimal paths (NVLink, GPUDirect-affine NIC, same-NUMA rail)
  tier-2  cross-root connections (same NUMA node, different PCIe root)
  tier-3  NUMA-crossing fallbacks

The resulting tiered topology graph is the global ground truth for routing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .types import LinkClass, Location, MemoryKind

# Paper §4.2: P_tier = {1, 3, inf} for tiers 1..3.
DEFAULT_TIER_PENALTY: Dict[int, float] = {1: 1.0, 2: 3.0, 3: float("inf")}


@dataclasses.dataclass(frozen=True)
class LinkDesc:
    """Static description of one physical link (a schedulable 'device')."""

    link_id: int
    node: int
    link_class: LinkClass
    index: int  # NIC ordinal / GPU ordinal within the node
    numa: int
    bandwidth: float  # bytes/sec, nominal (telemetry corrects the truth)
    base_latency: float  # seconds

    @property
    def name(self) -> str:
        return f"n{self.node}/{self.link_class.value}{self.index}"


@dataclasses.dataclass
class NodeSpec:
    """One server. Defaults mirror the paper's H800 HGX testbed:
    8 GPUs + 8 x 200 Gbps NICs over two NUMA domains, NVLink intra-node."""

    n_numa: int = 2
    n_gpus: int = 8
    n_nics: int = 8

    def gpu_numa(self, gpu: int) -> int:
        return gpu * self.n_numa // max(self.n_gpus, 1)

    def nic_numa(self, nic: int) -> int:
        return nic * self.n_numa // max(self.n_nics, 1)

    def tier1_nic(self, gpu: int) -> int:
        """The NIC sharing the GPU's PCIe root complex (1:1 affinity)."""
        return gpu * self.n_nics // max(self.n_gpus, 1)


@dataclasses.dataclass
class FabricSpec:
    """Cluster description. Bandwidth constants follow the paper's testbed
    (8-rail 200 Gbps RoCE = 25 GB/s/NIC; NVLink 204.5 GB/s; io_uring 6 GB/s;
    MNNVL 956.2 GB/s; Ascend UB 196 GB/s)."""

    n_nodes: int = 2
    node: NodeSpec = dataclasses.field(default_factory=NodeSpec)
    nic_bw: float = 25.0e9
    nvlink_bw: float = 204.5e9
    mnnvl_bw: float = 956.2e9
    ub_bw: float = 196.0e9
    pcie_bw: float = 27.0e9
    shm_bw: float = 20.0e9
    tcp_bw: float = 3.0e9
    storage_bw: float = 6.0e9
    rdma_latency: float = 5e-6
    nvlink_latency: float = 1.5e-6
    pcie_latency: float = 3e-6
    tcp_latency: float = 40e-6
    shm_latency: float = 1e-6
    storage_latency: float = 80e-6
    # capability switches (portability matrix, paper §5.2)
    has_nvlink: bool = True
    has_gpudirect: bool = True
    has_mnnvl: bool = False
    has_ub: bool = False
    # submission-side NUMA crossing cost (paper §2.2: rails physically
    # distant from submission threads exhibit higher per-slice service time)
    cross_numa_latency: float = 30e-6
    cross_numa_bw_factor: float = 0.45


class Topology:
    """Materialized link graph + tier classification + reachability."""

    def __init__(self, spec: FabricSpec):
        self.spec = spec
        self.links: List[LinkDesc] = []
        self._rdma: Dict[Tuple[int, int], LinkDesc] = {}  # (node, nic)
        self._nvlink: Dict[Tuple[int, int], LinkDesc] = {}  # (node, gpu)
        self._mnnvl: Dict[Tuple[int, int], LinkDesc] = {}
        self._ub: Dict[Tuple[int, int], LinkDesc] = {}
        self._pcie: Dict[Tuple[int, int], LinkDesc] = {}
        self._shm: Dict[int, LinkDesc] = {}  # node
        self._tcp: Dict[int, LinkDesc] = {}
        self._storage: Dict[int, LinkDesc] = {}
        self._build()

    # -- discovery ---------------------------------------------------------
    def _add(self, node: int, cls: LinkClass, index: int, numa: int, bw: float, lat: float) -> LinkDesc:
        link = LinkDesc(
            link_id=len(self.links), node=node, link_class=cls, index=index,
            numa=numa, bandwidth=bw, base_latency=lat,
        )
        self.links.append(link)
        return link

    def _build(self) -> None:
        s = self.spec
        for n in range(s.n_nodes):
            for nic in range(s.node.n_nics):
                self._rdma[(n, nic)] = self._add(
                    n, LinkClass.RDMA, nic, s.node.nic_numa(nic), s.nic_bw, s.rdma_latency)
            for gpu in range(s.node.n_gpus):
                numa = s.node.gpu_numa(gpu)
                if s.has_nvlink:
                    self._nvlink[(n, gpu)] = self._add(
                        n, LinkClass.NVLINK, gpu, numa, s.nvlink_bw, s.nvlink_latency)
                if s.has_mnnvl:
                    self._mnnvl[(n, gpu)] = self._add(
                        n, LinkClass.MNNVL, gpu, numa, s.mnnvl_bw, s.nvlink_latency)
                if s.has_ub:
                    self._ub[(n, gpu)] = self._add(
                        n, LinkClass.UB, gpu, numa, s.ub_bw, s.nvlink_latency)
                self._pcie[(n, gpu)] = self._add(
                    n, LinkClass.PCIE, gpu, numa, s.pcie_bw, s.pcie_latency)
            self._shm[n] = self._add(n, LinkClass.SHM, 0, 0, s.shm_bw, s.shm_latency)
            self._tcp[n] = self._add(n, LinkClass.TCP, 0, 0, s.tcp_bw, s.tcp_latency)
            self._storage[n] = self._add(n, LinkClass.STORAGE, 0, 0, s.storage_bw, s.storage_latency)

    # -- accessors ----------------------------------------------------------
    def rdma_nics(self, node: int) -> List[LinkDesc]:
        return [self._rdma[(node, i)] for i in range(self.spec.node.n_nics)]

    def rdma_nic(self, node: int, nic: int) -> LinkDesc:
        return self._rdma[(node, nic)]

    def nvlink(self, node: int, gpu: int) -> Optional[LinkDesc]:
        return self._nvlink.get((node, gpu))

    def mnnvl(self, node: int, gpu: int) -> Optional[LinkDesc]:
        return self._mnnvl.get((node, gpu))

    def ub(self, node: int, gpu: int) -> Optional[LinkDesc]:
        return self._ub.get((node, gpu))

    def pcie(self, node: int, gpu: int) -> LinkDesc:
        return self._pcie[(node, gpu)]

    def shm(self, node: int) -> LinkDesc:
        return self._shm[node]

    def tcp(self, node: int) -> LinkDesc:
        return self._tcp[node]

    def storage(self, node: int) -> LinkDesc:
        return self._storage[node]

    # -- tier classification (paper §3.1 + §5.1.3) ---------------------------
    def nic_tier(self, src: Location, nic: LinkDesc) -> int:
        """Affinity tier of a local NIC with respect to a source location.

        DEVICE_HBM: tier-1 = the GPU's PCIe-root NIC; tier-2 = same-NUMA;
                    tier-3 = NUMA-crossing (penalty inf by default).
        HOST_DRAM:  tier-1 = same-NUMA NIC; tier-2 = cross-NUMA (hosts can
                    reach any NIC through the interconnect, at a cost).
        FILE:       all NICs tier-2 (data is staged through host anyway).
        """
        if src.kind == MemoryKind.DEVICE_HBM:
            if nic.index == self.spec.node.tier1_nic(src.device):
                return 1
            if nic.numa == self.spec.node.gpu_numa(src.device):
                return 2
            return 3
        if src.kind == MemoryKind.HOST_DRAM:
            return 1 if nic.numa == src.numa else 2
        return 2

    def remote_nic_for(self, dst: Location, local_nic: LinkDesc) -> LinkDesc:
        """Topology-aligned 1:1 remote endpoint mapping (paper §4.2):
        prefer the remote NIC sharing the destination buffer's root/NUMA and
        the same ordinal; the engine falls back dynamically on failure."""
        node = dst.node
        want = local_nic.index
        cand = self._rdma.get((node, want))
        if cand is not None:
            return cand
        return self.rdma_nics(node)[0]

    def remote_nic_alternatives(self, dst: Location, exclude: Tuple[int, ...] = ()) -> List[LinkDesc]:
        out = [l for l in self.rdma_nics(dst.node) if l.index not in exclude]
        # Prefer NICs near the destination buffer
        dst_numa = dst.numa if dst.kind == MemoryKind.HOST_DRAM else self.spec.node.gpu_numa(dst.device)
        out.sort(key=lambda l: (l.numa != dst_numa, l.index))
        return out
