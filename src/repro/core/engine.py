"""TENT engine: declarative BatchTransfer API over the execution pipeline.

Applications declare *what* moves (`allocate_batch` / `submit_transfer` /
`wait`); the engine decides *how*: Phase 1 resolves a ranked transport plan
(plan.py), Phase 2 sprays telemetry-scheduled slices across rails
(scheduler.py), Phase 3 absorbs faults in the data plane (resilience.py).
Completion is exposed through hierarchical counters: applications observe
only "batch X has N slices remaining" (paper §4.4).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import events as OBS
from ..analysis import hot_path
from .fabric import Fabric, FabricConfig
from .plan import Orchestrator, Stage, StageCandidates, TransportPlan, build_stage_candidates
from .resilience import HealthConfig, HealthMonitor
from .scheduler import Policy, TentPolicy, make_policy
from .segments import Segment, SegmentManager
from .slicing import DEFAULT_MAX_SLICES, DEFAULT_SLICE_BYTES, decompose
from .telemetry import TelemetryStore
from .topology import DEFAULT_TIER_PENALTY, FabricSpec, Topology
from .transports import WirePath, load_backends
from .types import (
    BatchState,
    EXHAUSTED_RETRIES,
    Location,
    Slice,
    SliceState,
    TentError,
    TransferRequest,
    next_batch_id,
    next_transfer_id,
)


# Runs shorter than this go through the scalar chooser: the vectorized wave
# kernel and the scalar path pick bit-identical rails, so the cutover is a
# pure cost decision — below it, array gather/scatter setup costs more than
# it saves (the steady-state closed loop re-dispatches one slice per
# completion, which must stay on the cheap path). WAVE_MIN is the neutral
# starting point; unless `EngineConfig.wave_min` pins it, each engine tunes
# its crossover online within [WAVE_MIN_FLOOR, WAVE_MIN_CEIL] from the run
# lengths and completion-batch sizes it actually observes (burst-heavy
# traffic amortizes kernel setup well -> lower crossover; a trickle of
# single completions cannot -> higher). Because both paths pick identical
# rails, the tuner can never change a scheduling decision, only its cost.
WAVE_MIN = 4
WAVE_MIN_FLOOR = 2
WAVE_MIN_CEIL = 8


@dataclasses.dataclass
class EngineConfig:
    policy: str = "tent"
    slice_bytes: int = DEFAULT_SLICE_BYTES
    max_slices: int = DEFAULT_MAX_SLICES
    max_inflight: int = 256  # worker-ring capacity (paper §4.4)
    gamma: float = 0.05
    tier_penalty: Optional[Dict[int, float]] = None
    reset_interval: float = 30.0  # periodic state reset (paper §4.2)
    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)
    # datapath overheads (paper §4.4): per-post submission cost, amortized by
    # opportunistic batched posting of `post_batch` work requests.
    submission_overhead: float = 1.5e-6
    post_batch: int = 16
    global_diffusion_weight: float = 0.0  # omega, off by default
    # hot-path controls. `wave` schedules pending slices a batch at a time
    # through the vectorized chooser (`TentPolicy.choose_wave`), falling back
    # to the scalar path only for retries/substitutions; `candidate_cache`
    # reuses the per-plan-stage candidate sets instead of re-enumerating wire
    # paths per slice. Both default on; turning both off reproduces the
    # pre-wave one-slice-at-a-time hot path (the `benchmarks/spray_hotpath`
    # comparator) with bit-identical scheduling decisions.
    wave: bool = True
    candidate_cache: bool = True
    # `wave_complete` batches the *drain* half of the closed loop: the fabric
    # delivers all completions landing at one virtual timestamp in a single
    # call, telemetry EWMA updates run vectorized (`on_complete_many`), and
    # failure fan-out retries flush through one batched post. Off reproduces
    # the per-completion scalar drain with bit-identical outcomes (pinned in
    # tests/test_complete_parity.py). `wave_min` pins the scalar/wave
    # dispatch crossover to a fixed value for determinism experiments; None
    # (default) lets the engine adapt it online from observed run lengths
    # and completion-batch sizes.
    wave_complete: bool = True
    wave_min: Optional[int] = None
    # `jit_core` routes the two array kernels of the closed loop — the wave
    # chooser and the batched completion drain — through jitted fixed-shape
    # lax.scan kernels (repro.core.jit_core), padded to power-of-two shape
    # buckets and run under x64 so results stay bit-identical to the numpy
    # path (pinned in tests/test_jit_parity.py). The scalar/wave Python path
    # remains the fallback for small batches (own online-tuned crossover,
    # mirroring `wave_min`), staged hops, retries, and app callbacks; engines
    # with a FlightRecorder attached fall back entirely (see
    # `attach_recorder`). Off by default: jax dispatch only pays off on fat
    # waves, and the default path must not require jax at import.
    jit_core: bool = False
    # Run the fabric event loop on the calendar queue (bucketed timestamp
    # wheel, `repro.core.calqueue`) instead of the binary heap. Bit-identical
    # pop order (pinned across the library in tests/test_calendar_parity.py);
    # O(1) amortized per event, which pays off at production-scale serving
    # streams (10^5+ in-flight events). Only consulted when the engine builds
    # its own fabric — a fabric passed in keeps its own FabricConfig.
    calendar_queue: bool = False


@dataclasses.dataclass
class _TransferCB:
    req: TransferRequest
    plan: TransportPlan
    remaining: int
    batch_id: int
    # (route_idx, hop) -> StageCandidates: per-transfer memo over the
    # engine-wide stage cache, so the wave grouping pays one cheap int-tuple
    # lookup per slice instead of hashing Stage locations
    stages: Dict[Tuple[int, int], StageCandidates] = dataclasses.field(
        default_factory=dict)
    # (src_seg, dst_seg, dst_is_phantom) resolved once at submit: every
    # slice of the transfer finishes against the same segments, so the
    # drain loop never re-resolves them
    segs: tuple = ()


@dataclasses.dataclass
class _BatchCB:
    batch_id: int
    state: BatchState = BatchState.OPEN
    remaining_slices: int = 0  # hierarchical top-level counter
    transfers: List[_TransferCB] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    completed_at: float = 0.0
    error: Optional[str] = None
    callbacks: List[Callable[["_BatchCB"], None]] = dataclasses.field(default_factory=list)

    @property
    def bytes_total(self) -> int:
        return sum(t.req.length for t in self.transfers)


@dataclasses.dataclass
class BatchResult:
    batch_id: int
    ok: bool
    submitted_at: float
    completed_at: float
    bytes: int
    error: Optional[str] = None

    @property
    def elapsed(self) -> float:
        return self.completed_at - self.submitted_at

    @property
    def throughput(self) -> float:
        return self.bytes / max(self.elapsed, 1e-12)


@dataclasses.dataclass(slots=True)
class _InflightSlice:
    sl: Slice
    tcb: _TransferCB
    path: WirePath
    t_pred: float
    queued_at_schedule: int
    scheduled_at: float
    slot: int = -1  # local link's telemetry-store slot (batched-drain gather)
    # pre-packed batched-drain columns, built once at post time so the drain
    # gathers a whole run with one zip instead of per-item attribute chases:
    # (slot, length, queued_at_schedule, scheduled_at, t_pred, local_link,
    #  remote_link or -1)
    drain: tuple = ()


class TentEngine:
    """One engine instance (one process in the paper's deployment model)."""

    def __init__(
        self,
        spec: Optional[FabricSpec] = None,
        *,
        topology: Optional[Topology] = None,
        fabric: Optional[Fabric] = None,
        segments: Optional[SegmentManager] = None,
        config: Optional[EngineConfig] = None,
        seed: int = 0,
        name: str = "engine",
    ):
        self.name = name  # tenant tag on a shared fabric (cluster deployments)
        if topology is None:
            topology = Topology(spec or FabricSpec())
        self.topology = topology
        self.config = config or EngineConfig()
        if fabric is None:
            fabric = Fabric(
                topology, seed=seed,
                config=FabricConfig(event_queue="calendar")
                if self.config.calendar_queue else None)
        self.fabric = fabric
        self.segments = segments or SegmentManager()
        self.backends = load_backends(topology)
        self.orchestrator = Orchestrator(self.backends)
        self.store = TelemetryStore()
        self.store.global_weight = self.config.global_diffusion_weight
        self.policy = self._make_policy(self.config)
        self.health = HealthMonitor(self.store, self.config.health)
        self._batches: Dict[int, _BatchCB] = {}
        self._pending: Deque[Tuple[Slice, _TransferCB]] = deque()
        self._inflight = 0
        self._open_work = 0  # batches submitted but not completed
        self._reset_timer_armed = False
        self._probe_timer_armed = False
        # hot-path state: the engine-wide per-stage candidate cache, the
        # amortized per-post submission latency, and whether the policy has
        # a vectorized wave chooser (only TentPolicy does; the baseline
        # ablations run the scalar loop over the same cached candidates)
        self._stage_cache: Dict[Stage, StageCandidates] = {}
        self._post_overhead = (
            self.config.submission_overhead / max(self.config.post_batch, 1))
        self._tier_penalty = (
            self.policy.tier_penalty if isinstance(self.policy, TentPolicy) else None)
        self._wave_policy = self.config.wave and isinstance(self.policy, TentPolicy)
        # scalar/wave dispatch crossover: pinned by config, or tuned online
        # from run-length / completion-batch EWMAs (`_tune_wave_min`)
        self._adaptive_wave_min = self.config.wave_min is None
        self._wave_min = (
            WAVE_MIN if self._adaptive_wave_min else max(1, self.config.wave_min))
        self._run_ewma = 0.0
        self._drain_ewma = 0.0
        # jitted-core adapter (EngineConfig.jit_core): None = scalar/numpy
        # path everywhere. Requires the wave-capable TentPolicy — baseline
        # ablation policies have no vectorized chooser to fuse.
        self._jit = None
        if self.config.jit_core and self._wave_policy:
            from . import jit_core as _jc
            if _jc.jax_available():
                self._jit = _jc.EngineJitCore(self.policy, self.store)
            else:
                import warnings
                warnings.warn(
                    "EngineConfig.jit_core requested but jax is unavailable; "
                    "falling back to the numpy wave path",
                    RuntimeWarning, stacklevel=3)
        # armed only inside the batched failure drain: scalar `_issue` calls
        # append their post specs here instead of posting, and the drain
        # flushes them through one `post_many` (stream-identical to the
        # deferred sequential posts)
        self._post_buffer: Optional[list] = None
        self._cb_batches = 0  # live batches with registered done-callbacks
        # observability
        self.slice_latencies: List[float] = []
        self.transfer_records: List[BatchResult] = []
        self.slices_retried = 0
        self.backend_substitutions = 0
        self.slices_issued = 0
        self.waves = 0
        self.completions_drained = 0
        self.completion_batches = 0
        # flight recorder (repro.obs): None = tracing off. Every record site
        # is one `self._rec` load and an `is not None` branch per *batch*
        # (wave / drain run / declared intent), never per slice — the
        # zero-cost-when-off contract the hot-path bench gates pin.
        self._rec = None
        if self.config.wave_complete:
            self.fabric.register_completion_sink(
                self._on_wire_done, self._on_wire_done_many)
        # pre-register telemetry for every link so resets/benchmarks see all
        for link in topology.links:
            self.store.ensure(link)

    def _make_policy(self, cfg: EngineConfig) -> Policy:
        if cfg.policy == "tent":
            return TentPolicy(
                tier_penalty=cfg.tier_penalty or dict(DEFAULT_TIER_PENALTY),
                gamma=cfg.gamma,
                store=self.store,
            )
        return make_policy(cfg.policy)

    def attach_recorder(self, rec) -> None:
        """Attach a `repro.obs.FlightRecorder` to this engine, its fabric,
        and its health monitor. Recording is strictly passive — appends
        inside existing callbacks, batch-granular — and never schedules
        fabric events, so attaching cannot perturb the simulation (pinned by
        the tracing-ON/OFF report-parity tests)."""
        self._rec = rec
        self.fabric.attach_recorder(rec)
        self.health.attach_recorder(rec, self.fabric, owner=self.name)
        if self._jit is not None:
            # Recorder appends (wave provenance snapshots, drain payloads)
            # must be statically absent inside jitted kernels — tracing them
            # would silently capture stale traced arrays. Tracing therefore
            # forces the scalar/numpy path, loudly; reports stay identical
            # because both paths are bit-exact (tests/test_obs.py pins this).
            import warnings
            warnings.warn(
                f"engine {self.name!r}: FlightRecorder attached with "
                "jit_core enabled; disabling the jitted core for this "
                "engine (record sites cannot run under jit)",
                RuntimeWarning, stacklevel=2)
            self._jit = None

    def register_metrics(self, reg) -> None:
        """Expose the engine's scheduling counters as lazy gauges on a
        `repro.obs.MetricsRegistry`. The counters stay plain int attributes
        (the hot path keeps its bare `+= 1`); the registry reads them at
        `collect()` time."""
        reg.gauge("slices_issued", lambda: float(self.slices_issued))
        reg.gauge("waves", lambda: float(self.waves))
        reg.gauge("completions_drained",
                  lambda: float(self.completions_drained))
        reg.gauge("completion_batches",
                  lambda: float(self.completion_batches))

    # ------------------------------------------------------------------ API
    def register_segment(self, location: Location, length: int, **kw) -> Segment:
        seg = self.segments.register(location, length, **kw)
        # derive transport capabilities from the topology (paper §3.1)
        caps = [
            be.name
            for be in self.backends.values()
            if any(
                be.feasible(location, other.location) or be.feasible(other.location, location)
                for other in self.segments.all_segments()
            )
        ]
        self.segments.set_transports(seg.segment_id, caps)
        return seg

    def allocate_batch(self) -> int:
        bc = _BatchCB(batch_id=next_batch_id())
        self._batches[bc.batch_id] = bc
        return bc.batch_id

    def submit_transfer(
        self,
        batch_id: int,
        transfers: Sequence[Tuple[int, int, int, int, int]],
    ) -> None:
        """transfers: (src_segment, src_offset, dst_segment, dst_offset, length)."""
        bc = self._batches[batch_id]
        if bc.state not in (BatchState.OPEN, BatchState.SUBMITTED):
            raise TentError("BatchClosed", f"batch {batch_id} is {bc.state}")
        first_submit = bc.state == BatchState.OPEN
        if first_submit:
            bc.state = BatchState.SUBMITTED
            bc.submitted_at = self.fabric.now
            self._open_work += 1
            self._arm_reset_timer()
        n_before = len(bc.transfers)
        for (src, soff, dst, doff, length) in transfers:
            req = TransferRequest(
                transfer_id=next_transfer_id(),
                src_segment=src, src_offset=soff,
                dst_segment=dst, dst_offset=doff, length=length,
            )
            src_seg, dst_seg = self.segments.get(src), self.segments.get(dst)
            # validate the whole declared range up front: phantom segments
            # never materialize bytes, so submit time is where out-of-range
            # offsets must fail loudly (real segments re-check per slice
            # inside read/write as before)
            src_seg._check_range(soff, length)
            dst_seg._check_range(doff, length)
            plan = self.orchestrator.resolve(src_seg, dst_seg)
            slices = decompose(
                req, batch_id,
                slice_bytes=self.config.slice_bytes, max_slices=self.config.max_slices,
            )
            tcb = _TransferCB(req=req, plan=plan, remaining=len(slices), batch_id=batch_id)
            tcb.segs = (src_seg, dst_seg, dst_seg.phantom)
            bc.transfers.append(tcb)
            bc.remaining_slices += len(slices)
            for sl in slices:
                sl.submitted_at = self.fabric.now
                self._pending.append((sl, tcb))
        rec = self._rec
        if rec is not None:
            new = bc.transfers[n_before:]
            rec.append(OBS.INTENT, self.fabric.now, {
                "engine": self.name, "batch": rec.bid(batch_id),
                "transfers": len(new),
                "slices": sum(t.remaining for t in new),
                "bytes": sum(t.req.length for t in new)})
        self._dispatch()

    def on_batch_done(self, batch_id: int, fn: Callable[[BatchResult], None]) -> None:
        bc = self._batches[batch_id]
        if not bc.callbacks and bc.state in (BatchState.OPEN, BatchState.SUBMITTED):
            # live batches carrying callbacks force the batched drain to
            # project batch completions while scanning (the callback cut);
            # while this is zero the scan takes the bookkeeping-free path
            self._cb_batches += 1
        bc.callbacks.append(lambda b: fn(self._result(b)))

    def get_transfer_status(self, batch_id: int) -> Tuple[BatchState, int]:
        bc = self._batches[batch_id]
        return bc.state, bc.remaining_slices

    def wait(self, batch_id: int, *, max_events: int = 50_000_000) -> BatchResult:
        bc = self._batches[batch_id]
        n = 0
        while bc.state == BatchState.SUBMITTED:
            if not self.fabric.step():
                raise TentError("Stalled", f"batch {batch_id} stuck with no events")
            n += 1
            if n > max_events:
                raise TentError("Livelock", f"batch {batch_id} exceeded event budget")
        return self._result(bc)

    def run_until_idle(self) -> None:
        self.fabric.run_until_idle()

    def transfer_sync(self, src: int, soff: int, dst: int, doff: int, length: int) -> BatchResult:
        b = self.allocate_batch()
        self.submit_transfer(b, [(src, soff, dst, doff, length)])
        return self.wait(b)

    def _result(self, bc: _BatchCB) -> BatchResult:
        return BatchResult(
            batch_id=bc.batch_id,
            ok=bc.state == BatchState.DONE,
            submitted_at=bc.submitted_at,
            completed_at=bc.completed_at,
            bytes=bc.bytes_total,
            error=bc.error,
        )

    # ------------------------------------------------------------- dispatch
    @hot_path
    def _dispatch(self) -> None:
        """Drain the pending ring into the fabric, a wave at a time.

        Pops up to the worker-ring headroom worth of slices, groups
        consecutive runs that share a plan-stage candidate set, and issues
        each run in one batch: the TENT policy scores the whole run through
        the vectorized wave chooser (sequential line-11 queue charges
        preserved), baseline policies loop the scalar chooser over the same
        cached candidates, and the chosen paths are posted through one
        batched fabric call. Retries, staged-hop continuations, and backend
        substitutions keep using the scalar `_issue` path."""
        if not self.config.wave:
            while self._pending and self._inflight < self.config.max_inflight:
                sl, tcb = self._pending.popleft()
                if self._batches[tcb.batch_id].state != BatchState.SUBMITTED:
                    continue  # batch already failed; drop
                self._issue(sl, tcb, retry_exclude=())
            return
        while self._pending and self._inflight < self.config.max_inflight:
            budget = self.config.max_inflight - self._inflight
            wave: List[Tuple[Slice, _TransferCB]] = []
            while self._pending and len(wave) < budget:
                sl, tcb = self._pending.popleft()
                if self._batches[tcb.batch_id].state != BatchState.SUBMITTED:
                    continue  # batch already failed; drop
                wave.append((sl, tcb))
            if not wave:
                return
            self._issue_wave(wave)

    def _stage_cands(self, tcb: _TransferCB, hop: int) -> StageCandidates:
        """The candidate set for a transfer's current (route, hop) stage,
        resolved through the per-transfer memo and the engine-wide stage
        cache (stages are static given the topology, so one build serves
        every slice that ever crosses the stage)."""
        key = (tcb.plan.route_idx, hop)
        sc = tcb.stages.get(key)
        if sc is not None:
            return sc
        stage = tcb.plan.current.stages[hop]
        sc = self._stage_cache.get(stage) if self.config.candidate_cache else None
        if sc is None:
            sc = build_stage_candidates(
                stage, self.backends, self.store,
                tier_penalty=self._tier_penalty,
                post_overhead=self._post_overhead,
            )
            if self.config.candidate_cache:
                self._stage_cache[stage] = sc
        tcb.stages[key] = sc
        return sc

    def _issue_wave(self, wave: List[Tuple[Slice, _TransferCB]]) -> None:
        """Issue one popped wave: group by stage, choose in batch, post in
        batch. When a slice has no usable candidates (empty backend or
        tier-infeasible set) the slices after it are pushed back onto the
        pending ring and the problem slice takes the scalar substitution
        path — exactly the order the one-slice loop produced."""
        i, n = 0, len(wave)
        # set once a scalar _issue ran inside this wave: only then can a
        # batch have failed between pop time and a later run's posting
        dirty = False
        while i < n:
            sl, tcb = wave[i]
            sc = self._stage_cands(tcb, sl.hop)
            if not sc.paths:
                self._requeue_front(wave[i + 1:])
                # a scalar issue earlier in this wave may have failed this
                # slice's batch; the one-slice loop would drop it at pop
                # time, so the candidate-less fallback must not resurrect it
                # through the substitution path (it could post a dead
                # batch's slices on the next-best transport)
                if not dirty or \
                        self._batches[tcb.batch_id].state == BatchState.SUBMITTED:
                    self._issue(sl, tcb, retry_exclude=())
                return
            j = i + 1
            hop = sl.hop
            while j < n:
                sl2, tcb2 = wave[j]
                # same transfer, same hop -> same stage by construction; only
                # cross-transfer neighbours need the memo lookup
                if not (tcb2 is tcb and sl2.hop == hop) and \
                        self._stage_cands(tcb2, sl2.hop) is not sc:
                    break
                j += 1
            run = wave[i:j]
            if dirty:
                # a scalar issue earlier in this wave may have failed a
                # batch via exhausted substitution; drop its slices exactly
                # like the one-slice loop's pop-time check would
                run = [e for e in run
                       if self._batches[e[1].batch_id].state == BatchState.SUBMITTED]
                if not run:
                    i = j
                    continue
            if self._adaptive_wave_min:
                self._run_ewma = 0.75 * self._run_ewma + 0.25 * len(run)
                self._tune_wave_min()
            if self._wave_policy and len(run) >= self._wave_min:
                lengths = np.fromiter(
                    (s.length for s, _ in run), dtype=np.int64, count=len(run))
                rec = self._rec
                # decision provenance: snapshot the chooser's inputs *before*
                # the line-11 charges mutate the queue array (one dict of
                # fresh arrays per wave, nothing per slice)
                prov = self.policy.wave_inputs(sc) if rec is not None else None
                jit = self._jit
                if jit is not None and len(run) >= jit.min_batch:
                    choices, queued_at = jit.choose_wave(sc, lengths)
                else:
                    choices, queued_at = self.policy.choose_wave(sc, lengths)
                if rec is not None:
                    # slice refs, not ids: interning is deferred to the
                    # recorder's first read so the timed path stays O(1)
                    # dict-free per slice
                    rec.append(OBS.WAVE, self.fabric.now, {
                        "engine": self.name,
                        "slices": [s for s, _ in run],
                        "lengths": lengths,
                        "choices": choices,
                        "queued_at": queued_at,
                        "inputs": prov})
                if choices[-1] < 0:
                    # first infeasible slice ends the kernel's run: post what
                    # was scheduled, hand the bad slice to the scalar
                    # substitution path, push the rest back in order
                    k = int(np.argmax(choices < 0))
                    self._post_run(run[:k], sc, choices, queued_at)
                    self._requeue_front(list(run[k + 1:]) + list(wave[j:]))
                    bad_sl, bad_tcb = run[k]
                    self._issue(bad_sl, bad_tcb, retry_exclude=())
                    return
                self._post_run(run, sc, choices, queued_at)
            else:
                dirty = True
                for sl2, tcb2 in run:
                    # a substitution failure earlier in this run may have
                    # failed the batch; drop its remaining slices like the
                    # one-slice loop's pop-time check did
                    if self._batches[tcb2.batch_id].state != BatchState.SUBMITTED:
                        continue
                    self._issue(sl2, tcb2, retry_exclude=())
            i = j

    def _tune_wave_min(self) -> None:
        """Adapt the scalar/wave crossover online. The wave kernel pays an
        O(n_cands) array gather/scatter setup once per run while the scalar
        chooser pays O(n_cands) per slice, so the crossover should sit where
        typical runs amortize the setup: sustained long dispatch runs or fat
        completion batches (bursty traffic) push it to the floor, a trickle
        of single-slice redispatches (steady-state closed loop) pushes it to
        the ceiling. Deterministic given the virtual clock — the signal is
        structural (batch sizes), never wall-clock."""
        signal = self._run_ewma if self._run_ewma > self._drain_ewma \
            else self._drain_ewma
        if signal >= 2.0 * WAVE_MIN:
            self._wave_min = WAVE_MIN_FLOOR
        elif signal <= 0.5 * WAVE_MIN:
            self._wave_min = WAVE_MIN_CEIL
        else:
            self._wave_min = WAVE_MIN
        if self._jit is not None:
            # same structural signal drives the numpy/jit crossover
            self._jit.tune(signal)

    @property
    def wave_min(self) -> int:
        """The scalar/wave dispatch crossover currently in force (fixed when
        `EngineConfig.wave_min` pins it, otherwise the tuner's latest
        estimate)."""
        return self._wave_min

    def _requeue_front(self, items: Sequence[Tuple[Slice, _TransferCB]]) -> None:
        if items:
            self._pending.extendleft(reversed(items))

    def _post_run(
        self,
        run: Sequence[Tuple[Slice, _TransferCB]],
        sc: StageCandidates,
        choices,
        queued_at,
    ) -> None:
        """Build the inflight records for one scheduled run and enqueue the
        whole run through the fabric's batched post (one shared completion
        callback; no per-slice closures)."""
        if not len(run):
            return
        store = self.store
        beta0, beta1 = store.beta0_arr, store.beta1_arr
        charge_remote = store.charge_remote
        paths, slots, extras = sc.paths, sc.local_slot, sc.extra_latency
        bws = sc.bandwidth
        now = self.fabric.now
        inflight_state = SliceState.INFLIGHT
        specs = []
        append = specs.append
        for k, (sl, tcb) in enumerate(run):
            ci = choices[k]
            path = paths[ci]
            slot = slots[ci]
            q_after = int(queued_at[k])  # A_d at schedule time (incl. this slice)
            t_pred = beta0[slot] + beta1[slot] * q_after / bws[ci]
            inf = _InflightSlice(sl, tcb, path, t_pred, q_after, now, slot)
            # per-slice, not per-run: transfers at different route_idx can
            # share one stage by value, and the substitution-follow logic
            # compares sl.route_idx against the slice's OWN plan
            sl.route_idx = tcb.plan.route_idx
            sl.state = inflight_state
            local_link = path.local.link_id
            sl.scheduled_link = local_link
            remote = path.remote
            if remote is not None:
                # receiver-side accounting: published to the cluster's global
                # load table so peer engines see the incast forming (§4.2)
                rid = remote.link_id
                charge_remote(rid, sl.length)
                inf.drain = (slot, sl.length, q_after, now, t_pred,
                             local_link, rid)
                append((local_link, rid, sl.length,
                        extras[ci], path.bw_factor, inf))
            else:
                inf.drain = (slot, sl.length, q_after, now, t_pred,
                             local_link, -1)
                append((local_link, None, sl.length,
                        extras[ci], path.bw_factor, inf))
        self._inflight += len(specs)
        self.slices_issued += len(specs)
        self.waves += 1
        self.fabric.post_many(specs, self._on_wire_done, tenant=self.name)

    def _issue(self, sl: Slice, tcb: _TransferCB, *, retry_exclude: Sequence[int]) -> None:
        """Schedule one slice hop via the policy (or the reliability-first
        retry chooser) and post it to the fabric — the scalar path, kept for
        retries, staged-hop continuations, and backend substitutions."""
        try:
            sc = self._stage_cands(tcb, sl.hop)
            cands = sc.cands
            if retry_exclude or sl.attempts > 0:
                chosen = self.health.choose_retry(cands, retry_exclude)
                if chosen is None:
                    raise TentError("NoRetryCandidate", "all rails excluded")
                chosen.telemetry.on_schedule(sl.length)  # retries still charge queues
            else:
                chosen = self.policy.choose(cands, sl.length)
        except TentError:
            # No candidates on this backend: substitute the whole transport.
            if tcb.plan.substitute():
                self.backend_substitutions += 1
                rec = self._rec
                if rec is not None:
                    rec.append(OBS.SUBSTITUTE, self.fabric.now, {
                        "engine": self.name, "slice": sl,
                        "batch": rec.bid(tcb.batch_id)})
                sl.hop = 0
                self._issue(sl, tcb, retry_exclude=())
                return
            self._fail_batch(tcb, EXHAUSTED_RETRIES)
            return

        sl.route_idx = tcb.plan.route_idx
        path = sc.path_by_link[chosen.link_id]
        tl = chosen.telemetry
        queued_at_schedule = int(tl.queued_bytes)  # includes this slice (line 11)
        t_pred = tl.beta0 + tl.beta1 * queued_at_schedule / tl.desc.bandwidth
        now = self.fabric.now
        inf = _InflightSlice(
            sl=sl, tcb=tcb, path=path, t_pred=t_pred,
            queued_at_schedule=queued_at_schedule, scheduled_at=now,
            slot=tl.slot,
        )
        sl.state = SliceState.INFLIGHT
        sl.scheduled_link = path.local.link_id
        self._inflight += 1
        self.slices_issued += 1
        remote_link = path.remote.link_id if path.remote is not None else None
        inf.drain = (tl.slot, sl.length, queued_at_schedule, now, t_pred,
                     path.local.link_id,
                     remote_link if remote_link is not None else -1)
        if remote_link is not None:
            # receiver-side accounting: published to the cluster's global
            # load table so peer engines see the incast forming (§4.2)
            self.store.charge_remote(remote_link, sl.length)
        rec = self._rec
        if rec is not None:
            rec.append(OBS.POST, now, {
                "engine": self.name, "slice": sl,
                "link": path.local.link_id,
                "remote": remote_link if remote_link is not None else -1,
                "hop": sl.hop, "attempt": sl.attempts,
                "t_pred": t_pred, "queued": queued_at_schedule})
        buf = self._post_buffer
        if buf is not None:
            # batched failure drain: defer the post into the drain's single
            # post_many flush (stream- and event-identical to posting here)
            buf.append((path.local.link_id, remote_link, sl.length,
                        path.extra_latency + self._post_overhead,
                        path.bw_factor, inf))
            return
        self.fabric.post(
            path.local.link_id,
            remote_link,
            sl.length,
            self._on_wire_done,
            extra_latency=path.extra_latency + self._post_overhead,
            bw_scale=path.bw_factor,
            tenant=self.name,
            tag=inf,
        )

    def _on_wire_done(self, tag: "_InflightSlice", ok: bool, t0: float,
                      t1: float, err: str) -> None:
        """Shared tagged completion for every posted slice (wave or scalar):
        the fabric hands the `_InflightSlice` back, so posting needs no
        per-slice closure."""
        self.completions_drained += 1
        self._on_wire_complete(tag, ok, t1, err)

    # ----------------------------------------------------------- completion
    def _on_wire_complete(self, inf: _InflightSlice, ok: bool, t_end: float, err: str) -> None:
        """Scalar completion drain: one slice's full feedback sequence
        (telemetry EWMA / health / continuation or retry) plus a ring
        redispatch. The batched drain decomposes into exactly these handlers
        and must stay in lockstep with them."""
        self._inflight -= 1
        if inf.path.remote is not None:
            self.store.discharge_remote(inf.path.remote.link_id, inf.sl.length)
        if ok:
            self._handle_wire_success(inf, t_end)
        else:
            self._handle_wire_failure(inf, t_end)
        self._dispatch()

    def _handle_wire_success(self, inf: _InflightSlice, t_end: float) -> None:
        sl, tcb, tl = inf.sl, inf.tcb, self.store.get(inf.path.local.link_id)
        t_obs = t_end - inf.scheduled_at
        tl.on_complete(sl.length, inf.queued_at_schedule, t_obs)
        self.health.observe(tl.desc.link_id, t_obs, inf.t_pred)
        if tl.excluded:
            self._arm_probe_timer()  # implicit exclusion -> start probing
        route = tcb.plan.current
        rec = self._rec
        if rec is not None:
            rec.append(OBS.COMPLETE, t_end, {
                "engine": self.name,
                "slices": [sl],
                "links": (inf.path.local.link_id,),
                "scheduled": (inf.scheduled_at,),
                "t_pred": (inf.t_pred,),
                "lengths": (sl.length,),
                "hop": sl.hop})
        if sl.hop + 1 < len(route.stages):
            sl.hop += 1
            self._issue(sl, tcb, retry_exclude=())  # pipelined staged hop
        else:
            self._finish_slice(sl, tcb, t_end)

    def _handle_wire_failure(self, inf: _InflightSlice, t_end: float) -> None:
        sl, tcb, tl = inf.sl, inf.tcb, self.store.get(inf.path.local.link_id)
        rec = self._rec
        if rec is not None:
            rec.append(OBS.FAIL, t_end, {
                "engine": self.name, "slice": sl,
                "link": inf.path.local.link_id,
                "remote": (inf.path.remote.link_id
                           if inf.path.remote is not None else -1),
                "attempt": sl.attempts})
        tl.on_cancel(sl.length)
        self.health.on_path_failure(
            inf.path.local.link_id,
            inf.path.remote.link_id if inf.path.remote is not None else None,
        )
        self._arm_probe_timer()
        sl.attempts += 1
        self.slices_retried += 1
        if sl.attempts > self.config.health.retry_limit:
            if sl.route_idx != tcb.plan.route_idx:
                # another slice already substituted the backend: follow
                sl.hop = 0
                sl.attempts = 0
                self._issue(sl, tcb, retry_exclude=())
            elif tcb.plan.substitute():
                self.backend_substitutions += 1
                if rec is not None:
                    rec.append(OBS.SUBSTITUTE, t_end, {
                        "engine": self.name, "slice": sl,
                        "batch": rec.bid(tcb.batch_id)})
                sl.hop = 0
                sl.attempts = 0
                self._issue(sl, tcb, retry_exclude=())
            else:
                self._fail_batch(tcb, EXHAUSTED_RETRIES)
        else:
            # In-band recovery: reschedule on an alternative path now.
            self._issue(sl, tcb, retry_exclude=(inf.path.local.link_id,))

    # ------------------------------------------------- batched completion
    @hot_path
    def _on_wire_done_many(self, ops, now: float) -> None:
        """Batched completion drain (`EngineConfig.wave_complete`): the
        fabric delivers every tagged completion landing at one virtual
        timestamp in a single call, in heap (== scalar delivery) order.

        The walk peels the batch into maximal *vectorizable runs* —
        consecutive successful final-hop completions while the pending ring
        is empty — which drain through one `TelemetryStore.on_complete_many`
        + `HealthMonitor.observe_many` + one redispatch, and consecutive
        *failure runs*, which keep exact per-item bookkeeping order but
        flush their retry posts through one batched `post_many`. Anything
        else (staged-hop continuations, a non-empty pending ring, app
        callbacks that may submit new work mid-batch) falls back to the
        scalar per-item sequence, so the two drains stay bit-identical
        (pinned in tests/test_complete_parity.py)."""
        n = len(ops)
        self.completions_drained += n
        self.completion_batches += 1
        if self._adaptive_wave_min:
            self._drain_ewma = 0.75 * self._drain_ewma + 0.25 * n
            self._tune_wave_min()
        batches = self._batches
        i = 0
        while i < n:
            op = ops[i]
            inf = op.tag
            if op.failed:
                if self._pending:
                    self._on_wire_complete(inf, False, now, "LinkFailed")
                    i += 1
                else:
                    i = self._drain_failures(ops, i, now)
                continue
            if self._pending or \
                    inf.sl.hop + 1 < len(inf.tcb.plan.current.stages):
                self._on_wire_complete(inf, True, now, "")
                i += 1
                continue
            # scan the maximal vectorizable run. While no live batch carries
            # a done-callback (`_cb_batches == 0`) nothing mid-run can
            # submit new work, so the scan is a pure stage-shape check;
            # otherwise it also projects batch completions and cuts *after*
            # an item that completes a batch with registered callbacks (the
            # callback must observe the fully-drained per-item state exactly
            # like the scalar sequence exposes it)
            j = i
            hops: Dict[int, int] = {}  # route lengths memo (static mid-scan)
            run: List[_InflightSlice] = []
            if not self._cb_batches:
                while j < n:
                    op2 = ops[j]
                    if op2.failed:
                        break
                    inf2 = op2.tag
                    tcb2 = inf2.tcb
                    key = id(tcb2)
                    n_stages = hops.get(key)
                    if n_stages is None:
                        n_stages = hops[key] = len(tcb2.plan.current.stages)
                    if inf2.sl.hop + 1 < n_stages:
                        break
                    run.append(inf2)
                    j += 1
            else:
                rem: Dict[int, int] = {}
                while j < n:
                    op2 = ops[j]
                    if op2.failed:
                        break
                    inf2 = op2.tag
                    tcb2 = inf2.tcb
                    key = id(tcb2)
                    n_stages = hops.get(key)
                    if n_stages is None:
                        n_stages = hops[key] = len(tcb2.plan.current.stages)
                    if inf2.sl.hop + 1 < n_stages:
                        break
                    run.append(inf2)
                    bid = tcb2.batch_id
                    r = rem.get(bid)
                    if r is None:
                        r = batches[bid].remaining_slices
                    r -= 1
                    rem[bid] = r
                    j += 1
                    if r == 0 and batches[bid].callbacks:
                        break
            if j == i + 1:
                self._on_wire_complete(inf, True, now, "")
            else:
                self._drain_success_run(run, now)
            i = j

    @hot_path

    def _drain_success_run(self, infs: List[_InflightSlice], now: float) -> None:
        """Vectorized drain of one run of successful final-hop completions.
        The telemetry columns were pre-packed per slice at post time
        (`_InflightSlice.drain`), so the gather is one zip. Order-equivalent
        to the per-item scalar sequence because, with the pending ring
        empty, each item's trailing `_dispatch` is a no-op, the EWMA/health
        updates of distinct items touch disjoint telemetry state (per-slot
        order is preserved inside `on_complete_many` / `observe_many`),
        remote discharges are pure per-link sums nothing reads mid-run, and
        `_finish_slice` reads none of it."""
        self._inflight -= len(infs)
        slots_c, len_c, queued_c, sched_c, pred_c, links_c, remote_c = zip(
            *(inf.drain for inf in infs))
        store = self.store
        discharges: Dict[int, int] = {}  # remote link -> summed lengths
        for rid, length in zip(remote_c, len_c):
            if rid >= 0:
                discharges[rid] = discharges.get(rid, 0) + length
        discharge = store.discharge_remote
        for rid, total in discharges.items():
            discharge(rid, total)
        slots = np.asarray(slots_c, dtype=np.int64)
        lengths = np.asarray(len_c, dtype=np.int64)
        queued_at = np.asarray(queued_c, dtype=np.int64)
        t_obs = now - np.asarray(sched_c, dtype=np.float64)
        jit = self._jit
        if jit is not None and len(slots) >= jit.min_batch:
            jit.on_complete_many(slots, lengths, queued_at, t_obs)
        else:
            store.on_complete_many(slots, lengths, queued_at, t_obs)
        t_pred = np.asarray(pred_c, dtype=np.float64)
        if self.health.observe_many(slots, links_c, t_obs, t_pred):
            self._arm_probe_timer()
        rec = self._rec
        if rec is not None:
            # one append for the whole drain run — the batched-drain analogue
            # of the scalar handler's single-slice COMPLETE
            rec.append(OBS.COMPLETE, now, {
                "engine": self.name,
                "slices": [inf.sl for inf in infs],
                "links": links_c,
                "scheduled": sched_c,
                "t_pred": pred_c,
                "lengths": len_c})
        # one shared finish body with the scalar drain — any future
        # completion side effect lands in both drains by construction
        finish = self._finish_slice
        for inf in infs:
            finish(inf.sl, inf.tcb, now)
        self._dispatch()

    @hot_path

    def _drain_failures(self, ops, i: int, now: float) -> int:
        """Batched retry/requeue handler: process the run of consecutive
        failed completions starting at `i` with exact per-item bookkeeping
        (cancel charges, dual-layer exclusion, retry selection), deferring
        every retry's fabric post into one `post_many` flush — no per-slice
        closures, no per-slice post overhead, one trailing redispatch.
        Returns the index after the last item processed (early when an app
        callback refilled the pending ring: the rest of the batch takes the
        scalar per-item path)."""
        n = len(ops)
        buffer: list = []
        self._post_buffer = buffer
        try:
            while i < n and ops[i].failed:
                self._on_wire_complete_nofanout(ops[i].tag, now)
                i += 1
                if self._pending:
                    break
        finally:
            self._post_buffer = None
        if buffer:
            self.fabric.post_many(buffer, self._on_wire_done, tenant=self.name)
        self._dispatch()
        return i

    def _on_wire_complete_nofanout(self, inf: _InflightSlice, now: float) -> None:
        """One failure item inside the batched drain: identical to the
        scalar `_on_wire_complete(ok=False)` minus the per-item dispatch
        (a no-op while the pending ring is empty, which `_drain_failures`
        guarantees)."""
        self._inflight -= 1
        if inf.path.remote is not None:
            self.store.discharge_remote(inf.path.remote.link_id, inf.sl.length)
        self._handle_wire_failure(inf, now)

    def _finish_slice(self, sl: Slice, tcb: _TransferCB, t_end: float) -> None:
        # Idempotent write to the absolute destination offset. For staged
        # routes the intermediate hops are timing-only; bytes land here. A
        # phantom destination's write is a no-op, so skip materializing the
        # source bytes at all (phantom reads allocate a zero buffer per
        # slice — pure drain-loop waste for timing-only segments); bounds
        # were validated for the whole transfer at submit time.
        src_seg, dst_seg, dst_phantom = tcb.segs
        if not dst_phantom:
            dst_seg.write(sl.dst_offset, src_seg.read(sl.src_offset, sl.length))
        sl.state = SliceState.DONE
        sl.completed_at = t_end
        self.slice_latencies.append(t_end - sl.submitted_at)
        tcb.remaining -= 1
        bc = self._batches[tcb.batch_id]
        bc.remaining_slices -= 1
        if bc.remaining_slices == 0 and bc.state == BatchState.SUBMITTED:
            self._complete_app_batch(bc, t_end)

    def _complete_app_batch(self, bc: _BatchCB, t_end: float) -> None:
        """Last slice of an application batch landed: surface the completion
        through the hierarchical counters and run the registered callbacks."""
        bc.state = BatchState.DONE
        bc.completed_at = t_end
        self._open_work -= 1
        if bc.callbacks:
            self._cb_batches -= 1
        self.transfer_records.append(self._result(bc))
        rec = self._rec
        if rec is not None:
            rec.append(OBS.BATCH_DONE, t_end, {
                "engine": self.name, "batch": rec.bid(bc.batch_id),
                "bytes": bc.bytes_total})
        for cb in bc.callbacks:
            cb(bc)

    def _fail_batch(self, tcb: _TransferCB, code: str) -> None:
        # Inside the batched failure drain, deferred retry posts must reach
        # the fabric before any app callback runs (a callback may submit and
        # dispatch new work, and the scalar drain posted those retries
        # first); the buffer is disarmed around the callbacks so work they
        # trigger posts inline, exactly like the scalar sequence.
        buf = self._post_buffer
        if buf is not None:
            self._post_buffer = None
            if buf:
                self.fabric.post_many(
                    list(buf), self._on_wire_done, tenant=self.name)
                buf.clear()
        try:
            bc = self._batches[tcb.batch_id]
            if bc.state == BatchState.SUBMITTED:
                bc.state = BatchState.FAILED
                bc.error = code
                bc.completed_at = self.fabric.now
                rec = self._rec
                if rec is not None:
                    rec.append(OBS.BATCH_FAIL, bc.completed_at, {
                        "engine": self.name, "batch": rec.bid(bc.batch_id),
                        "error": code})
                self._open_work -= 1
                if bc.callbacks:
                    self._cb_batches -= 1
                for cb in bc.callbacks:
                    cb(bc)
        finally:
            if buf is not None:
                self._post_buffer = buf

    # ----------------------------------------------------------- timers
    def _arm_reset_timer(self) -> None:
        if self._reset_timer_armed or self.config.reset_interval <= 0:
            return
        self._reset_timer_armed = True
        self.fabric.call_after(self.config.reset_interval, self._on_reset_timer)

    def _on_reset_timer(self) -> None:
        self._reset_timer_armed = False
        # Periodic state reset (paper §4.2): forget learned penalties and
        # re-admit excluded rails so recovered paths rejoin the pool.
        for lid in self.health.excluded_links():
            self.health.readmit(lid)
        self.store.reset_all()
        if self._open_work > 0:
            self._arm_reset_timer()

    def _arm_probe_timer(self) -> None:
        if self._probe_timer_armed or self.config.health.probe_interval <= 0:
            return
        self._probe_timer_armed = True
        self.fabric.call_after(self.config.health.probe_interval, self._on_probe_timer)

    def _on_probe_timer(self) -> None:
        self._probe_timer_armed = False
        excluded = self.health.excluded_links()
        if not excluded:
            return
        for lid in excluded:
            self.fabric.post(
                lid, None, self.config.health.probe_bytes,
                lambda ok, t0, t1, err, l=lid: self._on_probe_done(l, ok),
            )
        if self._open_work > 0:
            self._arm_probe_timer()

    def _on_probe_done(self, link_id: int, ok: bool) -> None:
        if ok:
            self.health.readmit(link_id, verified=True)

    # ----------------------------------------------------------- metrics
    @property
    def open_batches(self) -> int:
        """Batches submitted but not yet completed/failed — the cluster
        control plane keeps its diffusion timer armed while any engine has
        open work."""
        return self._open_work

    def audit(self, *, ignore: Optional[Sequence[int]] = None) -> Dict[str, int]:
        """Batch/slice accounting across the engine's lifetime: every slice
        ever submitted must be either completed (its batch DONE) or surfaced
        as an application-visible batch failure — the zero-lost-slice
        invariant the scenario regression tier asserts. Batch ids in
        `ignore` (e.g. open-ended background tenant flows) are skipped."""
        skip = frozenset(ignore or ())
        out = {"batches_done": 0, "batches_failed": 0, "batches_open": 0,
               "slices_outstanding": 0}
        for bid, bc in self._batches.items():
            if bid in skip or bc.state == BatchState.OPEN:
                continue
            if bc.state == BatchState.DONE:
                out["batches_done"] += 1
            elif bc.state == BatchState.FAILED:
                out["batches_failed"] += 1
            else:
                out["batches_open"] += 1
                out["slices_outstanding"] += bc.remaining_slices
        return out

    def percentile_latency(self, q: float) -> float:
        if not self.slice_latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.slice_latencies), q))

    def bytes_by_link(self) -> Dict[int, int]:
        return self.fabric.bytes_by_link()
