"""TENT engine: declarative BatchTransfer API over the execution pipeline.

Applications declare *what* moves (`allocate_batch` / `submit_transfer` /
`wait`); the engine decides *how*: Phase 1 resolves a ranked transport plan
(plan.py), Phase 2 sprays telemetry-scheduled slices across rails
(scheduler.py), Phase 3 absorbs faults in the data plane (resilience.py).
Completion is exposed through hierarchical counters: applications observe
only "batch X has N slices remaining" (paper §4.4).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .fabric import Fabric
from .plan import Orchestrator, TransportPlan
from .resilience import HealthConfig, HealthMonitor
from .scheduler import Candidate, Policy, TentPolicy, make_policy
from .segments import Segment, SegmentManager
from .slicing import DEFAULT_MAX_SLICES, DEFAULT_SLICE_BYTES, decompose
from .telemetry import TelemetryStore
from .topology import DEFAULT_TIER_PENALTY, FabricSpec, Topology
from .transports import WirePath, load_backends
from .types import (
    BatchState,
    EXHAUSTED_RETRIES,
    Location,
    Slice,
    SliceState,
    TentError,
    TransferRequest,
    next_batch_id,
    next_transfer_id,
)


@dataclasses.dataclass
class EngineConfig:
    policy: str = "tent"
    slice_bytes: int = DEFAULT_SLICE_BYTES
    max_slices: int = DEFAULT_MAX_SLICES
    max_inflight: int = 256  # worker-ring capacity (paper §4.4)
    gamma: float = 0.05
    tier_penalty: Optional[Dict[int, float]] = None
    reset_interval: float = 30.0  # periodic state reset (paper §4.2)
    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)
    # datapath overheads (paper §4.4): per-post submission cost, amortized by
    # opportunistic batched posting of `post_batch` work requests.
    submission_overhead: float = 1.5e-6
    post_batch: int = 16
    global_diffusion_weight: float = 0.0  # omega, off by default


@dataclasses.dataclass
class _TransferCB:
    req: TransferRequest
    plan: TransportPlan
    remaining: int
    batch_id: int


@dataclasses.dataclass
class _BatchCB:
    batch_id: int
    state: BatchState = BatchState.OPEN
    remaining_slices: int = 0  # hierarchical top-level counter
    transfers: List[_TransferCB] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    completed_at: float = 0.0
    error: Optional[str] = None
    callbacks: List[Callable[["_BatchCB"], None]] = dataclasses.field(default_factory=list)

    @property
    def bytes_total(self) -> int:
        return sum(t.req.length for t in self.transfers)


@dataclasses.dataclass
class BatchResult:
    batch_id: int
    ok: bool
    submitted_at: float
    completed_at: float
    bytes: int
    error: Optional[str] = None

    @property
    def elapsed(self) -> float:
        return self.completed_at - self.submitted_at

    @property
    def throughput(self) -> float:
        return self.bytes / max(self.elapsed, 1e-12)


@dataclasses.dataclass
class _InflightSlice:
    sl: Slice
    tcb: _TransferCB
    path: WirePath
    t_pred: float
    queued_at_schedule: int
    scheduled_at: float


class TentEngine:
    """One engine instance (one process in the paper's deployment model)."""

    def __init__(
        self,
        spec: Optional[FabricSpec] = None,
        *,
        topology: Optional[Topology] = None,
        fabric: Optional[Fabric] = None,
        segments: Optional[SegmentManager] = None,
        config: Optional[EngineConfig] = None,
        seed: int = 0,
        name: str = "engine",
    ):
        self.name = name  # tenant tag on a shared fabric (cluster deployments)
        if topology is None:
            topology = Topology(spec or FabricSpec())
        self.topology = topology
        self.fabric = fabric or Fabric(topology, seed=seed)
        self.segments = segments or SegmentManager()
        self.config = config or EngineConfig()
        self.backends = load_backends(topology)
        self.orchestrator = Orchestrator(self.backends)
        self.store = TelemetryStore()
        self.store.global_weight = self.config.global_diffusion_weight
        self.policy = self._make_policy(self.config)
        self.health = HealthMonitor(self.store, self.config.health)
        self._batches: Dict[int, _BatchCB] = {}
        self._pending: Deque[Tuple[Slice, _TransferCB]] = deque()
        self._inflight = 0
        self._open_work = 0  # batches submitted but not completed
        self._reset_timer_armed = False
        self._probe_timer_armed = False
        # observability
        self.slice_latencies: List[float] = []
        self.transfer_records: List[BatchResult] = []
        self.slices_retried = 0
        self.backend_substitutions = 0
        # pre-register telemetry for every link so resets/benchmarks see all
        for link in topology.links:
            self.store.ensure(link)

    def _make_policy(self, cfg: EngineConfig) -> Policy:
        if cfg.policy == "tent":
            return TentPolicy(
                tier_penalty=cfg.tier_penalty or dict(DEFAULT_TIER_PENALTY),
                gamma=cfg.gamma,
                store=self.store,
            )
        return make_policy(cfg.policy)

    # ------------------------------------------------------------------ API
    def register_segment(self, location: Location, length: int, **kw) -> Segment:
        seg = self.segments.register(location, length, **kw)
        # derive transport capabilities from the topology (paper §3.1)
        caps = [
            be.name
            for be in self.backends.values()
            if any(
                be.feasible(location, other.location) or be.feasible(other.location, location)
                for other in self.segments.all_segments()
            )
        ]
        self.segments.set_transports(seg.segment_id, caps)
        return seg

    def allocate_batch(self) -> int:
        bc = _BatchCB(batch_id=next_batch_id())
        self._batches[bc.batch_id] = bc
        return bc.batch_id

    def submit_transfer(
        self,
        batch_id: int,
        transfers: Sequence[Tuple[int, int, int, int, int]],
    ) -> None:
        """transfers: (src_segment, src_offset, dst_segment, dst_offset, length)."""
        bc = self._batches[batch_id]
        if bc.state not in (BatchState.OPEN, BatchState.SUBMITTED):
            raise TentError("BatchClosed", f"batch {batch_id} is {bc.state}")
        first_submit = bc.state == BatchState.OPEN
        if first_submit:
            bc.state = BatchState.SUBMITTED
            bc.submitted_at = self.fabric.now
            self._open_work += 1
            self._arm_reset_timer()
        for (src, soff, dst, doff, length) in transfers:
            req = TransferRequest(
                transfer_id=next_transfer_id(),
                src_segment=src, src_offset=soff,
                dst_segment=dst, dst_offset=doff, length=length,
            )
            plan = self.orchestrator.resolve(self.segments.get(src), self.segments.get(dst))
            slices = decompose(
                req, batch_id,
                slice_bytes=self.config.slice_bytes, max_slices=self.config.max_slices,
            )
            tcb = _TransferCB(req=req, plan=plan, remaining=len(slices), batch_id=batch_id)
            bc.transfers.append(tcb)
            bc.remaining_slices += len(slices)
            for sl in slices:
                sl.submitted_at = self.fabric.now
                self._pending.append((sl, tcb))
        self._dispatch()

    def on_batch_done(self, batch_id: int, fn: Callable[[BatchResult], None]) -> None:
        bc = self._batches[batch_id]
        bc.callbacks.append(lambda b: fn(self._result(b)))

    def get_transfer_status(self, batch_id: int) -> Tuple[BatchState, int]:
        bc = self._batches[batch_id]
        return bc.state, bc.remaining_slices

    def wait(self, batch_id: int, *, max_events: int = 50_000_000) -> BatchResult:
        bc = self._batches[batch_id]
        n = 0
        while bc.state == BatchState.SUBMITTED:
            if not self.fabric.step():
                raise TentError("Stalled", f"batch {batch_id} stuck with no events")
            n += 1
            if n > max_events:
                raise TentError("Livelock", f"batch {batch_id} exceeded event budget")
        return self._result(bc)

    def run_until_idle(self) -> None:
        self.fabric.run_until_idle()

    def transfer_sync(self, src: int, soff: int, dst: int, doff: int, length: int) -> BatchResult:
        b = self.allocate_batch()
        self.submit_transfer(b, [(src, soff, dst, doff, length)])
        return self.wait(b)

    def _result(self, bc: _BatchCB) -> BatchResult:
        return BatchResult(
            batch_id=bc.batch_id,
            ok=bc.state == BatchState.DONE,
            submitted_at=bc.submitted_at,
            completed_at=bc.completed_at,
            bytes=bc.bytes_total,
            error=bc.error,
        )

    # ------------------------------------------------------------- dispatch
    def _dispatch(self) -> None:
        while self._pending and self._inflight < self.config.max_inflight:
            sl, tcb = self._pending.popleft()
            if self._batches[tcb.batch_id].state != BatchState.SUBMITTED:
                continue  # batch already failed; drop
            self._issue(sl, tcb, retry_exclude=())

    def _candidates(self, tcb: _TransferCB, hop: int) -> Tuple[List[Candidate], List[WirePath]]:
        stage = tcb.plan.current.stages[hop]
        be = self.backends[stage.backend]
        paths = be.paths(stage.src, stage.dst)
        cands = [
            Candidate(
                self.store.ensure(p.local), p.tier,
                remote=self.store.ensure(p.remote) if p.remote is not None else None,
            )
            for p in paths
        ]
        return cands, paths

    def _issue(self, sl: Slice, tcb: _TransferCB, *, retry_exclude: Sequence[int]) -> None:
        """Schedule one slice hop via the policy (or the reliability-first
        retry chooser) and post it to the fabric."""
        try:
            cands, paths = self._candidates(tcb, sl.hop)
            if retry_exclude or sl.attempts > 0:
                chosen = self.health.choose_retry(cands, retry_exclude)
                if chosen is None:
                    raise TentError("NoRetryCandidate", "all rails excluded")
                chosen.telemetry.on_schedule(sl.length)  # retries still charge queues
            else:
                chosen = self.policy.choose(cands, sl.length)
        except TentError:
            # No candidates on this backend: substitute the whole transport.
            if tcb.plan.substitute():
                self.backend_substitutions += 1
                sl.hop = 0
                self._issue(sl, tcb, retry_exclude=())
                return
            self._fail_batch(tcb, EXHAUSTED_RETRIES)
            return

        sl.route_idx = tcb.plan.route_idx
        path = next(p for p in paths if p.local.link_id == chosen.link_id)
        tl = chosen.telemetry
        queued_at_schedule = tl.queued_bytes  # includes this slice (line 11)
        t_pred = tl.beta0 + tl.beta1 * queued_at_schedule / tl.desc.bandwidth
        inf = _InflightSlice(
            sl=sl, tcb=tcb, path=path, t_pred=t_pred,
            queued_at_schedule=queued_at_schedule, scheduled_at=self.fabric.now,
        )
        sl.state = SliceState.INFLIGHT
        sl.scheduled_link = path.local.link_id
        self._inflight += 1
        if path.remote is not None:
            # receiver-side accounting: published to the cluster's global
            # load table so peer engines see the incast forming (§4.2)
            self.store.charge_remote(path.remote.link_id, sl.length)
        extra = path.extra_latency + self.config.submission_overhead / max(self.config.post_batch, 1)
        self.fabric.post(
            path.local.link_id,
            path.remote.link_id if path.remote is not None else None,
            sl.length,
            lambda ok, t0, t1, err, i=inf: self._on_wire_complete(i, ok, t1, err),
            extra_latency=extra,
            bw_scale=path.bw_factor,
            tenant=self.name,
        )

    # ----------------------------------------------------------- completion
    def _on_wire_complete(self, inf: _InflightSlice, ok: bool, t_end: float, err: str) -> None:
        self._inflight -= 1
        sl, tcb, tl = inf.sl, inf.tcb, self.store.get(inf.path.local.link_id)
        if inf.path.remote is not None:
            self.store.discharge_remote(inf.path.remote.link_id, sl.length)
        if ok:
            t_obs = t_end - inf.scheduled_at
            tl.on_complete(sl.length, inf.queued_at_schedule, t_obs)
            self.health.observe(tl.desc.link_id, t_obs, inf.t_pred)
            if tl.excluded:
                self._arm_probe_timer()  # implicit exclusion -> start probing
            route = tcb.plan.current
            if sl.hop + 1 < len(route.stages):
                sl.hop += 1
                self._issue(sl, tcb, retry_exclude=())  # pipelined staged hop
            else:
                self._finish_slice(sl, tcb, t_end)
        else:
            tl.on_cancel(sl.length)
            self.health.on_path_failure(
                inf.path.local.link_id,
                inf.path.remote.link_id if inf.path.remote is not None else None,
            )
            self._arm_probe_timer()
            sl.attempts += 1
            self.slices_retried += 1
            if sl.attempts > self.config.health.retry_limit:
                if sl.route_idx != tcb.plan.route_idx:
                    # another slice already substituted the backend: follow
                    sl.hop = 0
                    sl.attempts = 0
                    self._issue(sl, tcb, retry_exclude=())
                elif tcb.plan.substitute():
                    self.backend_substitutions += 1
                    sl.hop = 0
                    sl.attempts = 0
                    self._issue(sl, tcb, retry_exclude=())
                else:
                    self._fail_batch(tcb, EXHAUSTED_RETRIES)
            else:
                # In-band recovery: reschedule on an alternative path now.
                self._issue(sl, tcb, retry_exclude=(inf.path.local.link_id,))
        self._dispatch()

    def _finish_slice(self, sl: Slice, tcb: _TransferCB, t_end: float) -> None:
        # Idempotent write to the absolute destination offset. For staged
        # routes the intermediate hops are timing-only; bytes land here.
        src_seg = self.segments.get(sl.src_segment)
        dst_seg = self.segments.get(sl.dst_segment)
        dst_seg.write(sl.dst_offset, src_seg.read(sl.src_offset, sl.length))
        sl.state = SliceState.DONE
        sl.completed_at = t_end
        self.slice_latencies.append(t_end - sl.submitted_at)
        tcb.remaining -= 1
        bc = self._batches[tcb.batch_id]
        bc.remaining_slices -= 1
        if bc.remaining_slices == 0 and bc.state == BatchState.SUBMITTED:
            bc.state = BatchState.DONE
            bc.completed_at = t_end
            self._open_work -= 1
            res = self._result(bc)
            self.transfer_records.append(res)
            for cb in bc.callbacks:
                cb(bc)

    def _fail_batch(self, tcb: _TransferCB, code: str) -> None:
        bc = self._batches[tcb.batch_id]
        if bc.state == BatchState.SUBMITTED:
            bc.state = BatchState.FAILED
            bc.error = code
            bc.completed_at = self.fabric.now
            self._open_work -= 1
            for cb in bc.callbacks:
                cb(bc)

    # ----------------------------------------------------------- timers
    def _arm_reset_timer(self) -> None:
        if self._reset_timer_armed or self.config.reset_interval <= 0:
            return
        self._reset_timer_armed = True
        self.fabric.call_after(self.config.reset_interval, self._on_reset_timer)

    def _on_reset_timer(self) -> None:
        self._reset_timer_armed = False
        # Periodic state reset (paper §4.2): forget learned penalties and
        # re-admit excluded rails so recovered paths rejoin the pool.
        for lid in self.health.excluded_links():
            self.health.readmit(lid)
        self.store.reset_all()
        if self._open_work > 0:
            self._arm_reset_timer()

    def _arm_probe_timer(self) -> None:
        if self._probe_timer_armed or self.config.health.probe_interval <= 0:
            return
        self._probe_timer_armed = True
        self.fabric.call_after(self.config.health.probe_interval, self._on_probe_timer)

    def _on_probe_timer(self) -> None:
        self._probe_timer_armed = False
        excluded = self.health.excluded_links()
        if not excluded:
            return
        for lid in excluded:
            self.fabric.post(
                lid, None, self.config.health.probe_bytes,
                lambda ok, t0, t1, err, l=lid: self._on_probe_done(l, ok),
            )
        if self._open_work > 0:
            self._arm_probe_timer()

    def _on_probe_done(self, link_id: int, ok: bool) -> None:
        if ok:
            self.health.readmit(link_id, verified=True)

    # ----------------------------------------------------------- metrics
    @property
    def open_batches(self) -> int:
        """Batches submitted but not yet completed/failed — the cluster
        control plane keeps its diffusion timer armed while any engine has
        open work."""
        return self._open_work

    def audit(self, *, ignore: Optional[Sequence[int]] = None) -> Dict[str, int]:
        """Batch/slice accounting across the engine's lifetime: every slice
        ever submitted must be either completed (its batch DONE) or surfaced
        as an application-visible batch failure — the zero-lost-slice
        invariant the scenario regression tier asserts. Batch ids in
        `ignore` (e.g. open-ended background tenant flows) are skipped."""
        skip = frozenset(ignore or ())
        out = {"batches_done": 0, "batches_failed": 0, "batches_open": 0,
               "slices_outstanding": 0}
        for bid, bc in self._batches.items():
            if bid in skip or bc.state == BatchState.OPEN:
                continue
            if bc.state == BatchState.DONE:
                out["batches_done"] += 1
            elif bc.state == BatchState.FAILED:
                out["batches_failed"] += 1
            else:
                out["batches_open"] += 1
                out["slices_outstanding"] += bc.remaining_slices
        return out

    def percentile_latency(self, q: float) -> float:
        if not self.slice_latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.slice_latencies), q))

    def bytes_by_link(self) -> Dict[int, int]:
        return self.fabric.bytes_by_link()
