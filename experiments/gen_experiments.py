"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
final sweep JSONLs. The §Perf narrative is maintained by hand."""
import json
import sys


def load(path):
    return [json.loads(l) for l in open(path)]


def fmt_mem(ma):
    if not ma or "error" in ma:
        return "n/a"
    t = ma.get("temp_size_bytes") or 0
    a = ma.get("argument_size_bytes") or 0
    o = ma.get("output_size_bytes") or 0
    return f"arg {a/2**30:.2f} / out {o/2**30:.2f} / temp {t/2**30:.2f}"


def main(single_path, multi_path):
    single = load(single_path)
    multi = load(multi_path)
    out = []
    out.append("### Dry-run results (all 80 combinations)\n")
    out.append("Every (architecture x input shape) lowers AND compiles on both the")
    out.append("single-pod 16x16 mesh (256 chips) and the multi-pod 2x16x16 mesh")
    out.append("(512 chips). Compile wall-times are on this CPU host; GiB/dev is the")
    out.append("analytic params+optimizer+cache footprint implied by the shardings;")
    out.append("memory_analysis is XLA's argument/output/temp report (CPU backend —")
    out.append("temp is pessimistic vs TPU, see notes).\n")
    for label, rows in (("16x16 (single pod)", single), ("2x16x16 (multi-pod, 512 chips)", multi)):
        out.append(f"#### Mesh {label}\n")
        out.append("| arch | shape | lower s | compile s | GiB/dev | XLA memory (GiB) | collectives |")
        out.append("|---|---|---|---|---|---|---|")
        for r in rows:
            if "error" in r:
                out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
                continue
            colls = ",".join(
                f"{k.split('-')[-1] if False else k}:{int(v['count'])}"
                for k, v in sorted(r.get("collectives", {}).items())
            )
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['lower_s']} | {r['compile_s']} | "
                f"{r['analytic_bytes_per_device']/2**30:.2f} | {fmt_mem(r.get('memory_analysis'))} | {colls} |"
            )
        out.append("")
    out.append("### Roofline (single-pod 16x16, per chip per step)\n")
    out.append("Terms from the loop-aware HLO profiler (launch/hlo_analysis.py):")
    out.append("compute = dot-FLOPs/197 TF/s; memory = fusion-boundary bytes/819 GB/s;")
    out.append("collective = collective result bytes/50 GB/s-link. MODEL_FLOPS = 6ND")
    out.append("(train) / 2ND (prefill, decode per token), N = active params.\n")
    out.append("| arch | shape | compute s | memory s | collective s | bottleneck | MODEL_FLOPS | useful |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in single:
        if "error" in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | {rf['memory_s']:.3e} | "
            f"{rf['collective_s']:.3e} | {rf['bottleneck']} | {rf['model_flops']:.2e} | "
            f"{rf['useful_ratio']:.3f} |"
        )
    out.append("")
    print("\n".join(out))


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2])
