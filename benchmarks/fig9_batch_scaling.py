"""Fig. 9: host-to-host write throughput, ONE submission thread on NUMA
node 0 (4 local NICs => 800 Gbps ideal if cross-socket traffic is avoided),
4 MB blocks, batch size 1..128. NIXL's multirail threshold keeps 4 MB blocks
on a single NIC; Mooncake TE's randomized tier-1 selection ignores load."""
from __future__ import annotations

from .common import closed_loop, host_loc, make_engine

BLOCK = 4 << 20
BATCHES = [1, 2, 4, 8, 16, 32, 64, 128]
POLICIES = [("tent", "TENT"), ("round_robin", "MooncakeTE"), ("static_best2", "NIXL")]


def _one(policy: str, batch: int):
    eng = make_engine(policy, seed=2)
    src = eng.register_segment(host_loc(0, 0), BLOCK)
    dst = eng.register_segment(host_loc(1, 0), BLOCK)
    return closed_loop(eng, [(src.segment_id, dst.segment_id, BLOCK)],
                       iters=8, batch_size=batch)


def run() -> list:
    ideal = 4 * 25e9
    out = []
    tp = {}
    p90 = {}
    for policy, label in POLICIES:
        for batch in BATCHES:
            res = _one(policy, batch)
            tp[(label, batch)] = res.throughput
            p90[(label, batch)] = res.pct(90)
            out.append({
                "name": f"fig9.{label}.batch{batch}",
                "us_per_call": res.pct(90) * 1e6,
                "derived": f"GBps={res.throughput/1e9:.2f};pct_ideal={res.throughput/ideal*100:.1f}",
            })
    gains = [tp[("TENT", b)] / tp[("MooncakeTE", b)] for b in BATCHES]
    p90_impr = [1 - p90[("TENT", b)] / p90[("MooncakeTE", b)] for b in BATCHES]
    out.append({
        "name": "fig9.summary",
        "us_per_call": 0.0,
        "derived": (
            f"tent_vs_te_min={min(gains):.2f};tent_vs_te_max={max(gains):.2f};"
            f"avg_p90_reduction_pct={100*sum(p90_impr)/len(p90_impr):.1f}"
        ),
    })
    return out
