# Wall-clock hot-path microbench: slices scheduled/sec, wave vs pre-refactor.
"""Spray hot-path microbenchmark.

Measures how fast the engine can *schedule* slices — decompose an elephant,
resolve candidates, run Algorithm 1, charge queues, post to the fabric —
under a single-engine incast burst, and end-to-end under the cluster
kv_incast scenario. Three engine modes are compared:

  * ``wave``    — the current hot path: cached per-stage candidate sets +
                  vectorized wave chooser + batched fabric posts;
  * ``scalar``  — wave dispatch off, candidate cache on: the engine's own
                  scalar fallback path (what retries/substitutions use);
  * ``prewave`` — a verbatim re-implementation of the pre-refactor hot path
                  (per-slice candidate rebuild, scalar choose, O(paths)
                  linear path scan, per-slice completion closure), kept here
                  as the bench comparator so the speedup claim stays
                  reproducible against this exact code.

All three modes make bit-identical scheduling decisions (the wave-parity
regression in tests/test_wave_parity.py pins this), so the comparison is
pure overhead, not policy drift.

The ``hotpath_completion_drain`` section measures the *other* half of the
closed loop: completions drained per second under an incast burst on a
jitter-free fabric (identical service times across the receiver rails, so
completions land in same-timestamp groups — exactly the regime the batched
drain exploits), ``wave_complete`` on vs off. Both drains produce
bit-identical outcomes (tests/test_complete_parity.py), so this too is pure
overhead.

The ``hotpath_tracing_overhead`` section re-runs the wave-mode incast burst
with the flight recorder (repro.obs) off vs on and gates the ON arm at
``TRACING_MAX_REGRESSION`` — the observability layer's "zero cost when off,
bounded cost when on" contract, measured rather than asserted.

    python -m benchmarks.spray_hotpath                  # full run
    python -m benchmarks.spray_hotpath --quick          # CI smoke
    python -m benchmarks.spray_hotpath --out BENCH_hotpath.json

The --out document uses the same ``tent-scenario-reports/v1`` schema as
``benchmarks.run --scenario --out`` (scheduling/drain rate in the
``throughput`` slot), so ``benchmarks.diff old new --fail-on-regression
PCT`` tracks the hot-path trajectory with no extra tooling.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

from repro.core import EngineConfig, Fabric, FabricSpec, NodeSpec, TentEngine, Topology
from repro.core.engine import _InflightSlice
from repro.core.scheduler import Candidate
from repro.core.types import BatchState, Location, MemoryKind, SliceState

SCHEMA = "tent-scenario-reports/v1"
SPEEDUP_FLOOR = 3.0  # acceptance: wave >= 3x the pre-refactor hot path
DRAIN_SPEEDUP_FLOOR = 2.0  # acceptance: batched drain >= 2x the scalar drain
TRACING_MAX_REGRESSION = 0.10  # acceptance: flight recorder ON costs <= 10%


class PreWaveEngine(TentEngine):
    """The pre-refactor hot path, verbatim: one slice at a time, candidate
    objects rebuilt per slice, scalar ranking, linear path scan, per-slice
    completion closure. Kept only as this benchmark's comparator."""

    def _dispatch(self) -> None:
        while self._pending and self._inflight < self.config.max_inflight:
            sl, tcb = self._pending.popleft()
            if self._batches[tcb.batch_id].state != BatchState.SUBMITTED:
                continue
            self._issue(sl, tcb, retry_exclude=())

    def _candidates(self, tcb, hop):
        stage = tcb.plan.current.stages[hop]
        be = self.backends[stage.backend]
        paths = be.paths(stage.src, stage.dst)
        cands = [
            Candidate(
                self.store.ensure(p.local), p.tier,
                remote=self.store.ensure(p.remote) if p.remote is not None else None,
            )
            for p in paths
        ]
        return cands, paths

    def _issue(self, sl, tcb, *, retry_exclude=()):
        from repro.core.types import EXHAUSTED_RETRIES, TentError

        try:
            cands, paths = self._candidates(tcb, sl.hop)
            if retry_exclude or sl.attempts > 0:
                chosen = self.health.choose_retry(cands, retry_exclude)
                if chosen is None:
                    raise TentError("NoRetryCandidate", "all rails excluded")
                chosen.telemetry.on_schedule(sl.length)
            else:
                chosen = self.policy.choose(cands, sl.length)
        except TentError:
            if tcb.plan.substitute():
                self.backend_substitutions += 1
                sl.hop = 0
                self._issue(sl, tcb, retry_exclude=())
                return
            self._fail_batch(tcb, EXHAUSTED_RETRIES)
            return
        sl.route_idx = tcb.plan.route_idx
        path = next(p for p in paths if p.local.link_id == chosen.link_id)
        tl = chosen.telemetry
        queued_at_schedule = int(tl.queued_bytes)
        t_pred = tl.beta0 + tl.beta1 * queued_at_schedule / tl.desc.bandwidth
        inf = _InflightSlice(sl, tcb, path, t_pred, queued_at_schedule, self.fabric.now)
        sl.state = SliceState.INFLIGHT
        sl.scheduled_link = path.local.link_id
        self._inflight += 1
        self.slices_issued += 1
        if path.remote is not None:
            self.store.charge_remote(path.remote.link_id, sl.length)
        extra = path.extra_latency + self.config.submission_overhead / max(self.config.post_batch, 1)
        self.fabric.post(
            path.local.link_id,
            path.remote.link_id if path.remote is not None else None,
            sl.length,
            lambda ok, t0, t1, err, i=inf: self._on_wire_complete(i, ok, t1, err),
            extra_latency=extra,
            bw_scale=path.bw_factor,
            tenant=self.name,
        )


MODES = ("wave", "scalar", "prewave")


def _build_engine(mode: str, spec: FabricSpec, cfg: EngineConfig) -> TentEngine:
    if mode == "wave":
        return TentEngine(spec, config=cfg, seed=1)
    if mode == "scalar":
        return TentEngine(
            spec, config=dataclasses.replace(cfg, wave=False), seed=1)
    cfg = dataclasses.replace(
        cfg, wave=False, candidate_cache=False, wave_complete=False)
    return PreWaveEngine(spec, config=cfg, seed=1)


def _incast_once(mode: str, streams: int, block: int, recorder=None):
    """One incast-burst repetition: returns (sched_rate, e2e_rate, slices).
    With `recorder` set, the flight recorder is attached before the burst so
    the timed section includes the full recording cost."""
    cfg = EngineConfig(
        slice_bytes=64 * 1024, max_slices=512, max_inflight=1 << 20)
    eng = _build_engine(mode, FabricSpec(n_nodes=3, nic_bw=1e9), cfg)
    if recorder is not None:
        eng.attach_recorder(recorder)
    segs = []
    for i in range(streams):
        src = eng.register_segment(
            Location(node=i % 2, kind=MemoryKind.HOST_DRAM, numa=i % 2),
            block, materialize=False)
        dst = eng.register_segment(
            Location(node=2, kind=MemoryKind.HOST_DRAM, numa=i % 2),
            block, materialize=False)
        segs.append((src, dst))
    t0 = time.perf_counter()
    batches = []
    for src, dst in segs:
        b = eng.allocate_batch()
        eng.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, block)])
        batches.append(b)
    t_issue = time.perf_counter() - t0
    for b in batches:
        res = eng.wait(b)
        assert res.ok
    t_total = time.perf_counter() - t0
    slices = eng.slices_issued
    return slices / t_issue, slices / t_total, slices


def bench_single_incast(mode: str, *, streams: int, block: int, reps: int) -> dict:
    """Incast burst: `streams` elephants from two sender nodes converge on
    one receiver node; the worker ring is opened wide so every elephant's
    slices are scheduled in one dispatch. The timed section is the issue
    path (decompose -> candidates -> Algorithm 1 -> fabric post); the drain
    (fabric service + completions) runs untimed between bursts and is
    reported separately as the end-to-end rate."""
    best_sched, best_e2e = 0.0, 0.0
    slices = 0
    for _ in range(reps):
        sched, e2e, slices = _incast_once(mode, streams, block)
        best_sched = max(best_sched, sched)
        best_e2e = max(best_e2e, e2e)
    return {"slices": slices, "sched_rate": best_sched, "e2e_rate": best_e2e}


TRACE_MODES = ("off", "on")


def bench_tracing_pair(*, streams: int, block: int, reps: int):
    """The flight-recorder overhead column: the wave-mode incast burst with
    tracing off vs on (a `FlightRecorder` attached before the burst, so the
    timed issue path pays the per-wave provenance snapshot and every event
    append). Unlike the speedup benches (3x/2x floors, where best-of-reps
    maxima are fine) this gate rides a *ratio near 1.0*, so it needs two
    noise controls: the cyclic GC is paused with an explicit collect
    between repetitions (the ON arm retains thousands of payload dicts, so
    collector pauses otherwise land stochastically inside ~30ms timed
    sections and bill one rep's garbage to another — the appends themselves
    stay fully timed), and both arms take the median over interleaved
    repetitions after an untimed warm-up, which shrugs off the multi-10ms
    scheduler spikes shared hosts land on either arm. Returns the per-arm
    rows and the last ON repetition's recorder (for `--trace-out`)."""
    import gc
    import statistics

    from repro.obs import FlightRecorder

    _incast_once("wave", streams, block)  # warm-up: allocator + caches
    rows = {m: {"slices": 0, "t_issue": [], "t_total": []}
            for m in TRACE_MODES}
    recorder = None
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(reps):
            for m in TRACE_MODES:
                gc.collect()
                rec = FlightRecorder(capacity=1 << 18) if m == "on" else None
                sched, e2e, slices = _incast_once(
                    "wave", streams, block, recorder=rec)
                r = rows[m]
                r["slices"] = slices
                r["t_issue"].append(slices / sched)
                r["t_total"].append(slices / e2e)
                if rec is not None:
                    recorder = rec
    finally:
        if gc_was_enabled:
            gc.enable()
    for r in rows.values():
        r["sched_rate"] = r["slices"] / statistics.median(r.pop("t_issue"))
        r["e2e_rate"] = r["slices"] / statistics.median(r.pop("t_total"))
    rows["on"]["events"] = len(recorder)
    return rows, recorder


DRAIN_MODES = ("batched", "scalar")


def bench_completion_drain(mode: str, *, streams: int, block: int, reps: int) -> dict:
    """Completions drained/sec under an incast burst. `streams` elephants
    from two sender nodes converge on one fat 128-rail receiver; the fabric
    runs jitter-free so the parallel receiver chains stay in lockstep and
    completions land in same-timestamp groups of ~128 — the regime the
    batched drain is built for. Every slice is issued up-front (untimed)
    with the worker ring wide open; the timed section is the pure drain —
    event pops, per-op fabric accounting, telemetry EWMA feedback, health
    observation, slice finish — `wave_complete` on (batched: one sink call
    + `on_complete_many` per group) vs off (the per-completion scalar
    drain). Decisions and outcomes are bit-identical across the toggle
    (tests/test_complete_parity.py), so the ratio is pure drain overhead."""
    best_rate = 0.0
    drained = batches = 0
    for _ in range(reps):
        rate, drained, batches = _drain_once(mode, streams, block)
        best_rate = max(best_rate, rate)
    return {"slices": drained, "drain_rate": best_rate,
            "completion_batches": batches}


def _drain_once(mode: str, streams: int, block: int):
    """One measured drain: returns (completions/sec, drained, batches)."""
    cfg = EngineConfig(
        slice_bytes=64 * 1024, max_slices=1024, max_inflight=1 << 20,
        wave_complete=(mode == "batched"))
    topo = Topology(FabricSpec(
        n_nodes=3, nic_bw=1e9,
        node=NodeSpec(n_numa=1, n_gpus=0, n_nics=128)))
    eng = TentEngine(
        topology=topo, fabric=Fabric(topo, seed=1, jitter=0.0),
        config=cfg, seed=1)
    batches_ids = []
    for i in range(streams):
        src = eng.register_segment(
            Location(node=i % 2, kind=MemoryKind.HOST_DRAM, numa=0),
            block, materialize=False)
        dst = eng.register_segment(
            Location(node=2, kind=MemoryKind.HOST_DRAM, numa=0),
            block, materialize=False)
        b = eng.allocate_batch()
        eng.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, block)])
        batches_ids.append(b)
    t0 = time.perf_counter()
    eng.run_until_idle()
    t_drain = time.perf_counter() - t0
    for b in batches_ids:
        state, remaining = eng.get_transfer_status(b)
        assert state == BatchState.DONE and remaining == 0
    return eng.completions_drained / t_drain, eng.completions_drained, \
        eng.completion_batches


def bench_completion_drain_pair(*, streams: int, block: int, reps: int) -> dict:
    """Both drain arms measured with *interleaved* repetitions
    (batched, scalar, batched, scalar, ...): a background load spike on the
    host then deflates both arms rather than whichever arm happened to be
    running, which keeps the reported ratio honest on shared machines. Each
    arm reports its best repetition."""
    rows = {}
    for mode in DRAIN_MODES:
        rows[mode] = {"slices": 0, "drain_rate": 0.0, "completion_batches": 0}
    for _ in range(reps):
        for mode in DRAIN_MODES:
            rate, drained, batches = _drain_once(mode, streams, block)
            r = rows[mode]
            r["slices"], r["completion_batches"] = drained, batches
            r["drain_rate"] = max(r["drain_rate"], rate)
    return rows


def bench_cluster_kv_incast(mode: str) -> dict:
    """End-to-end cluster kv_incast: the library's multi_engine_kv_incast
    scenario (three prefill engines + decode pool + cache contender, global
    diffusion on) with the hot-path knobs toggled through EngineParams.
    `prewave` cannot be injected into TentCluster, so it reports the scalar
    no-cache configuration — the closest in-cluster stand-in."""
    from repro.scenarios import ScenarioRunner, get

    spec = get("multi_engine_kv_incast")
    if mode == "wave":
        engine = spec.engine
    elif mode == "scalar":
        engine = dataclasses.replace(spec.engine, wave=False)
    else:
        engine = dataclasses.replace(spec.engine, wave=False, candidate_cache=False)
    spec = dataclasses.replace(spec, engine=engine)
    t0 = time.perf_counter()
    report = ScenarioRunner(spec).run_policy("tent+diffusion")
    wall = time.perf_counter() - t0
    slices = int(report.extra["slices_issued"])
    return {"slices": slices, "sched_rate": slices / wall, "e2e_rate": slices / wall}


def _policy_report(rate: float, extra: dict) -> dict:
    """Minimal PolicyReport-shaped dict (the keys benchmarks.diff consumes)
    with the scheduling rate in the throughput slot."""
    return {
        "policy": extra["mode"],
        "ok": True,
        "throughput": rate,
        "recovery_ms": -1.0,
        "stall_ms": -1.0,
        "extra": extra,
    }


def run(quick: bool = False) -> list:
    streams = 8 if quick else 16
    reps = 2 if quick else 3
    docs = []

    rows = {}
    for mode in MODES:
        rows[mode] = bench_single_incast(
            mode, streams=streams, block=32 << 20, reps=reps)
    speedup = rows["wave"]["sched_rate"] / rows["prewave"]["sched_rate"]
    violations = []
    if speedup < SPEEDUP_FLOOR:
        violations.append(
            f"wave schedules {speedup:.2f}x the pre-refactor rate "
            f"(< {SPEEDUP_FLOOR:.1f}x floor)")
    docs.append({
        "scenario": "hotpath_single_incast",
        "ok": not violations,
        "violations": violations,
        "policies": {
            mode: _policy_report(
                r["sched_rate"],
                {"mode": mode, "slices": r["slices"],
                 "e2e_rate": r["e2e_rate"],
                 "speedup_vs_prewave": r["sched_rate"] / rows["prewave"]["sched_rate"]})
            for mode, r in rows.items()
        },
        "spec": {"policies": list(MODES), "streams": streams,
                 "block": 32 << 20, "reps": reps},
    })

    # the drain bench is cheap (pure event-loop wall clock), so it keeps its
    # full burst even under --quick: fewer streams shrink the lockstep
    # chains and under-fill the completion batches the bench exists to weigh
    drain_streams = 16
    drain_reps = 3 if quick else 5
    drows = bench_completion_drain_pair(
        streams=drain_streams, block=32 << 20, reps=drain_reps)
    drain_speedup = drows["batched"]["drain_rate"] / drows["scalar"]["drain_rate"]
    drain_violations = []
    if drain_speedup < DRAIN_SPEEDUP_FLOOR:
        drain_violations.append(
            f"batched drain completes {drain_speedup:.2f}x the scalar drain "
            f"rate (< {DRAIN_SPEEDUP_FLOOR:.1f}x floor)")
    docs.append({
        "scenario": "hotpath_completion_drain",
        "ok": not drain_violations,
        "violations": drain_violations,
        "policies": {
            mode: _policy_report(
                r["drain_rate"],
                {"mode": mode, "slices": r["slices"],
                 "completion_batches": r["completion_batches"],
                 "speedup_vs_scalar":
                     r["drain_rate"] / drows["scalar"]["drain_rate"]})
            for mode, r in drows.items()
        },
        "spec": {"policies": list(DRAIN_MODES), "streams": drain_streams,
                 "block": 32 << 20, "reps": drain_reps},
    })

    cluster_modes = MODES if not quick else ("wave", "prewave")
    crows = {mode: bench_cluster_kv_incast(mode) for mode in cluster_modes}
    docs.append({
        "scenario": "hotpath_cluster_kv_incast",
        "ok": True,
        "violations": [],
        "policies": {
            mode: _policy_report(
                r["sched_rate"], {"mode": mode, "slices": r["slices"]})
            for mode, r in crows.items()
        },
        "spec": {"policies": list(cluster_modes)},
    })

    # each repetition is cheap (~0.2s) and the gate rides a ratio of two
    # wall-clock rates, so extra interleaved reps buy flake resistance
    # (median-of-5 tolerates two noise spikes per arm)
    trace_reps = max(5, 2 * reps)
    trows, trace_rec = bench_tracing_pair(
        streams=streams, block=32 << 20, reps=trace_reps)
    on_vs_off = trows["on"]["sched_rate"] / trows["off"]["sched_rate"]
    trace_violations = []
    if on_vs_off < 1.0 - TRACING_MAX_REGRESSION:
        trace_violations.append(
            f"tracing-on schedules {on_vs_off:.2f}x the tracing-off rate "
            f"(< {1.0 - TRACING_MAX_REGRESSION:.2f}x floor)")
    docs.append({
        "scenario": "hotpath_tracing_overhead",
        "ok": not trace_violations,
        "violations": trace_violations,
        "policies": {
            mode: _policy_report(
                r["sched_rate"],
                {"mode": mode, "slices": r["slices"],
                 "e2e_rate": r["e2e_rate"],
                 "on_vs_off": on_vs_off,
                 **({"events": r["events"]} if "events" in r else {})})
            for mode, r in trows.items()
        },
        "spec": {"policies": list(TRACE_MODES), "streams": streams,
                 "block": 32 << 20, "reps": trace_reps},
    })
    return docs, trace_rec


def render(docs: list) -> None:
    for doc in docs:
        print(f"\n{doc['scenario']}")
        print(f"  {'mode':9s} {'slices':>8s} {'sched rate':>14s} {'e2e rate':>14s}")
        for mode, rep in doc["policies"].items():
            ex = rep["extra"]
            e2e = ex.get("e2e_rate", rep["throughput"])
            print(f"  {mode:9s} {ex['slices']:8d} "
                  f"{rep['throughput']:>11,.0f}/s {e2e:>11,.0f}/s")
        for mode, rep in doc["policies"].items():
            if "speedup_vs_prewave" in rep["extra"] and mode == "wave":
                print(f"  wave vs pre-refactor: "
                      f"{rep['extra']['speedup_vs_prewave']:.2f}x "
                      f"(floor {SPEEDUP_FLOOR:.1f}x)")
            if "speedup_vs_scalar" in rep["extra"] and mode == "batched":
                print(f"  batched vs scalar drain: "
                      f"{rep['extra']['speedup_vs_scalar']:.2f}x "
                      f"(floor {DRAIN_SPEEDUP_FLOOR:.1f}x, "
                      f"{rep['extra']['completion_batches']} batches)")
            if "on_vs_off" in rep["extra"] and mode == "on":
                print(f"  tracing on vs off: "
                      f"{rep['extra']['on_vs_off']:.2f}x "
                      f"(floor {1.0 - TRACING_MAX_REGRESSION:.2f}x, "
                      f"{rep['extra']['events']} events recorded)")
        for v in doc["violations"]:
            print(f"  VIOLATION: {v}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller burst + fewer reps (CI smoke)")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write the rates as a tent-scenario-reports/v1 "
                         "document (default: BENCH_hotpath.json; compare "
                         "runs with benchmarks.diff)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="export the tracing-on incast burst as a "
                         "Perfetto/Chrome-trace JSON (load at "
                         "ui.perfetto.dev or chrome://tracing)")
    args = ap.parse_args(argv)
    docs, trace_rec = run(quick=args.quick)
    render(docs)
    if args.trace_out:
        from repro.obs import export_chrome_trace, to_json
        with open(args.trace_out, "w") as f:
            f.write(to_json(export_chrome_trace(trace_rec)))
        print(f"wrote {args.trace_out}", file=sys.stderr)
    out = args.out or "BENCH_hotpath.json"
    with open(out, "w") as f:
        json.dump({
            "schema": SCHEMA,
            "generated_unix": round(time.time(), 3),
            "scenarios": len(docs),
            "violated": sum(not d["ok"] for d in docs),
            "reports": docs,
        }, f, indent=2)
        f.write("\n")
    print(f"\nwrote {out}", file=sys.stderr)
    if any(not d["ok"] for d in docs):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
