"""Table 3: model parameter updates with the Moonshot-style Checkpoint
Engine. Every rank pulls its FP16 weight shard through the transfer engine;
only the backend policy differs. Qwen3-235B-A22B and GLM-4.5-Air sizes
(scaled 1/64 to keep slice counts tractable on the event simulator — the
improvement ratio, which is what Table 3 demonstrates, is scale-invariant)."""
from __future__ import annotations

import numpy as np

from repro.serving import CheckpointEngine

from .common import add_background_turbulence, make_engine

SCALE = 64
MODELS = {
    "Qwen3-235B-A22B": int(235e9 * 2 / SCALE),
    "GLM-4.5-Air": int(110e9 * 2 / SCALE),
}


def _one(policy: str, nbytes: int) -> float:
    eng = make_engine(policy, seed=6, max_slices=128)
    add_background_turbulence(eng, seed=17, horizon=400.0, severity=0.6)
    ce = CheckpointEngine(eng, nodes=2, gpus_per_node=8, materialize=False)
    ce.register_checkpoint({"ckpt": nbytes})
    return ce.update().seconds * SCALE


def run() -> list:
    out = []
    for model, nbytes in MODELS.items():
        te = _one("round_robin", nbytes)
        tent = _one("tent", nbytes)
        out.append({
            "name": f"table3.{model}",
            "us_per_call": tent * 1e6,
            "derived": (
                f"te_s={te:.2f};tent_s={tent:.2f};improvement_pct={100*(1-tent/te):.1f}"
            ),
        })
    return out
