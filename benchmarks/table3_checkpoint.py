"""Table 3: model parameter updates with the Moonshot-style Checkpoint
Engine. Every rank pulls its FP16 weight shard through the transfer engine;
only the backend policy differs. Qwen3-235B-A22B and GLM-4.5-Air sizes
(scaled 1/64 to keep slice counts tractable on the event simulator — the
improvement ratio, which is what Table 3 demonstrates, is scale-invariant).
Each model is one `ScenarioSpec` with a tent/round-robin ablation list."""
from __future__ import annotations

import dataclasses

from repro.scenarios import (
    BackgroundSpec,
    CheckpointWorkload,
    EngineParams,
    ScenarioRunner,
    get,
)

SCALE = 64
MODELS = {
    "Qwen3-235B-A22B": int(235e9 * 2 / SCALE),
    "GLM-4.5-Air": int(110e9 * 2 / SCALE),
}


def _spec(model: str, nbytes: int):
    return dataclasses.replace(
        get("checkpoint_broadcast"),
        name=f"table3_{model}",
        workload=CheckpointWorkload(nbytes=nbytes),
        background=BackgroundSpec(turbulence_severity=0.6, turbulence_seed=17,
                                  turbulence_horizon=400.0),
        engine=EngineParams(max_slices=128, reset_interval=30.0,
                            probe_interval=0.05),
        seed=6,
    )


def run() -> list:
    out = []
    for model, nbytes in MODELS.items():
        report = ScenarioRunner(_spec(model, nbytes)).run()
        te = report.policies["round_robin"].extra["update_seconds"] * SCALE
        tent = report.policies["tent"].extra["update_seconds"] * SCALE
        out.append({
            "name": f"table3.{model}",
            "us_per_call": tent * 1e6,
            "derived": (
                f"te_s={te:.2f};tent_s={tent:.2f};improvement_pct={100*(1-tent/te):.1f}"
            ),
        })
        assert not report.violations, report.violations
    return out
