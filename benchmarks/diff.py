# Diff two BENCH_*.json trajectory files: per-scenario deltas + regression gate.
"""Bench trajectory diff driver.

`python -m benchmarks.run --scenario all --out BENCH_<rev>.json` writes one
self-describing document per run; this module compares two of them and
prints, for every scenario present in both, the primary policy's throughput
delta and the recovery/stall movement — the part of a PR's impact that a
pass/fail test tier cannot see.

    python -m benchmarks.diff BENCH_old.json BENCH_new.json
    python -m benchmarks.diff BENCH_old.json BENCH_new.json --policy tent
    python -m benchmarks.diff BENCH_old.json BENCH_new.json --fail-on-regression 5

With `--fail-on-regression PCT` the process exits non-zero when any compared
scenario's primary-policy throughput dropped by more than PCT percent (or a
scenario that used to pass its expectations now violates them), so CI and
scripted workflows can gate on trajectory health, not just correctness.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

SCHEMA = "tent-scenario-reports/v1"


def load_reports(path: str) -> Dict[str, dict]:
    """BENCH document -> {scenario name: report dict}. Accepts either the
    --out document shape or a bare list of reports (forward tolerance)."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        if doc.get("schema") != SCHEMA:
            raise SystemExit(
                f"{path}: expected schema {SCHEMA!r}, got {doc.get('schema')!r}")
        reports = doc.get("reports")
        if reports is None:
            raise SystemExit(f"{path}: document has no 'reports' section")
    else:
        reports = doc
    out = {}
    for r in reports:
        name = r.get("scenario")
        if name is None:
            raise SystemExit(f"{path}: report entry without a 'scenario' name")
        out[name] = r
    return out


def primary_policy(report: dict, override: Optional[str] = None) -> Optional[str]:
    """The policy to compare: --policy override, else the spec's primary
    (first in the ablation list), else the first recorded policy."""
    policies = report.get("policies", {})
    if override is not None:
        return override if override in policies else None
    declared = report.get("spec", {}).get("policies") or []
    if declared and declared[0] in policies:
        return declared[0]
    return next(iter(policies), None)


def _pct(old: float, new: float) -> Optional[float]:
    if old <= 0:
        return None
    return (new - old) / old * 100.0


def _fmt_pct(p: Optional[float]) -> str:
    return "n/a" if p is None else f"{p:+6.1f}%"


def _fmt_ms(v: float) -> str:
    return "-" if v < 0 else f"{v:.1f}ms"


def diff_reports(
    old: Dict[str, dict],
    new: Dict[str, dict],
    *,
    policy: Optional[str] = None,
) -> Tuple[List[dict], List[str], List[str], List[str], List[str]]:
    """Rows for scenarios in both files, plus the added/removed name lists,
    the common scenarios skipped because the compared policy was not run on
    both sides, and `incomparable` messages for rows one side of which is
    missing the compared metric (reported, never silently dropped — a
    half-written or schema-drifted trajectory must not look healthy). Each
    row: scenario, policy, old/new throughput, delta %, recovery and stall
    movement, and whether expectations regressed (ok -> violated)."""
    rows: List[dict] = []
    skipped: List[str] = []
    incomparable: List[str] = []
    for name in sorted(set(old) & set(new)):
        o, n = old[name], new[name]
        pol = primary_policy(n, policy)
        if pol is None or pol not in o.get("policies", {}):
            skipped.append(name)  # the policy was not run on both sides
            continue
        op, np_ = o["policies"][pol], n["policies"][pol]
        missing = [
            f"{side} is missing metric {metric!r}"
            for side, rep in (("baseline", op), ("candidate", np_))
            for metric in ("throughput",)
            if metric not in rep
        ]
        if missing:
            incomparable.append(f"{name} [{pol}]: " + "; ".join(missing))
            continue
        rows.append({
            "scenario": name,
            "policy": pol,
            "old_throughput": op["throughput"],
            "new_throughput": np_["throughput"],
            "delta_pct": _pct(op["throughput"], np_["throughput"]),
            # recovery/stall are secondary movement columns: -1 already
            # means "not applicable", so a missing key renders as '-'
            "old_recovery_ms": op.get("recovery_ms", -1.0),
            "new_recovery_ms": np_.get("recovery_ms", -1.0),
            "old_stall_ms": op.get("stall_ms", -1.0),
            "new_stall_ms": np_.get("stall_ms", -1.0),
            "ok_regressed": bool(o.get("ok", True)) and not bool(n.get("ok", True)),
        })
    added = sorted(set(new) - set(old))
    removed = sorted(set(old) - set(new))
    return rows, added, removed, skipped, incomparable


def render(rows: List[dict], added: List[str], removed: List[str]) -> None:
    if rows:
        print(f"{'scenario':28s} {'policy':16s} {'old':>10s} {'new':>10s} "
              f"{'delta':>8s}  {'recovery':>15s}  {'stall':>15s}")
        for r in rows:
            rec = f"{_fmt_ms(r['old_recovery_ms'])} -> {_fmt_ms(r['new_recovery_ms'])}"
            stall = f"{_fmt_ms(r['old_stall_ms'])} -> {_fmt_ms(r['new_stall_ms'])}"
            flag = "  EXPECTATIONS-REGRESSED" if r["ok_regressed"] else ""
            print(f"{r['scenario']:28s} {r['policy']:16s} "
                  f"{r['old_throughput'] / 1e9:10.3f} "
                  f"{r['new_throughput'] / 1e9:10.3f} "
                  f"{_fmt_pct(r['delta_pct']):>8s}  {rec:>15s}  {stall:>15s}{flag}")
        print("(throughput in GB/s for byte workloads, Gtok/s for serving; "
              "recovery/stall in virtual ms, '-' = no fault onset)")
    for name in added:
        print(f"+ {name}: only in the new trajectory")
    for name in removed:
        print(f"- {name}: only in the old trajectory")


def worst_regression(rows: List[dict]) -> Tuple[Optional[str], float]:
    """(scenario, drop %) of the largest throughput drop; (None, 0) if none."""
    worst, worst_name = 0.0, None
    for r in rows:
        if r["delta_pct"] is not None and -r["delta_pct"] > worst:
            worst, worst_name = -r["delta_pct"], r["scenario"]
    return worst_name, worst


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json (benchmarks.run --out)")
    ap.add_argument("new", help="candidate BENCH_*.json to compare against it")
    ap.add_argument("--policy", metavar="NAME",
                    help="compare this policy instead of each scenario's primary")
    ap.add_argument("--fail-on-regression", metavar="PCT", type=float,
                    help="exit non-zero if any scenario's throughput dropped "
                         "more than PCT percent, a passing scenario now "
                         "violates its expectations, or a common scenario "
                         "could not be compared (missing metric)")
    ap.add_argument("--allow-expectation-regressions", action="store_true",
                    help="with --fail-on-regression, do not fail on ok->"
                         "violated flips (for gates whose expectations embed "
                         "wall-clock speedup floors that are noisy on shared "
                         "runners); throughput drops and incomparable "
                         "scenarios still fail")
    args = ap.parse_args(argv)

    rows, added, removed, skipped, incomparable = diff_reports(
        load_reports(args.old), load_reports(args.new), policy=args.policy)
    if not rows and not added and not removed and not skipped and not incomparable:
        raise SystemExit("no scenarios in common and nothing added/removed")
    render(rows, added, removed)
    for name in skipped:
        print(f"! {name}: policy "
              f"{args.policy or 'primary'!r} not present in both trajectories "
              "— skipped", file=sys.stderr)
    for msg in incomparable:
        print(f"! {msg} — not compared", file=sys.stderr)
    if args.policy is not None and not rows and not incomparable:
        # a typo'd/renamed --policy must not let the gate pass on zero rows
        raise SystemExit(
            f"--policy {args.policy!r} matched no scenario present in both "
            "trajectories; nothing was compared")

    name, drop = worst_regression(rows)
    if name is not None:
        print(f"worst throughput regression: {name} -{drop:.1f}%", file=sys.stderr)
    if args.fail_on_regression is not None:
        if incomparable:
            # a half-written or schema-drifted trajectory must not pass the
            # gate by being impossible to compare
            print(f"FAIL: {len(incomparable)} scenario(s) could not be "
                  "compared (see '!' lines above)", file=sys.stderr)
            raise SystemExit(1)
        broken = [r["scenario"] for r in rows if r["ok_regressed"]]
        if broken and not args.allow_expectation_regressions:
            print(f"FAIL: expectations regressed in {', '.join(broken)}",
                  file=sys.stderr)
            raise SystemExit(1)
        if broken:
            print("warning: expectations regressed in "
                  f"{', '.join(broken)} (allowed by "
                  "--allow-expectation-regressions)", file=sys.stderr)
        if name is not None and drop > args.fail_on_regression:
            print(f"FAIL: {name} dropped {drop:.1f}% "
                  f"(> {args.fail_on_regression:.1f}% budget)", file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: no regression beyond {args.fail_on_regression:.1f}%",
              file=sys.stderr)


if __name__ == "__main__":
    main()
