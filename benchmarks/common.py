"""Shared TEBench-style harness (paper §5.1.3, inspired by NIXLBench).

Issues repeated synchronous transfer requests from multiple submission
"threads" (closed-loop actors on the virtual clock), with configurable block
size, batch size, and thread count. Policies are swapped per run:
  tent          TENT (telemetry-driven slice spraying)
  round_robin   Mooncake TE (state-blind striping)
  static_best2  NIXL/UCX (static best-K rails)
  pinned        UCCL-P2P (one NIC per region)

The submission loop and the contention generators are the declarative
scenario subsystem's (repro.scenarios) — benchmarks and the regression tier
drive the exact same code; this module only keeps the TEBench-flavoured
entry points (explicit segments, LoadResult).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import EngineConfig, FabricSpec, Location, MemoryKind, TentEngine
from repro.scenarios import (
    add_background_turbulence,
    add_tenant_contention,
    drive_closed_loop,
    host_loc,
)

__all__ = [
    "host_loc", "gpu_loc", "make_engine", "LoadResult", "closed_loop",
    "add_background_turbulence", "add_tenant_contention", "fmt_gbps",
]


def gpu_loc(spec: FabricSpec, node: int, gpu: int) -> Location:
    return Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu,
                    numa=spec.node.gpu_numa(gpu))


def make_engine(policy: str = "tent", *, spec: Optional[FabricSpec] = None,
                seed: int = 0, **cfg_kw) -> TentEngine:
    return TentEngine(
        spec or FabricSpec(),
        config=EngineConfig(policy=policy, **cfg_kw),
        seed=seed,
    )


@dataclasses.dataclass
class LoadResult:
    latencies: np.ndarray  # per-request completion latency (s)
    makespan: float
    bytes_total: int

    @property
    def throughput(self) -> float:  # bytes/s
        return self.bytes_total / max(self.makespan, 1e-12)

    def pct(self, q: float) -> float:
        return float(np.percentile(self.latencies, q))


def closed_loop(
    engine: TentEngine,
    streams: Sequence[Tuple[int, int, int]],  # (src_seg, dst_seg, block_bytes)
    *,
    iters: int,
    batch_size: int = 1,
) -> LoadResult:
    """Each stream is one submission thread: it keeps exactly one batch of
    `batch_size` transfers in flight, resubmitting on completion, `iters`
    times. Returns per-request latencies on the virtual clock."""
    out = drive_closed_loop(engine, list(streams), iters=iters, batch_size=batch_size)
    return LoadResult(
        latencies=np.asarray([c[2] for c in out.completions]),
        makespan=out.makespan,
        bytes_total=out.bytes_total,
    )


def fmt_gbps(bps: float) -> str:
    return f"{bps / 1e9:.2f}"
