"""Shared TEBench-style harness (paper §5.1.3, inspired by NIXLBench).

Issues repeated synchronous transfer requests from multiple submission
"threads" (closed-loop actors on the virtual clock), with configurable block
size, batch size, and thread count. Policies are swapped per run:
  tent          TENT (telemetry-driven slice spraying)
  round_robin   Mooncake TE (state-blind striping)
  static_best2  NIXL/UCX (static best-K rails)
  pinned        UCCL-P2P (one NIC per region)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import EngineConfig, FabricSpec, Location, MemoryKind, TentEngine


def host_loc(node: int, numa: int = 0) -> Location:
    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


def gpu_loc(spec: FabricSpec, node: int, gpu: int) -> Location:
    return Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu,
                    numa=spec.node.gpu_numa(gpu))


def make_engine(policy: str = "tent", *, spec: Optional[FabricSpec] = None,
                seed: int = 0, **cfg_kw) -> TentEngine:
    return TentEngine(
        spec or FabricSpec(),
        config=EngineConfig(policy=policy, **cfg_kw),
        seed=seed,
    )


@dataclasses.dataclass
class LoadResult:
    latencies: np.ndarray  # per-request completion latency (s)
    makespan: float
    bytes_total: int

    @property
    def throughput(self) -> float:  # bytes/s
        return self.bytes_total / max(self.makespan, 1e-12)

    def pct(self, q: float) -> float:
        return float(np.percentile(self.latencies, q))


def closed_loop(
    engine: TentEngine,
    streams: Sequence[Tuple[int, int, int]],  # (src_seg, dst_seg, block_bytes)
    *,
    iters: int,
    batch_size: int = 1,
) -> LoadResult:
    """Each stream is one submission thread: it keeps exactly one batch of
    `batch_size` transfers in flight, resubmitting on completion, `iters`
    times. Returns per-request latencies on the virtual clock."""
    latencies: List[float] = []
    done = {i: 0 for i in range(len(streams))}
    t_start = engine.fabric.now
    bytes_total = 0

    def submit(i: int) -> None:
        nonlocal bytes_total
        src, dst, block = streams[i]
        b = engine.allocate_batch()
        t0 = engine.fabric.now
        engine.submit_transfer(b, [(src, 0, dst, 0, block)] * batch_size)
        bytes_total += block * batch_size

        def on_done(res, i=i, t0=t0):
            latencies.append(engine.fabric.now - t0)
            done[i] += 1
            if done[i] < iters:
                submit(i)

        engine.on_batch_done(b, on_done)

    for i in range(len(streams)):
        submit(i)
    guard = 0
    while any(d < iters for d in done.values()):
        if not engine.fabric.step():
            raise RuntimeError("fabric idle before load completed")
        guard += 1
        if guard > 60_000_000:
            raise RuntimeError("bench event budget exceeded")
    return LoadResult(
        latencies=np.asarray(latencies),
        makespan=engine.fabric.now - t_start,
        bytes_total=bytes_total,
    )


def add_background_turbulence(engine: TentEngine, *, seed: int = 7,
                              horizon: float = 60.0, severity: float = 0.5) -> None:
    """Transient per-rail slowdowns (noisy neighbours / signal degradation,
    paper §2.2): deterministic schedule of degradation windows on RDMA rails."""
    rng = np.random.default_rng(seed)
    for node in range(engine.topology.spec.n_nodes):
        for nic in engine.topology.rdma_nics(node):
            # windows cover t=0 onward so short virtual-time experiments see
            # the same non-uniform fabric that long-running services do
            t = 0.0
            while t < horizon:
                dur = float(rng.uniform(0.05, 0.5))
                if rng.random() < 0.4:
                    factor = float(rng.uniform(1 - severity, 0.9))
                    engine.fabric.schedule_degradation(nic.link_id, at=t, until=t + dur, factor=factor)
                t += dur + float(rng.uniform(0.0, 0.3))


def add_tenant_contention(engine: TentEngine, *, streams: int = 4,
                          block: int = 64 << 20, horizon: float = 1e12) -> None:
    """Co-located tenants saturating the same rails (paper §2.2 "noisy
    neighbours"): closed-loop host-to-host elephant flows that run for the
    whole experiment, scheduled through the same engine/fabric."""
    for i in range(streams):
        numa = i % 2
        src = engine.register_segment(host_loc(0, numa), block, materialize=False)
        dst = engine.register_segment(host_loc(1, numa), block, materialize=False)

        def pump(src=src, dst=dst):
            if engine.fabric.now >= horizon:
                return
            b = engine.allocate_batch()
            engine.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, block)])
            engine.on_batch_done(b, lambda res: pump())

        pump()


def fmt_gbps(bps: float) -> str:
    return f"{bps / 1e9:.2f}"
