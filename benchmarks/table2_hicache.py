"""Table 2: SGLang HiCache multi-turn conversation benchmark.

Qwen3-235B-A22B on one 8-GPU node (TP8); the global KVCache pool's CPU/disk
tiers live on a storage node reached over the 8-rail fabric (Mooncake-store
style "global KVCache blocks"). Three configurations:
  baseline      no HiCache (recompute the whole history every turn)
  MooncakeTE    HiCache promotions through round-robin striping
  TENT          HiCache promotions through telemetry-driven slice spraying
Identical cache policy/budget; only the transfer engine differs."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving import (
    HiCache,
    ServeSimConfig,
    ServingSimulator,
    from_table2,
    kv_bytes_per_token,
    make_cpu_pool,
    make_disk_pool,
    make_gpu_pool,
)

from .common import add_background_turbulence, add_tenant_contention, make_engine

SIM = ServeSimConfig(clients=8, concurrency=4, turns=10, input_tokens=2048,
                     output_tokens=64)
PAGE_TOKENS = 256


def _engine(policy, *, contended=True):
    # cap slice count (paper §4.2: bound control-plane overhead on huge pages)
    eng = make_engine(policy, seed=21, max_slices=64)
    if contended:
        add_background_turbulence(eng, seed=13, horizon=4000.0, severity=0.6)
        # co-tenant elephant flows on the same rails (global-store reality)
        add_tenant_contention(eng, streams=3, block=512 << 20)
    return eng


def _hicache(eng, cfg):
    pb = kv_bytes_per_token(cfg) * PAGE_TOKENS
    turns_pages = SIM.turns * SIM.input_tokens // PAGE_TOKENS + 2
    gpu_pages = 3 * turns_pages  # GPU tier holds a few conversations
    cpu_pages = SIM.clients * turns_pages + 8
    return HiCache(
        eng, cfg,
        gpu_pool=make_gpu_pool(eng, 0, 0, page_bytes=pb, num_pages=gpu_pages, materialize=False),
        cpu_pool=make_cpu_pool(eng, 1, page_bytes=pb, num_pages=cpu_pages, materialize=False),
        disk_pool=make_disk_pool(eng, 1, page_bytes=pb, num_pages=cpu_pages, materialize=False),
        page_tokens=PAGE_TOKENS,
    )


def run() -> list:
    cfg = get_config("qwen3-moe-235b-a22b")
    perf = from_table2()
    results = {}
    for label, policy, cached in (
        ("baseline", "tent", False),
        ("MooncakeTE", "round_robin", True),
        ("TENT", "tent", True),
    ):
        eng = _engine(policy, contended=cached)  # baseline moves no KV bytes
        hc = _hicache(eng, cfg) if cached else None
        results[label] = ServingSimulator(eng, perf, hicache=hc, sim_cfg=SIM).run()
    out = []
    for label, st in results.items():
        rounds = ";".join(f"R{r}={st.round_avg_ttft[r]:.2f}s" for r in (1, 5, 10))
        out.append({
            "name": f"table2.{label}",
            "us_per_call": st.avg_ttft * 1e6,
            "derived": (
                f"input_tok_s={st.input_throughput:.0f};p90_ttft_s={st.p90_ttft:.2f};{rounds}"
            ),
        })
    te, tent, base = results["MooncakeTE"], results["TENT"], results["baseline"]
    out.append({
        "name": "table2.summary",
        "us_per_call": 0.0,
        "derived": (
            f"tent_vs_te_throughput={tent.input_throughput/te.input_throughput:.2f};"
            f"tent_p90_reduction_pct={100*(1-tent.p90_ttft/te.p90_ttft):.1f};"
            f"tent_vs_baseline_throughput={tent.input_throughput/base.input_throughput:.2f}"
        ),
    })
    return out
