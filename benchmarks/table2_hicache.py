"""Table 2: SGLang HiCache multi-turn conversation benchmark.

Qwen3-235B-A22B on one 8-GPU node (TP8); the global KVCache pool's CPU/disk
tiers live on a storage node reached over the 8-rail fabric (Mooncake-store
style "global KVCache blocks"). Three configurations:
  baseline      no HiCache (recompute the whole history every turn)
  MooncakeTE    HiCache promotions through round-robin striping
  TENT          HiCache promotions through telemetry-driven slice spraying
Identical cache policy/budget; only the transfer engine differs — both runs
are one declarative `ScenarioSpec` (the library's `hicache_serve` scaled to
the paper's fabric and conversation load) with a policy ablation list.
"""
from __future__ import annotations

import dataclasses

from repro.scenarios import (
    BackgroundSpec,
    EngineParams,
    Expectations,
    ScenarioRunner,
    ServeWorkload,
    TopologyParams,
    get,
)

WORKLOAD = ServeWorkload(clients=8, concurrency=4, turns=10, input_tokens=2048,
                         output_tokens=64, page_tokens=256)
# the paper's long-running-service engine configuration (not the regression
# tier's fast-probe variant)
ENGINE = EngineParams(max_slices=64, reset_interval=30.0, probe_interval=0.05)

CACHED = dataclasses.replace(
    get("hicache_serve"),
    name="table2_hicache",
    topology=TopologyParams(),  # full-rate fabric
    workload=WORKLOAD,
    background=BackgroundSpec(turbulence_severity=0.6, turbulence_seed=13,
                              turbulence_horizon=4000.0,
                              tenant_streams=3, tenant_block=512 << 20),
    engine=ENGINE,
    policies=("tent", "round_robin"),
    expectations=Expectations(tent_vs_baseline=1.0),
    seed=21,
)
# baseline moves no KV bytes: no HiCache, no co-tenant store traffic
BASELINE = dataclasses.replace(
    CACHED,
    name="table2_baseline",
    workload=dataclasses.replace(WORKLOAD, use_hicache=False),
    background=BackgroundSpec(),
    policies=("tent",),
    expectations=Expectations(tent_vs_baseline=0.0),
)


def run() -> list:
    cached = ScenarioRunner(CACHED).run()
    baseline = ScenarioRunner(BASELINE).run()
    assert not baseline.violations, baseline.violations
    base = baseline.policies["tent"]
    results = {
        "baseline": base,
        "MooncakeTE": cached.policies["round_robin"],
        "TENT": cached.policies["tent"],
    }
    out = []
    for label, r in results.items():
        rounds = ";".join(
            f"R{n}={r.extra[f'round_avg_ttft_R{n}']:.2f}s" for n in (1, 5, 10))
        out.append({
            "name": f"table2.{label}",
            "us_per_call": r.extra["avg_ttft_s"] * 1e6,
            "derived": (
                f"input_tok_s={r.extra['input_throughput']:.0f};"
                f"p90_ttft_s={r.extra['p90_ttft_s']:.2f};{rounds}"
            ),
        })
    te, tent = results["MooncakeTE"], results["TENT"]
    out.append({
        "name": "table2.summary",
        "us_per_call": 0.0,
        "derived": (
            f"tent_vs_te_throughput={tent.throughput/te.throughput:.2f};"
            f"tent_p90_reduction_pct={100*(1-tent.extra['p90_ttft_s']/te.extra['p90_ttft_s']):.1f};"
            f"tent_vs_baseline_throughput={tent.throughput/base.throughput:.2f}"
        ),
    })
    assert not cached.violations, cached.violations
    return out
