# Serving-scale bench: batched SoA stepper vs per-request loop + fabric parity.
"""Production-stream serving scale benchmark.

Two claims ride here, both produced by the PR that rebuilt the serving hot
path as struct-of-arrays over requests and put a calendar queue under the
fabric's event loop:

  * **requests-simulated/sec** — the batched stepper (whole phases advance
    per virtual-clock tick over the `RequestTable`, cold-prefix promotions
    leave as one cohort batch per tick) against the per-request event-driven
    closed loop (every request a chain of fabric callbacks, every promotion
    its own batch). Both run the same slowed production fabric; the floor is
    ``SCALE_SPEEDUP_FLOOR``x.
  * **fabric event-queue parity** — the `serving_production_stream` scenario
    run on the binary-heap fabric and on the calendar-queue fabric must
    produce byte-identical `ScenarioReport`s (the spec echo of the toggle
    itself is the only permitted difference). The calendar queue is a pure
    cost change, exactly like wave/wave_complete/jit_core before it.

All simulated times are virtual; the requests/sec rates are wall-clock and
machine-dependent, which is why the gate is a wide floor and not a pin.

    python -m benchmarks.serving_scale                  # full run
    python -m benchmarks.serving_scale --quick          # CI smoke
    python -m benchmarks.serving_scale --out BENCH_serving_scale.json

The --out document uses the ``tent-scenario-reports/v1`` schema so
``benchmarks.diff old new --fail-on-regression PCT`` tracks the trajectory
with no extra tooling.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

from repro.scenarios import ScenarioRunner, get

SCHEMA = "tent-scenario-reports/v1"
SCENARIO = "serving_production_stream"
# acceptance: the batched SoA stepper simulates >= 10x the requests/sec of
# the per-request event loop
SCALE_SPEEDUP_FLOOR = 10.0
# the per-request arm: enough requests to amortize engine warm-up, few
# enough that the per-request event count stays affordable; concurrency
# matches the legacy closed-loop scenarios (the HiCache GPU pool is sized
# for a handful of concurrent working sets)
ASYNC_CLIENTS, ASYNC_TURNS, ASYNC_CONCURRENCY = 64, 4, 8


def _stream_spec(quick: bool):
    spec = get(SCENARIO)
    if quick:
        spec = dataclasses.replace(
            spec,
            workload=dataclasses.replace(spec.workload, stream_requests=20_000))
    return spec


def bench_batched(quick: bool) -> dict:
    """The batched arm: the library scenario itself (tent policy), timed."""
    spec = _stream_spec(quick)
    t0 = time.perf_counter()
    rep = ScenarioRunner(spec).run_policy("tent")
    wall = time.perf_counter() - t0
    n = int(rep.extra["requests_completed"])
    return {
        "requests": n,
        "wall_seconds": wall,
        "rate": n / wall,
        "throughput": rep.throughput,
        "makespan": rep.makespan,
        "p90_ttft_s": rep.extra["p90_ttft_s"],
        "p99_ttft_s": rep.extra["p99_ttft_s"],
    }


def bench_async(quick: bool) -> dict:
    """The per-request arm: the same slowed fabric and engine knobs, but the
    event-driven closed loop (every request a chain of fabric callbacks,
    HiCache promotions per request)."""
    spec = _stream_spec(quick)
    clients = ASYNC_CLIENTS // 2 if quick else ASYNC_CLIENTS
    spec = dataclasses.replace(
        spec,
        workload=dataclasses.replace(
            spec.workload, stream_requests=0, clients=clients,
            turns=ASYNC_TURNS, concurrency=ASYNC_CONCURRENCY),
        faults=(),  # the async arm is a rate baseline, not an SLO scenario
        expectations=dataclasses.replace(
            spec.expectations, tent_vs_baseline=0.0, ttft_p90_vs_baseline=0.0,
            max_ttft_p99_s=0.0, max_tpot_p99_s=0.0),
    )
    t0 = time.perf_counter()
    rep = ScenarioRunner(spec).run_policy("tent")
    wall = time.perf_counter() - t0
    n = clients * ASYNC_TURNS
    return {
        "requests": n,
        "wall_seconds": wall,
        "rate": n / wall,
        "throughput": rep.throughput,
        "makespan": rep.makespan,
    }


def check_fabric_parity(quick: bool) -> dict:
    """Heap vs calendar event queue over the full scenario (all policies):
    the reports must be byte-identical once the toggle's own spec echo is
    normalized out."""
    spec = _stream_spec(quick)
    if quick:
        # parity is scale-invariant (same event order at any size); the
        # quick arm shrinks further so CI pays seconds, not a minute
        spec = dataclasses.replace(
            spec,
            workload=dataclasses.replace(spec.workload, stream_requests=5_000))

    def normalized(s) -> str:
        d = ScenarioRunner(s).run().to_dict()
        d["spec"]["engine"]["calendar_queue"] = None
        return json.dumps(d, sort_keys=True)

    heap_doc = normalized(spec)
    cal_doc = normalized(dataclasses.replace(
        spec, engine=dataclasses.replace(spec.engine, calendar_queue=True)))
    return {"identical": heap_doc == cal_doc,
            "requests": spec.workload.stream_requests}


def _policy_report(rate: float, extra: dict) -> dict:
    """Minimal PolicyReport-shaped dict (the keys benchmarks.diff consumes)
    with the requests-simulated/sec rate in the throughput slot."""
    return {
        "policy": extra["mode"],
        "ok": True,
        "throughput": rate,
        "recovery_ms": -1.0,
        "stall_ms": -1.0,
        "extra": extra,
    }


def run(quick: bool = False) -> list:
    batched = bench_batched(quick)
    per_req = bench_async(quick)
    speedup = batched["rate"] / per_req["rate"]
    violations = []
    if speedup < SCALE_SPEEDUP_FLOOR:
        violations.append(
            f"batched stepper simulates {speedup:.1f}x the per-request "
            f"loop's requests/sec (< {SCALE_SPEEDUP_FLOOR:.0f}x floor)")
    docs = [{
        "scenario": "serving_stream_scale",
        "ok": not violations,
        "violations": violations,
        "policies": {
            "batched": _policy_report(
                batched["rate"],
                {"mode": "batched", **batched, "speedup_vs_per_request": speedup}),
            "per_request": _policy_report(
                per_req["rate"], {"mode": "per_request", **per_req}),
        },
        "spec": {"policies": ["batched", "per_request"],
                 "scenario": SCENARIO, "quick": quick},
    }]

    parity = check_fabric_parity(quick)
    parity_violations = []
    if not parity["identical"]:
        parity_violations.append(
            "calendar-queue fabric produced a different ScenarioReport than "
            "the binary heap (bit-parity broken)")
    docs.append({
        "scenario": "serving_stream_fabric_parity",
        "ok": not parity_violations,
        "violations": parity_violations,
        "policies": {
            "calendar_vs_heap": _policy_report(
                1.0 if parity["identical"] else 0.0,
                {"mode": "calendar_vs_heap", **parity}),
        },
        "spec": {"policies": ["calendar_vs_heap"], "scenario": SCENARIO,
                 "quick": quick},
    })
    return docs


def render(docs: list) -> None:
    scale = docs[0]["policies"]
    b, p = scale["batched"]["extra"], scale["per_request"]["extra"]
    print(f"\nserving_stream_scale ({'quick' if docs[0]['spec']['quick'] else 'full'})")
    print(f"  batched:     {b['requests']:7d} requests in "
          f"{b['wall_seconds']:6.1f}s wall = {b['rate']:>10,.0f} req/s")
    print(f"  per-request: {p['requests']:7d} requests in "
          f"{p['wall_seconds']:6.1f}s wall = {p['rate']:>10,.0f} req/s")
    print(f"  speedup: {b['speedup_vs_per_request']:.1f}x "
          f"(floor {SCALE_SPEEDUP_FLOOR:.0f}x)")
    par = docs[1]["policies"]["calendar_vs_heap"]["extra"]
    print(f"\nserving_stream_fabric_parity")
    print(f"  heap vs calendar over {par['requests']} requests: "
          f"{'byte-identical' if par['identical'] else 'MISMATCH'}")
    for doc in docs:
        for v in doc["violations"]:
            print(f"  VIOLATION: {v}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller stream (CI smoke)")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write the rates as a tent-scenario-reports/v1 "
                         "document (default: BENCH_serving_scale.json; "
                         "compare runs with benchmarks.diff)")
    args = ap.parse_args(argv)
    docs = run(quick=args.quick)
    render(docs)
    out = args.out or "BENCH_serving_scale.json"
    with open(out, "w") as f:
        json.dump({
            "schema": SCHEMA,
            "generated_unix": round(time.time(), 3),
            "scenarios": len(docs),
            "violated": sum(not d["ok"] for d in docs),
            "reports": docs,
        }, f, indent=2)
        f.write("\n")
    print(f"\nwrote {out}", file=sys.stderr)
    if any(not d["ok"] for d in docs):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
