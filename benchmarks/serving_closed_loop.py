# Serving closed-loop trajectory: the three serving scenarios as one BENCH doc.
"""Benchmark the event-driven serving closed loop (paper §5.1).

Runs the serving scenario family (HiCache promotion under a flapping NIC,
prefill->decode handoff incast, checkpoint refresh overlapped with decode)
and writes a ``tent-scenario-reports/v1`` document, so `benchmarks/diff.py`
can gate serving-tier regressions the same way it gates the spray hot path:

    python -m benchmarks.serving_closed_loop --out BENCH_serving.json
    python -m benchmarks.diff BENCH_serving.json BENCH_serving_new.json \
        --fail-on-regression 5

All times are virtual-fabric seconds, so the trajectory is deterministic and
machine-independent: any drift in the diff is a code change, not noise.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.scenarios import ScenarioRunner, get, names

SCHEMA = "tent-scenario-reports/v1"
SERVING_PREFIX = "serving_"


def serving_names() -> list:
    return [n for n in names() if n.startswith(SERVING_PREFIX)]


def run(out: str | None = None, only: str | None = None) -> int:
    """Run the serving scenarios; returns the number of violated scenarios."""
    picked = [only] if only else serving_names()
    docs = []
    violated = 0
    for name in picked:
        spec = get(name)
        t0 = time.time()
        report = ScenarioRunner(spec).run()
        doc = report.to_dict()
        doc["wall_seconds"] = round(time.time() - t0, 3)
        docs.append(doc)
        prim = report.policies[spec.primary_policy]
        print(
            f"{name}: {spec.primary_policy} {prim.throughput:.1f} tok/s, "
            f"p90 TTFT {prim.extra.get('p90_ttft_s', 0.0):.3f}s, "
            f"p99 TPOT {prim.extra.get('p99_tpot_s', 0.0):.4f}s, "
            f"overlap {prim.extra.get('overlap_ratio', 0.0):.2f}x",
            file=sys.stderr)
        if report.violations:
            violated += 1
            for v in report.violations:
                print(f"{name}: VIOLATION: {v}", file=sys.stderr)
    if out:
        with open(out, "w") as f:
            json.dump(
                {
                    "schema": SCHEMA,
                    "generated_unix": round(time.time(), 3),
                    "scenarios": len(docs),
                    "violated": violated,
                    "reports": docs,
                },
                f, indent=2)
            f.write("\n")
        print(f"wrote {len(docs)} reports to {out}", file=sys.stderr)
    else:
        for doc in docs:
            print(json.dumps(doc))
    return violated


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", metavar="PATH",
                    help="write the reports as one tent-scenario-reports/v1 "
                         "document (bench trajectory tracking)")
    ap.add_argument("--scenario", metavar="NAME",
                    help="run a single serving scenario instead of the family")
    ap.add_argument("--list", action="store_true",
                    help="list the serving scenario family and exit")
    args = ap.parse_args(argv)
    if args.list:
        for n in serving_names():
            print(f"{n:28s} {get(n).description}")
        return
    if args.scenario and args.scenario not in serving_names():
        ap.error(f"unknown serving scenario {args.scenario!r} "
                 f"(have: {', '.join(serving_names())})")
    if run(out=args.out, only=args.scenario):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
