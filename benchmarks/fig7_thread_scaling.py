"""Fig. 7: GPU-to-GPU read bandwidth vs submission-thread count (4 MB
blocks, each thread bound to one local GPU). Paper: TENT saturates at ~16
threads, >2x Mooncake TE, ~77% of hardware peak."""
from __future__ import annotations

from repro.core import FabricSpec

from .common import closed_loop, gpu_loc, make_engine

BLOCK = 4 << 20
THREADS = [1, 2, 4, 8, 16, 32, 64]
POLICIES = [("tent", "TENT"), ("pinned", "MooncakeTE"), ("static_best2", "NIXL")]


def _one(policy: str, threads: int):
    spec = FabricSpec()
    eng = make_engine(policy, spec=spec, seed=1)
    streams = []
    for t in range(threads):
        gpu = t % spec.node.n_gpus
        src = eng.register_segment(gpu_loc(spec, 0, gpu), BLOCK)
        dst = eng.register_segment(gpu_loc(spec, 1, gpu), BLOCK)
        streams.append((src.segment_id, dst.segment_id, BLOCK))
    return closed_loop(eng, streams, iters=12)


def run() -> list:
    peak = 8 * 25e9  # eight 200 Gbps rails
    out = []
    tp = {}
    for policy, label in POLICIES:
        for n in THREADS:
            res = _one(policy, n)
            tp[(label, n)] = res.throughput
            out.append({
                "name": f"fig7.{label}.threads{n}",
                "us_per_call": res.pct(50) * 1e6,
                "derived": f"GBps={res.throughput/1e9:.2f};pct_peak={res.throughput/peak*100:.1f}",
            })
    out.append({
        "name": "fig7.summary.threads16",
        "us_per_call": 0.0,
        "derived": (
            f"tent_vs_te={tp[('TENT',16)]/tp[('MooncakeTE',16)]:.2f};"
            f"tent_pct_peak={tp[('TENT',16)]/peak*100:.1f}"
        ),
    })
    return out
