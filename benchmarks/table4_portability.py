"""Table 4: peak read bandwidth across transfer modes vs theoretical limits.

Applications issue the same BatchTransfer calls; only the fabric capability
flags differ (thin-backend portability). Modes: multi-rail GPUDirect RDMA,
staged GPU->Host / GPU->GPU (no GPUDirect), NVLink, MNNVL, Ascend UB,
io_uring GPU->file, SHM, TCP."""
from __future__ import annotations

from repro.core import FabricSpec, Location, MemoryKind

from .common import closed_loop, gpu_loc, host_loc, make_engine

BLOCK = 256 << 20


def _peak(policy_spec, src_loc, dst_loc, theoretical, label, iters=6):
    spec, kw = policy_spec
    eng = make_engine("tent", spec=spec, seed=8, **kw)
    src = eng.register_segment(src_loc(spec, eng), BLOCK)
    dst = eng.register_segment(dst_loc(spec, eng), BLOCK)
    res = closed_loop(eng, [(src.segment_id, dst.segment_id, BLOCK)], iters=iters)
    return {
        "name": f"table4.{label}",
        "us_per_call": res.pct(50) * 1e6,
        "derived": (
            f"GBps={res.throughput/1e9:.1f};theoretical={theoretical};"
            f"efficiency={res.throughput/1e9/float(theoretical.split('/')[0]):.2f}"
            if theoretical[0].isdigit() else f"GBps={res.throughput/1e9:.1f}"
        ),
    }


def run() -> list:
    rows = []
    base = FabricSpec()
    rows.append(_peak((base, {}),
                      lambda s, e: gpu_loc(s, 0, 0), lambda s, e: gpu_loc(s, 1, 0),
                      "100", "rdma_gpu_gpu"))  # 4 usable rails (tier1+2) x 25
    nogd = FabricSpec(has_gpudirect=False)
    rows.append(_peak((nogd, {}),
                      lambda s, e: gpu_loc(s, 0, 0), lambda s, e: host_loc(1, 0),
                      "27", "staged_gpu_host"))
    rows.append(_peak((nogd, {}),
                      lambda s, e: gpu_loc(s, 0, 0), lambda s, e: gpu_loc(s, 1, 0),
                      "27", "staged_gpu_gpu"))
    rows.append(_peak((base, {}),
                      lambda s, e: gpu_loc(s, 0, 0), lambda s, e: gpu_loc(s, 0, 4),
                      "204.5", "nvlink_gpu_gpu"))
    mn = FabricSpec(has_mnnvl=True)
    rows.append(_peak((mn, {}),
                      lambda s, e: gpu_loc(s, 0, 0), lambda s, e: gpu_loc(s, 1, 0),
                      "956.2", "mnnvl_gpu_gpu"))
    ub = FabricSpec(has_ub=True, has_nvlink=False, has_gpudirect=False)
    rows.append(_peak((ub, {}),
                      lambda s, e: gpu_loc(s, 0, 0), lambda s, e: gpu_loc(s, 1, 0),
                      "196.0", "ascend_ub_gpu_gpu"))
    rows.append(_peak((base, {}),
                      lambda s, e: gpu_loc(s, 0, 0),
                      lambda s, e: Location(node=0, kind=MemoryKind.FILE),
                      "6.0", "io_uring_gpu_file"))
    rows.append(_peak((base, {}),
                      lambda s, e: host_loc(0, 0), lambda s, e: host_loc(0, 1),
                      "20.0", "shm_host_host"))
    tcponly = FabricSpec(has_gpudirect=False, has_nvlink=False)
    rows.append(_peak((tcponly, {}),
                      lambda s, e: host_loc(0, 0), lambda s, e: host_loc(1, 0),
                      "100", "rdma_host_host"))
    return rows
