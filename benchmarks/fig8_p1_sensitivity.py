"""Fig. 8: sensitivity to the tier-2 topology penalty P1 (the paper's name
for the tier-2 entry of P_tier; we sweep it on the Fig. 6 GPU-to-GPU setup).

Too large -> TENT degenerates to single-rail (tier-1 only); too small ->
tier-2 rails are overused and their access cost inflates latency. The paper
adopts P1 = 3; mis-setting should degrade only modestly because the EWMA
feedback keeps pulling the scheduler back toward faster rails."""
from __future__ import annotations

from repro.core import FabricSpec

from .common import closed_loop, gpu_loc, make_engine

BLOCKS = [1 << 20, 4 << 20, 16 << 20, 64 << 20]
P1S = [1.0, 2.0, 3.0, 6.0, 12.0, 1e9]


def _one(p1: float, block: int):
    spec = FabricSpec()
    eng = make_engine("tent", spec=spec, seed=3,
                      tier_penalty={1: 1.0, 2: p1, 3: float("inf")})
    src = eng.register_segment(gpu_loc(spec, 0, 0), block)
    dst = eng.register_segment(gpu_loc(spec, 1, 0), block)
    return closed_loop(eng, [(src.segment_id, dst.segment_id, block)], iters=12)


def run() -> list:
    out = []
    p99 = {}
    for p1 in P1S:
        for block in BLOCKS:
            res = _one(p1, block)
            p99[(p1, block)] = res.pct(99)
            tag = "inf" if p1 > 1e6 else f"{p1:g}"
            out.append({
                "name": f"fig8.P1={tag}.block{block>>20}M",
                "us_per_call": res.pct(99) * 1e6,
                "derived": f"GBps={res.throughput/1e9:.2f}",
            })
    big = BLOCKS[-1]
    best = min(P1S, key=lambda p: p99[(p, big)])
    worst_frac = max(p99[(p, big)] for p in P1S if p <= 12) / p99[(best, big)]
    out.append({
        "name": "fig8.summary.64M",
        "us_per_call": 0.0,
        "derived": f"best_P1={'inf' if best > 1e6 else best};missetting_penalty={worst_frac:.2f}x",
    })
    return out
