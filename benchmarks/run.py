# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: reproduces every TENT table/figure on the deterministic
fabric simulator. Each module's run() returns rows; failures in one module
do not mask the others.

Scenario mode (machine-readable, for bench trajectory tracking):
    python -m benchmarks.run --list-scenarios
    python -m benchmarks.run --scenario single_rail_flap
    python -m benchmarks.run --scenario all
    python -m benchmarks.run --scenario-file my_scenario.json
prints each `ScenarioReport` as one JSON document on stdout and exits
non-zero if any scenario violates its declared expectations.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from repro.scenarios import ScenarioRunner, ScenarioSpec, get, names

from . import (
    fig2_per_rail,
    fig5_host_to_host,
    fig6_device_to_device,
    fig7_thread_scaling,
    fig8_p1_sensitivity,
    fig9_batch_scaling,
    fig10_failure_injection,
    table2_hicache,
    table3_checkpoint,
    table4_portability,
)

MODULES = [
    ("fig2_per_rail", fig2_per_rail),
    ("fig5_host_to_host", fig5_host_to_host),
    ("fig6_device_to_device", fig6_device_to_device),
    ("fig7_thread_scaling", fig7_thread_scaling),
    ("fig8_p1_sensitivity", fig8_p1_sensitivity),
    ("fig9_batch_scaling", fig9_batch_scaling),
    ("fig10_failure_injection", fig10_failure_injection),
    ("table2_hicache", table2_hicache),
    ("table3_checkpoint", table3_checkpoint),
    ("table4_portability", table4_portability),
]


def run_figures() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}.ERROR,0,failed")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
        print(f"{name}.wall,{(time.time()-t0)*1e6:.0f},bench_wall_time", file=sys.stderr)
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


def run_scenarios(specs, out: str | None = None) -> None:
    violated = 0
    docs = []
    for spec in specs:
        t0 = time.time()
        report = ScenarioRunner(spec).run()
        doc = report.to_dict()
        doc["wall_seconds"] = round(time.time() - t0, 3)
        docs.append(doc)
        print(json.dumps(doc))
        sys.stdout.flush()
        if report.violations:
            violated += 1
            for v in report.violations:
                print(f"{spec.name}: VIOLATION: {v}", file=sys.stderr)
    if out:
        # one self-describing document per file, for bench trajectory
        # tracking (BENCH_*.json): written even when scenarios violate, so
        # regressions land in the trajectory too
        with open(out, "w") as f:
            json.dump(
                {
                    "schema": "tent-scenario-reports/v1",
                    "generated_unix": round(time.time(), 3),
                    "scenarios": len(docs),
                    "violated": violated,
                    "reports": docs,
                },
                f, indent=2)
            f.write("\n")
        print(f"wrote {len(docs)} reports to {out}", file=sys.stderr)
    if violated:
        raise SystemExit(1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", metavar="NAME",
                    help="run one named scenario ('all' for the whole library) "
                         "and print its ScenarioReport as JSON")
    ap.add_argument("--scenario-file", metavar="PATH",
                    help="run a ScenarioSpec from a JSON file")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="list the named scenario library and exit")
    ap.add_argument("--out", metavar="PATH",
                    help="additionally write the scenario reports to PATH as "
                         "one JSON document (bench trajectory tracking)")
    args = ap.parse_args(argv)

    if args.out and not (args.scenario or args.scenario_file):
        ap.error("--out only applies to scenario mode "
                 "(use --scenario or --scenario-file)")
    if args.list_scenarios:
        for n in names():
            print(f"{n:28s} {get(n).description}")
        return
    if args.scenario_file:
        with open(args.scenario_file) as f:
            raw = f.read()
        try:
            spec = ScenarioSpec.from_json(raw)
        except Exception as e:
            ap.error(f"invalid scenario file {args.scenario_file}: {e!r}")
        run_scenarios([spec], out=args.out)
        return
    if args.scenario:
        try:
            specs = [get(n) for n in names()] if args.scenario == "all" else [get(args.scenario)]
        except KeyError as e:
            ap.error(e.args[0])
        run_scenarios(specs, out=args.out)
        return
    run_figures()


if __name__ == "__main__":
    main()
