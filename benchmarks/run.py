# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: reproduces every TENT table/figure on the deterministic
fabric simulator. Each module's run() returns rows; failures in one module
do not mask the others."""
from __future__ import annotations

import sys
import time
import traceback

from . import (
    fig2_per_rail,
    fig5_host_to_host,
    fig6_device_to_device,
    fig7_thread_scaling,
    fig8_p1_sensitivity,
    fig9_batch_scaling,
    fig10_failure_injection,
    table2_hicache,
    table3_checkpoint,
    table4_portability,
)

MODULES = [
    ("fig2_per_rail", fig2_per_rail),
    ("fig5_host_to_host", fig5_host_to_host),
    ("fig6_device_to_device", fig6_device_to_device),
    ("fig7_thread_scaling", fig7_thread_scaling),
    ("fig8_p1_sensitivity", fig8_p1_sensitivity),
    ("fig9_batch_scaling", fig9_batch_scaling),
    ("fig10_failure_injection", fig10_failure_injection),
    ("table2_hicache", table2_hicache),
    ("table3_checkpoint", table3_checkpoint),
    ("table4_portability", table4_portability),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}.ERROR,0,failed")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
        print(f"{name}.wall,{(time.time()-t0)*1e6:.0f},bench_wall_time", file=sys.stderr)
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
