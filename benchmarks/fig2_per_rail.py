"""Fig. 2: per-rail average latency — RR's HoL-blocking spikes vs TENT.

Eight-rail 200 Gbps fabric, read requests split into 1 MB slices, four
submission threads that can post to any NIC; two rails sit on the remote
NUMA domain relative to their submitters and one is transiently degraded.
Round-robin keeps feeding the slow rails (queue buildup inflates their
per-slice service time); TENT steers slices away, flattening the profile.
"""
from __future__ import annotations

import numpy as np

from repro.core import FabricSpec

from .common import closed_loop, host_loc, make_engine

BLOCK = 32 * 1024 * 1024
SLICE = 1 * 1024 * 1024


def _run(policy: str):
    eng = make_engine(policy, slice_bytes=SLICE, seed=5)
    # one degraded rail (signal degradation without hard failure)
    nic = eng.topology.rdma_nic(0, 2)
    eng.fabric.schedule_degradation(nic.link_id, at=0.0, until=1e9, factor=0.35)
    streams = []
    for t in range(4):
        numa = t % 2
        src = eng.register_segment(host_loc(0, numa), BLOCK)
        dst = eng.register_segment(host_loc(1, numa), BLOCK)
        streams.append((src.segment_id, dst.segment_id, BLOCK))
    closed_loop(eng, streams, iters=12)
    # per-rail mean service time = busy time per completed op
    rows = []
    for nic in eng.topology.rdma_nics(0):
        link = eng.fabric.link(nic.link_id)
        if link.ops_completed:
            per_slice = link.bytes_completed / max(link.ops_completed, 1) / nic.bandwidth
            tl = eng.store.maybe(nic.link_id)
            rows.append((nic.name, link.ops_completed,
                         tl.ewma_service_time if tl else 0.0))
        else:
            rows.append((nic.name, 0, 0.0))
    return rows


def run() -> list:
    out = []
    for policy, label in (("round_robin", "RR"), ("tent", "TENT")):
        rows = _run(policy)
        lats = [r[2] for r in rows if r[1] > 0]
        spike = max(lats) / max(min(l for l in lats if l > 0), 1e-9)
        for name, ops, ewma in rows:
            out.append({
                "name": f"fig2.{label}.{name}",
                "us_per_call": ewma * 1e6,
                "derived": f"ops={ops}",
            })
        out.append({
            "name": f"fig2.{label}.spike_ratio",
            "us_per_call": 0.0,
            "derived": f"max_over_min_rail_latency={spike:.2f}",
        })
    return out
