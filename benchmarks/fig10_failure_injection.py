"""Fig. 10: manual rail shutdown (t=1000 ms) and recovery (t=3000 ms) while
continuously issuing 64 MB transfers. TENT must mask the failure (dip
< 50 ms), run degraded, and reintegrate the restored rail within tens of
milliseconds (paper: 26 ms). Link status reset every second, as in the
paper's configuration for this experiment.

The experiment is the library's `single_rail_flap` scenario scaled up to the
paper's full fabric, timeline, and block size — the declarative spec (not
bespoke setup) defines the run; this module only formats the timeline rows.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.scenarios import (
    ClosedLoopWorkload,
    EngineParams,
    Expectations,
    FaultEvent,
    ScenarioRunner,
    TopologyParams,
    get,
)

BLOCK = 64 << 20
BUCKET = 0.025  # 25 ms throughput buckets
END = 4.0

SPEC = dataclasses.replace(
    get("single_rail_flap"),
    name="fig10_failure_injection",
    description="Fig. 10 at paper scale: one 25 GB/s rail down 1.0s-3.0s "
                "under a continuous 64 MB elephant flow.",
    topology=TopologyParams(),  # full-rate H800-style fabric
    workload=ClosedLoopWorkload(streams=1, blocks=(BLOCK,), iters=0, duration=END),
    faults=(FaultEvent("fail", 0, 0, at=1.0, until=3.0),),
    engine=EngineParams(max_slices=256, reset_interval=1.0, probe_interval=0.02),
    policies=("tent",),
    expectations=Expectations(tent_vs_baseline=0.0, max_recovery_ms=50.0,
                              max_stall_ms=50.0),
    seed=4,
    bucket=BUCKET,
)


def run() -> list:
    report = ScenarioRunner(SPEC).run()
    r = report.policies["tent"]
    gbps = np.asarray(r.buckets_gbps)
    healthy = np.median(gbps[4 : int(1.0 / BUCKET)])
    degraded = np.median(gbps[int(1.5 / BUCKET) : int(2.9 / BUCKET)])
    recovered = np.median(gbps[int(3.3 / BUCKET) : int(3.9 / BUCKET)])
    out = []
    for i in range(0, len(gbps) - 1, 8):
        out.append({
            "name": f"fig10.t{int(i*BUCKET*1000):04d}ms",
            "us_per_call": 0.0,
            "derived": f"GBps={gbps[i]:.1f}",
        })
    out.append({
        "name": "fig10.summary",
        "us_per_call": 0.0,
        "derived": (
            f"healthy_GBps={healthy:.1f};dip_ms={r.recovery_ms:.0f};"
            f"degraded_GBps={degraded:.1f};recovered_GBps={recovered:.1f};"
            f"readmissions={r.readmissions};app_visible_failures={r.batches_failed}"
        ),
    })
    assert not report.violations, report.violations
    return out
