"""Fig. 10: manual rail shutdown (t=1000 ms) and recovery (t=3000 ms) while
continuously issuing 64 MB transfers. TENT must mask the failure (dip
< 50 ms), run degraded, and reintegrate the restored rail within tens of
milliseconds (paper: 26 ms). Link status reset every second, as in the
paper's configuration for this experiment."""
from __future__ import annotations

import numpy as np

from repro.core import HealthConfig, EngineConfig, FabricSpec, TentEngine

from .common import host_loc

BLOCK = 64 << 20
BUCKET = 0.025  # 25 ms throughput buckets
END = 4.0


def run() -> list:
    eng = TentEngine(
        FabricSpec(),
        config=EngineConfig(
            policy="tent",
            reset_interval=1.0,
            health=HealthConfig(probe_interval=0.02),
            max_slices=256,
        ),
        seed=4,
    )
    nic = eng.topology.rdma_nic(0, 0)
    eng.fabric.schedule_failure(nic.link_id, at=1.0, recover_at=3.0)
    src = eng.register_segment(host_loc(0, 0), BLOCK)
    dst = eng.register_segment(host_loc(1, 0), BLOCK)
    completions = []  # (time, bytes)

    def pump():
        if eng.fabric.now >= END:
            return
        b = eng.allocate_batch()
        t0 = eng.fabric.now
        eng.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, BLOCK)])

        def on_done(res, t0=t0):
            completions.append((eng.fabric.now, BLOCK))
            pump()

        eng.on_batch_done(b, on_done)

    pump()
    while eng.fabric.now < END and not eng.fabric.idle:
        eng.fabric.step()

    # bucketized throughput timeline
    buckets = np.zeros(int(END / BUCKET) + 1)
    for t, nbytes in completions:
        if t < END:
            buckets[int(t / BUCKET)] += nbytes
    gbps = buckets / BUCKET / 1e9
    healthy = np.median(gbps[4 : int(1.0 / BUCKET)])
    # dip duration: consecutive buckets after t=1.0 below 50% of healthy
    post_fail = gbps[int(1.0 / BUCKET) :]
    dip = 0
    for v in post_fail:
        if v < 0.5 * healthy:
            dip += 1
        else:
            break
    dip_ms = dip * BUCKET * 1e3
    # reintegration: time after t=3.0 until tier-1 NIC0 carries bytes again
    nic0_used_at = None
    link = eng.fabric.link(nic.link_id)
    # re-run detection via telemetry store exclusion state history is not
    # recorded; use probe readmissions metric instead
    reint = eng.health.readmissions
    degraded = np.median(gbps[int(1.5 / BUCKET) : int(2.9 / BUCKET)])
    recovered = np.median(gbps[int(3.3 / BUCKET) : int(3.9 / BUCKET)])
    out = []
    for i in range(0, len(gbps) - 1, 8):
        out.append({
            "name": f"fig10.t{int(i*BUCKET*1000):04d}ms",
            "us_per_call": 0.0,
            "derived": f"GBps={gbps[i]:.1f}",
        })
    out.append({
        "name": "fig10.summary",
        "us_per_call": 0.0,
        "derived": (
            f"healthy_GBps={healthy:.1f};dip_ms={dip_ms:.0f};"
            f"degraded_GBps={degraded:.1f};recovered_GBps={recovered:.1f};"
            f"readmissions={reint};app_visible_failures=0"
        ),
    })
    assert dip_ms < 50.0, f"self-healing dip {dip_ms} ms exceeds the paper's 50 ms"
    return out
