"""Fig. 6: one-to-one GPU writes across nodes (KVCache-sized blocks).

Each H800 GPU has one tier-1 NIC and three same-NUMA tier-2 NICs. Engines
that pin GPU traffic to the tier-1 NIC (Mooncake TE / UCCL) serialize on it
at large blocks; TENT recruits tier-2 rails only when the parallel bandwidth
outweighs their access penalty (paper: 2.1x throughput, P99 -> 46.7%, and
roughly half the bytes on the tier-1 NIC)."""
from __future__ import annotations

from repro.core import FabricSpec

from .common import closed_loop, gpu_loc, make_engine

BLOCKS = [256 * 1024, 1 << 20, 4 << 20, 16 << 20, 64 << 20]
POLICIES = [("tent", "TENT"), ("pinned", "MooncakeTE/UCCL"), ("static_best2", "NIXL")]


def _one(policy: str, block: int):
    spec = FabricSpec()
    eng = make_engine(policy, spec=spec, seed=3)
    src = eng.register_segment(gpu_loc(spec, 0, 0), block)
    dst = eng.register_segment(gpu_loc(spec, 1, 0), block)
    res = closed_loop(eng, [(src.segment_id, dst.segment_id, block)], iters=16)
    tier1 = eng.topology.rdma_nic(0, spec.node.tier1_nic(0))
    t1 = eng.fabric.link(tier1.link_id).bytes_completed
    total = sum(
        l.bytes_completed for l in eng.fabric.links.values()
        if l.desc.link_class.value == "rdma" and l.desc.node == 0
    )
    return res, (t1 / total if total else 1.0)


def run() -> list:
    out = []
    tp = {}
    p99 = {}
    for policy, label in POLICIES:
        for block in BLOCKS:
            res, t1_frac = _one(policy, block)
            tp[(label, block)] = res.throughput
            p99[(label, block)] = res.pct(99)
            out.append({
                "name": f"fig6.{label.split('/')[0]}.block{block>>20}M",
                "us_per_call": res.pct(50) * 1e6,
                "derived": f"GBps={res.throughput/1e9:.2f};p99_us={res.pct(99)*1e6:.1f};tier1_frac={t1_frac:.2f}",
            })
    big = BLOCKS[-1]
    out.append({
        "name": "fig6.summary.64M",
        "us_per_call": 0.0,
        "derived": (
            f"tent_vs_pinned_tp={tp[('TENT', big)]/tp[('MooncakeTE/UCCL', big)]:.2f};"
            f"tent_p99_frac={p99[('TENT', big)]/p99[('MooncakeTE/UCCL', big)]:.3f}"
        ),
    })
    return out
