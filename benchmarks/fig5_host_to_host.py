"""Fig. 5: host-to-host read/write throughput and P99 latency, two nodes,
eight 200 Gbps rails, per-socket memory + per-socket submission threads,
block sizes 4 KB .. 64 MB. Baselines: Mooncake TE (round_robin),
NIXL (static_best2), UCCL-P2P (pinned)."""
from __future__ import annotations

import numpy as np

from .common import add_background_turbulence, closed_loop, host_loc, make_engine

BLOCKS = [4 * 1024, 64 * 1024, 1 << 20, 16 << 20, 64 << 20]
POLICIES = [("tent", "TENT"), ("round_robin", "MooncakeTE"),
            ("static_best2", "NIXL"), ("pinned", "UCCL")]


def _one(policy: str, block: int):
    eng = make_engine(policy, seed=9)
    add_background_turbulence(eng, seed=11, severity=0.5)
    streams = []
    for sock in range(2):
        src = eng.register_segment(host_loc(0, sock), block)
        dst = eng.register_segment(host_loc(1, sock), block)
        streams.append((src.segment_id, dst.segment_id, block))
    iters = 24 if block >= (1 << 20) else 12
    res = closed_loop(eng, streams, iters=iters)
    return res


def run() -> list:
    out = []
    tp = {}
    p99 = {}
    for policy, label in POLICIES:
        for block in BLOCKS:
            res = _one(policy, block)
            tp[(label, block)] = res.throughput
            p99[(label, block)] = res.pct(99)
            out.append({
                "name": f"fig5.{label}.block{block>>10}k",
                "us_per_call": res.pct(50) * 1e6,
                "derived": f"GBps={res.throughput/1e9:.2f};p99_us={res.pct(99)*1e6:.1f}",
            })
    big = BLOCKS[-1]
    best_base_tp = max(tp[(l, big)] for _, l in POLICIES[1:])
    best_base_p99 = min(p99[(l, big)] for _, l in POLICIES[1:])
    out.append({
        "name": "fig5.summary.64M",
        "us_per_call": 0.0,
        "derived": (
            f"tent_tp_gain={tp[('TENT', big)]/best_base_tp:.3f};"
            f"tent_p99_frac={p99[('TENT', big)]/best_base_p99:.3f}"
        ),
    })
    return out
