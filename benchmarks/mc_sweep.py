# Monte-Carlo fault sweeps over the fused lax.scan spray core. Emits
# `tent-scenario-reports/v1` documents so `benchmarks.diff` can gate
# healing-tail / throughput regressions exactly like the scalar tier.
"""Vmapped Monte-Carlo fault sweeps (BENCH_mc.json).

Each named fault scenario is compiled to a fixed-shape `SprayProgram` and
swept over N seeds with jittered fault onset/duration/depth
(`repro.scenarios.MonteCarloSweep`). The per-policy healing-time and
throughput distributions (P50/P99/P99.9 with bootstrap CIs) are projected
into `ScenarioReport` form, so the existing `benchmarks.diff
--fail-on-regression` gate covers distribution tails too:

    python -m benchmarks.mc_sweep --seeds 64 --out BENCH_mc.json
    python -m benchmarks.mc_sweep --scenario flap_storm --seeds 256

Exits non-zero if any sweep violates its declared MC expectations
(`Expectations.healing_p999_ms` / `throughput_p50_vs_baseline`).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.scenarios import MonteCarloSweep, get
from repro.scenarios.sweep import sweepable_names

# Curated default: the closed-loop fault scenarios where jittered
# onset/duration/depth actually moves the distribution (flaps, correlated
# outage, degrade ramps, PD handoff under failure).
DEFAULT_SCENARIOS = (
    "single_rail_flap",
    "flap_storm",
    "correlated_outage",
    "degrade_recover_ramp",
    "disagg_prefill_decode",
)


def run_sweeps(scenarios, *, seeds: int, fault_jitter: float,
               rounds=None, out=None) -> None:
    violated = 0
    docs = []
    for name in scenarios:
        t0 = time.time()
        sweep = MonteCarloSweep(
            get(name), n_seeds=seeds, fault_jitter=fault_jitter,
            rounds=rounds)
        report = sweep.run().to_scenario_report()
        doc = report.to_dict()
        doc["wall_seconds"] = round(time.time() - t0, 3)
        docs.append(doc)
        print(json.dumps(doc))
        sys.stdout.flush()
        if report.violations:
            violated += 1
            for v in report.violations:
                print(f"{name}: VIOLATION: {v}", file=sys.stderr)
    if out:
        # Same self-describing document shape as benchmarks.run --out, so
        # benchmarks.diff consumes BENCH_mc.json unchanged. Written even on
        # violations: regressions belong in the trajectory too.
        with open(out, "w") as f:
            json.dump(
                {
                    "schema": "tent-scenario-reports/v1",
                    "generated_unix": round(time.time(), 3),
                    "scenarios": len(docs),
                    "violated": violated,
                    "reports": docs,
                },
                f, indent=2)
            f.write("\n")
        print(f"wrote {len(docs)} sweep reports to {out}", file=sys.stderr)
    if violated:
        raise SystemExit(1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", metavar="NAME", action="append",
                    help="sweep one named scenario (repeatable; 'all' for "
                         "every sweepable closed-loop scenario); default: "
                         "the curated fault set")
    ap.add_argument("--seeds", type=int, default=64, metavar="N",
                    help="Monte-Carlo seeds per scenario (default 64)")
    ap.add_argument("--fault-jitter", type=float, default=0.25, metavar="FJ",
                    help="relative jitter on fault onset/duration/depth "
                         "(default 0.25; 0 pins the declared schedule)")
    ap.add_argument("--rounds", type=int, default=None, metavar="R",
                    help="override the per-scenario spray round count")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="list sweepable scenarios and exit")
    ap.add_argument("--out", metavar="PATH",
                    help="write the sweep reports to PATH as one JSON "
                         "document (bench trajectory tracking)")
    args = ap.parse_args(argv)

    if args.list_scenarios:
        for n in sweepable_names():
            print(f"{n:28s} {get(n).description}")
        return
    scenarios = list(args.scenario or DEFAULT_SCENARIOS)
    if "all" in scenarios:
        scenarios = list(sweepable_names())
    run_sweeps(scenarios, seeds=args.seeds, fault_jitter=args.fault_jitter,
               rounds=args.rounds, out=args.out)


if __name__ == "__main__":
    main()
