"""Run the whole named scenario library and print the regression matrix.

One row per (scenario, policy): throughput, tail latency, recovery after
fault onsets, retry/exclusion counters, and whether the scenario's declared
expectations hold. This is the same code path `tests/test_scenarios.py` and
`python -m benchmarks.run --scenario all` use — three consumers, one spec.

Run:  PYTHONPATH=src python examples/scenario_matrix.py
"""
import time

from repro.scenarios import SCENARIOS, ScenarioRunner

HDR = (f"{'scenario':26s} {'policy':13s} {'thr':>10s} {'p99':>9s} "
       f"{'rec_ms':>7s} {'retry':>6s} {'excl':>5s} {'imb':>5s}")


def _fmt(v: float) -> str:
    return f"{v/1e9:8.2f}G" if v > 1e6 else f"{v:9.1f}"


t_all = time.time()
print(HDR)
print("-" * len(HDR))
violations = []
for name, spec in SCENARIOS.items():
    report = ScenarioRunner(spec).run()
    for policy, r in report.policies.items():
        rec = f"{r.recovery_ms:7.1f}" if r.recovery_ms >= 0 else "      -"
        print(f"{name:26s} {policy:13s} {_fmt(r.throughput):>10s} "
              f"{r.latency_p99*1e3:8.2f}m {rec} {r.retries:6d} "
              f"{r.exclusions:5d} {r.rail_imbalance:5.2f}")
    violations += [f"{name}: {v}" for v in report.violations]

print(f"\n{len(SCENARIOS)} scenarios in {time.time()-t_all:.1f}s wall "
      f"(virtual clocks, deterministic)")
if violations:
    print("VIOLATIONS:")
    for v in violations:
        print("  " + v)
    raise SystemExit(1)
print("all declared expectations hold: "
      "tent >= baselines, sub-50ms recovery, zero lost slices")
