"""Production-scale serving stream: 20k Zipf/Poisson requests in seconds.

Builds the request mix with `repro.scenarios.traffic` — the same seeded
generator the `serving_production_stream` scenario, `benchmarks/serving_scale.py`,
and the Monte-Carlo sweep lowering share — inspects its shape, then runs a
scaled-down production stream through the batched SoA stepper on both the
binary-heap and the calendar-queue fabric event loop and shows the reports
are byte-identical (the toggle is a pure cost change).

Run:  PYTHONPATH=src python examples/production_stream.py
"""
import dataclasses
import json
import time

import numpy as np

from repro.scenarios import ScenarioRunner, get
from repro.scenarios.traffic import TrafficSpec, promotion_bytes

# --- the traffic mix, standalone -------------------------------------------
spec = get("serving_production_stream")
wl = spec.workload
traffic = TrafficSpec(
    requests=20_000, arrival_rate=wl.arrival_rate, zipf_alpha=wl.zipf_alpha,
    groups=wl.traffic_groups, input_tokens=wl.input_tokens,
    output_tokens=wl.output_tokens, seed=spec.seed).generate()
promo = promotion_bytes(
    traffic, prefix_frac=wl.prefix_frac,
    kv_bytes_per_token=wl.stream_kv_bytes_per_token, resident_s=wl.resident_s)
counts = np.bincount(traffic.group, minlength=wl.traffic_groups)
cold = int((promo > 0).sum())
print(f"stream: {len(traffic)} requests over {traffic.arrival[-1]:.0f}s, "
      f"{wl.traffic_groups} prefix groups (top group {counts.max()} hits, "
      f"median {int(np.median(counts))})")
print(f"residency model: {cold} cold prefixes promote "
      f"{promo.sum()/1e9:.1f} GB store->GPU; "
      f"{len(traffic) - cold} re-hit GPU-resident KV for free\n")

# --- the same stream through the batched stepper, both event queues --------
small = dataclasses.replace(
    spec, workload=dataclasses.replace(wl, stream_requests=20_000))
reports = {}
for calendar in (False, True):
    s = dataclasses.replace(
        small, engine=dataclasses.replace(small.engine,
                                          calendar_queue=calendar))
    t0 = time.time()
    rep = ScenarioRunner(s).run()
    wall = time.time() - t0
    tent = rep.policies["tent"]
    label = "calendar" if calendar else "heap"
    print(f"[{label:8s}] {20_000/wall:7.0f} requests-simulated/s | "
          f"tent {tent.throughput:7.0f} tok/s, "
          f"TTFT P90 {tent.extra['p90_ttft_s']:.2f}s, "
          f"TPOT P99 {tent.extra['p99_tpot_s']*1e3:.1f}ms | "
          f"ok={rep.ok}")
    d = rep.to_dict()
    d["spec"]["engine"]["calendar_queue"] = None  # the toggle's own echo
    reports[label] = json.dumps(d, sort_keys=True)

assert reports["heap"] == reports["calendar"]
print("\nheap vs calendar ScenarioReports: byte-identical "
      "(same pops, same RNG draws, same simulation)")
