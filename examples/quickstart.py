"""Quickstart: the TENT declarative transfer API in 40 lines.

Builds a two-node H800-style fabric, registers segments, declares a batched
transfer, and lets the engine spray slices across rails — then injects a NIC
failure mid-flight and shows the data still arrives intact.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FabricSpec, Location, MemoryKind, TentEngine

engine = TentEngine(FabricSpec())  # 2 nodes x 8 GPUs x 8x200Gbps rails

# 1. declare WHERE data lives (segments) — never WHICH wires to use
src = engine.register_segment(
    Location(node=0, kind=MemoryKind.HOST_DRAM, numa=0), 256 << 20, name="kv-src")
dst = engine.register_segment(
    Location(node=1, kind=MemoryKind.DEVICE_HBM, device=3, numa=0), 256 << 20, name="kv-dst")

payload = np.random.default_rng(0).integers(0, 256, 256 << 20, dtype=np.uint8)
src.write(0, payload)

# 2. break a rail while the elephant flow is in flight
nic = engine.topology.rdma_nic(0, 1)
engine.fabric.schedule_failure(nic.link_id, at=0.0005, recover_at=0.5)

# 3. declare intent; the engine plans routes, sprays slices, heals failures
batch = engine.allocate_batch()
engine.submit_transfer(batch, [(src.segment_id, 0, dst.segment_id, 0, 256 << 20)])
result = engine.wait(batch)

assert result.ok
np.testing.assert_array_equal(dst.read(0, 256 << 20), payload)
print(f"moved {result.bytes >> 20} MiB in {result.elapsed * 1e3:.2f} ms (virtual)")
print(f"throughput: {result.throughput / 1e9:.1f} GB/s across "
      f"{sum(1 for l in engine.fabric.links.values() if l.bytes_completed)} links")
print(f"slices retried around the failed NIC: {engine.slices_retried}")
print(f"rails excluded/readmitted: {engine.health.exclusions}/{engine.health.readmissions}")
print("data integrity: OK")
