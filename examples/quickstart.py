"""Quickstart: the TENT declarative transfer API in 40 lines.

The environment comes from the declarative scenario subsystem: we take the
named `single_rail_flap` scenario, swap in a full-rate H800-style fabric and
a mid-flight NIC failure, and let `ScenarioRunner.build_engine` materialize
the engine with the fault program installed. Then we declare one batched
transfer and watch the engine spray slices, absorb the flap, and deliver the
bytes intact.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.core import Location, MemoryKind
from repro.scenarios import FaultEvent, ScenarioRunner, TopologyParams, get

# 1. describe the world declaratively: topology + fault program, no wires
spec = dataclasses.replace(
    get("single_rail_flap"),
    name="quickstart",
    topology=TopologyParams(),  # 2 nodes x 8 GPUs x 8x200Gbps rails
    faults=(FaultEvent("fail", node=0, nic=1, at=0.0005, until=0.5),),
)
engine, _ = ScenarioRunner(spec).build_engine("tent")

# 2. declare WHERE data lives (segments) — never WHICH wires to use
src = engine.register_segment(
    Location(node=0, kind=MemoryKind.HOST_DRAM, numa=0), 256 << 20, name="kv-src")
dst = engine.register_segment(
    Location(node=1, kind=MemoryKind.DEVICE_HBM, device=3, numa=0), 256 << 20, name="kv-dst")

payload = np.random.default_rng(0).integers(0, 256, 256 << 20, dtype=np.uint8)
src.write(0, payload)

# 3. declare intent; the engine plans routes, sprays slices, heals the flap
batch = engine.allocate_batch()
engine.submit_transfer(batch, [(src.segment_id, 0, dst.segment_id, 0, 256 << 20)])
result = engine.wait(batch)

assert result.ok
np.testing.assert_array_equal(dst.read(0, 256 << 20), payload)
print(f"moved {result.bytes >> 20} MiB in {result.elapsed * 1e3:.2f} ms (virtual)")
print(f"throughput: {result.throughput / 1e9:.1f} GB/s across "
      f"{sum(1 for l in engine.fabric.links.values() if l.bytes_completed)} links")
print(f"slices retried around the failed NIC: {engine.slices_retried}")
print(f"rails excluded/readmitted: {engine.health.exclusions}/{engine.health.readmissions}")
print("data integrity: OK")
