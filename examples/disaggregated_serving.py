"""End-to-end driver: disaggregated LLM serving with TENT as the data plane.

A real (smoke-scale) qwen2-family model prefils prompts on node 0, ships the
decode cache across the simulated fabric through TENT (the PD-disaggregation
elephant flow), and decodes on node 1. Output tokens are verified against
monolithic generation; then the multi-tier HiCache is exercised with reuse.
The fabric is the one the `disagg_prefill_decode` regression scenario
declares — including its mid-run tier-1 NIC flap, which the data plane must
absorb without the model ever noticing.

Run:  PYTHONPATH=src python examples/disaggregated_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.scenarios import ScenarioRunner, get
from repro.serving import (
    DisaggregatedServer,
    HiCache,
    kv_bytes_per_token,
    make_cpu_pool,
    make_disk_pool,
    make_gpu_pool,
    monolithic_generate,
)

cfg = get_smoke_config("qwen2-0.5b").with_(remat="none")
params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
engine, _ = ScenarioRunner(get("disagg_prefill_decode")).build_engine("tent")

print("== prefill/decode disaggregation over TENT ==")
server = DisaggregatedServer(engine, cfg, params, prefill_node=0, decode_node=1)
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
res = server.generate(prompt, n_new=12, max_len=48)
ref = monolithic_generate(cfg, params, prompt, n_new=12, max_len=48)
np.testing.assert_array_equal(res.tokens, ref)
print(f"generated {res.tokens.shape[1]} tokens x {res.tokens.shape[0]} seqs; "
      f"KV flow {res.kv_bytes >> 10} KiB in {res.kv_transfer_seconds * 1e6:.0f} us (virtual)")
print("decode == monolithic: OK")

print("\n== multi-tier HiCache (GPU/CPU/disk) over TENT ==")
page_tokens = 16
pb = kv_bytes_per_token(cfg) * page_tokens
hc = HiCache(
    engine, cfg,
    gpu_pool=make_gpu_pool(engine, 0, 0, page_bytes=pb, num_pages=4),
    cpu_pool=make_cpu_pool(engine, 1, page_bytes=pb, num_pages=32),
    disk_pool=make_disk_pool(engine, 1, page_bytes=pb, num_pages=64),
    page_tokens=page_tokens,
)
convo_a = list(range(64))
convo_b = list(range(1000, 1064))
hc.insert(convo_a)
hc.insert(convo_b)  # evicts convo_a pages down-tier (GPU pool holds 4 pages)
print("tiers after two conversations:", hc.tier_counts())
fetch = hc.fetch_prefix(convo_a)
print(f"refetched convo A: {fetch.prefix_tokens} tokens, "
      f"{fetch.promoted_pages} pages promoted in {fetch.transfer_seconds * 1e6:.0f} us, "
      f"{fetch.bytes_moved >> 10} KiB moved")
print("tiers after promotion:", hc.tier_counts())
