"""RL-pipeline weight refresh via the Checkpoint Engine over TENT (Table 3).

Trains a real smoke model a few steps, stages the updated checkpoint on the
parameter-server node, then refreshes all 16 ranks' weights through the
transfer engine — comparing Mooncake-TE-style striping vs TENT spraying on
the same degraded fabric, with byte-exact verification. The fabric and its
fault program come from a declarative `ScenarioSpec`: the checkpoint
broadcast scenario with two silently degraded rails.

Run:  PYTHONPATH=src python examples/rl_weight_update.py
"""
import dataclasses

from repro.configs import get_smoke_config
from repro.scenarios import FaultEvent, ScenarioRunner, get
from repro.serving import CheckpointEngine
from repro.training import flatten_state, train

print("== a few real training steps (the 'RL update' source) ==")
cfg = get_smoke_config("qwen2-0.5b")
result = train(cfg, steps=8, batch_size=2, seq_len=64, log=lambda s: print("  " + s))
print(f"  tokens/sec {result.tokens_per_sec:.0f}")

print("\n== weight refresh across 2 nodes x 8 GPUs ==")
# the library's broadcast scenario, with two rails degraded to 25% for the
# whole run: the telemetry-driven engine must steer around them
spec = dataclasses.replace(
    get("checkpoint_broadcast"),
    name="rl_weight_update",
    faults=tuple(FaultEvent("degrade", node=0, nic=n, at=0.0, until=1e9, factor=0.25)
                 for n in (1, 5)),
    seed=3,
)
runner = ScenarioRunner(spec)
for policy in spec.policies[::-1]:  # round_robin first, tent last
    eng, _ = runner.build_engine(policy)
    ce = CheckpointEngine(eng, nodes=2, gpus_per_node=8)
    # scale the table to elephant-flow size by repeating the real weights
    import jax

    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    base = flatten_state(params)
    table = {f"rep{i}/{k}": v for i in range(256) for k, v in base.items()}
    ce.register_checkpoint(table)
    res = ce.update(verify=(policy == "tent"))
    label = "Mooncake TE (round-robin)" if policy == "round_robin" else "TENT"
    print(f"  {label:28s}: {res.bytes >> 20} MiB to {res.ranks} ranks in "
          f"{res.seconds * 1e3:.1f} ms  ({res.aggregate_bandwidth / 1e9:.1f} GB/s)")
print("  weights verified byte-exact on every rank: OK")
