"""Multi-engine TENT: the cluster control plane dissolving telemetry silos.

Five engines share one fabric — three prefill engines shipping KV into a
decode pool while a cache-tier engine's statically pinned elephants occupy
two of the receiver's NICs. Each prefill engine's own telemetry cannot see
that pressure until its slices are already stuck behind it; the cluster's
global load diffusion table (paper §4.2) shares every engine's queue
footprint — including receiver-side charges — so a diffusion-enabled spray
steers off the contended ordinals in advance. Then a decode-side NIC flaps:
the first engine to observe the wire failure gossips it, and every peer
reroutes before paying the detection latency itself (§4.3, cluster-wide).

Then the control plane itself gets hostile: gossip messages are dropped and
delayed (anti-entropy must close the gaps for healing to stay sub-50 ms),
and engines join/leave mid-run (departed state garbage-collected, joiners
bootstrapped cold through gossip).

Everything is the declarative scenario subsystem: the same specs drive
`tests/test_scenarios.py` and `python -m benchmarks.run --scenario ...`.

Run:  PYTHONPATH=src python examples/multi_engine.py
"""
from repro.scenarios import ScenarioRunner, get

print("== multi-engine KV incast: diffusion ON vs OFF vs Mooncake-TE ==")
spec = get("multi_engine_kv_incast")
rep = ScenarioRunner(spec).run()
rows = rep.policies
for policy, r in rows.items():
    label = {
        "tent+diffusion": "TENT + global diffusion",
        "tent": "TENT (siloed engines)",
        "round_robin": "Mooncake TE (state-blind)",
    }.get(policy, policy)
    print(f"  {label:26s} {r.throughput / 1e9:7.3f} GB/s   p99 "
          f"{r.latency_p99 * 1e3:6.2f} ms   exclusions {r.exclusions:3d}   "
          f"diffusion rounds {r.extra['diffusion_rounds']:.0f}")
on, off = rows["tent+diffusion"], rows["tent"]
print(f"  -> silo elimination is worth {on.throughput / off.throughput:.2f}x "
      f"under cross-engine incast")
assert on.throughput > off.throughput > rows["round_robin"].throughput
assert rep.ok, rep.violations

print("\n== + decode-side NIC flap: failure rumors heal the whole cluster ==")
spec = get("multi_engine_incast_flap")
rep = ScenarioRunner(spec).run()
r = rep.policies["tent+diffusion"]
print(f"  first observation gossiped as {r.extra['rumors_sent']:.0f} rumors "
      f"({r.extra['rumors_applied']:.0f} peer applications)")
print(f"  cluster-wide stall after onset: {r.stall_ms:.2f} ms (virtual, "
      f"budget 50 ms); retries {r.retries}, zero lost slices: "
      f"{r.lost_slices == 0}")
assert rep.ok, rep.violations

print("\n== trainer checkpoint broadcast through serving traffic ==")
rep = ScenarioRunner(get("trainer_broadcast_fanout")).run()
for policy, r in rep.policies.items():
    print(f"  {policy:16s} {r.throughput / 1e9:7.3f} GB/s")
assert rep.ok, rep.violations

print("\n== the crutch removed: 20% gossip loss + 5 ms delivery delay ==")
spec = get("lossy_gossip_flap")
rep = ScenarioRunner(spec).run()
r = rep.policies["tent+diffusion"]
print(f"  control plane dropped {r.extra['gossip_dropped']:.0f} of "
      f"{r.extra['gossip_msgs']:.0f} messages; anti-entropy repaired "
      f"{r.extra['anti_entropy_repairs']:.0f} replica gaps")
print(f"  cluster-wide stall after onset: {r.stall_ms:.2f} ms (virtual, "
      f"budget 50 ms) — healing survives a lossy control plane")
assert rep.ok, rep.violations

print("\n== membership churn: one engine leaves, a cold one joins ==")
rep = ScenarioRunner(get("engine_churn_diffusion")).run()
on, off = rep.policies["tent+diffusion"], rep.policies["tent"]
print(f"  joins {on.extra['engines_joined']:.0f}, leaves "
      f"{on.extra['engines_left']:.0f}; departed state GC'd, joiner "
      f"bootstrapped via gossip")
print(f"  diffusion still pays for itself through the churn: "
      f"{on.throughput / off.throughput:.2f}x over siloed engines")
assert rep.ok, rep.violations

print("\nall cluster expectations hold: diffusion-ON > diffusion-OFF > "
      "baseline — with loss, delay, partial views and churn — sub-50ms "
      "virtual healing, zero lost slices on every engine")
