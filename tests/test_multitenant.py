"""Multi-tenant behaviour: two engine instances (processes) sharing one
physical fabric, and the optional global load diffusion mechanism
(paper §4.2: processes publish per-NIC queue depths to shared memory and
blend a global load factor with weight omega)."""
import numpy as np

from repro.core import (
    EngineConfig,
    Fabric,
    FabricSpec,
    Location,
    MemoryKind,
    TentEngine,
    Topology,
)


def host_loc(node, numa=0):
    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


def _two_engines(omega: float):
    topo = Topology(FabricSpec())
    fabric = Fabric(topo, seed=5)
    e1 = TentEngine(topology=topo, fabric=fabric,
                    config=EngineConfig(global_diffusion_weight=omega))
    e2 = TentEngine(topology=topo, fabric=fabric,
                    config=EngineConfig(global_diffusion_weight=omega))
    if omega > 0:
        # shared-memory analogue: both stores point at one global table
        e2.store.global_load = e1.store.global_load
    return e1, e2, fabric


class TestMultiTenant:
    def test_two_engines_share_fabric_and_complete(self):
        e1, e2, fabric = _two_engines(omega=0.0)
        n = 32 << 20
        pairs = []
        for idx, eng in enumerate((e1, e2)):
            src = eng.register_segment(host_loc(0, 0), n)
            dst = eng.register_segment(host_loc(1, 0), n)
            # seed from the tenant index, not id(eng): object identity
            # changes run to run and would make the payloads irreproducible
            payload = np.random.default_rng(97 + idx).integers(0, 256, n, np.uint8)
            src.write(0, payload)
            b = eng.allocate_batch()
            eng.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, n)])
            pairs.append((eng, b, src, dst, payload))
        # drive the SHARED fabric until both engines' batches finish
        while any(eng.get_transfer_status(b)[1] > 0 for eng, b, *_ in pairs):
            assert fabric.step()
        for eng, b, src, dst, payload in pairs:
            res = eng.wait(b)
            assert res.ok
            np.testing.assert_array_equal(dst.read(0, n), payload)

    def test_payloads_depend_only_on_tenant_index(self):
        """Regression for the id(eng)-derived payload seed: payload bytes
        must be a pure function of the tenant index so reruns (and fresh
        engine objects) generate identical content."""
        n = 1 << 16
        a1 = np.random.default_rng(97 + 0).integers(0, 256, n, np.uint8)
        a2 = np.random.default_rng(97 + 0).integers(0, 256, n, np.uint8)
        b1 = np.random.default_rng(97 + 1).integers(0, 256, n, np.uint8)
        np.testing.assert_array_equal(a1, a2)
        assert not np.array_equal(a1, b1)

    def test_global_diffusion_biases_scores(self):
        """With omega > 0, tenant B's scheduler must see tenant A's queued
        bytes and score those rails worse."""
        e1, e2, _ = _two_engines(omega=0.5)
        n = 64 << 20
        src = e1.register_segment(host_loc(0, 0), n)
        dst = e1.register_segment(host_loc(1, 0), n)
        b = e1.allocate_batch()
        e1.submit_transfer(b, [(src.segment_id, 0, dst.segment_id, 0, n)])
        e1.store.publish_global()  # publish per-NIC queue depths
        # tenant B scores an idle-from-its-view rail that A loaded heavily
        loaded = max(
            (tl for _, tl in e1.store.items()), key=lambda t: t.queued_bytes
        )
        tl2 = e2.store.get(loaded.desc.link_id)
        assert tl2.queued_bytes == 0  # B itself queued nothing
        eff = e2.store.effective_queue(tl2)
        assert eff > 0, "global load factor must leak A's queue into B's view"
        e1.wait(b)
