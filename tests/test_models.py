"""Model-math correctness: SSD oracle equivalence, MoE dispatch equivalence,
decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
    prefill_forward,
)
from repro.models.moe import moe_ffn_dense, moe_ffn_sorted
from repro.models.ssm import ssd_chunked, ssd_recurrent_ref


class TestSSD:
    @pytest.mark.parametrize("shape", [(1, 64, 2, 8, 16), (2, 128, 4, 16, 32)])
    def test_chunked_matches_recurrence(self, shape):
        b, s, h, p, n = shape
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (b, s, h), jnp.float32)) * 0.3
        B = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
        C = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.5
        y_ref, st_ref = ssd_recurrent_ref(x, a, B, C)
        y, st = ssd_chunked(x, a, B, C, chunk=32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=2e-3, atol=2e-3)

    def test_initial_state_carries(self):
        b, s, h, p, n = 1, 64, 2, 8, 16
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (b, 2 * s, h, p), jnp.float32) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (b, 2 * s, h), jnp.float32)) * 0.3
        B = jax.random.normal(ks[2], (b, 2 * s, n), jnp.float32) * 0.5
        C = jax.random.normal(ks[3], (b, 2 * s, n), jnp.float32) * 0.5
        y_full, st_full = ssd_chunked(x, a, B, C, chunk=32)
        y1, st1 = ssd_chunked(x[:, :s], a[:, :s], B[:, :s], C[:, :s], chunk=32)
        y2, st2 = ssd_chunked(
            x[:, s:], a[:, s:], B[:, s:], C[:, s:], chunk=32, initial_state=st1
        )
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, s:]), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), rtol=2e-3, atol=2e-3)

    def test_interchunk_scan_jit_bitexact_vs_numpy(self):
        """Regression for the inter-chunk scan's fma guard: the jitted
        recurrence must reproduce an unfused numpy float32 evaluation
        (separate IEEE rounding for the product and the add) bit-exactly.
        Without the divide guard in `_interchunk_step`, XLA contracts
        `prev * dec + st` in the compiled scan body into a single-rounded
        fma and the states drift one ulp."""
        from repro.models.ssm import _interchunk_step

        rng = np.random.default_rng(11)
        c, b, h, p, n = 16, 2, 3, 4, 5
        states = rng.standard_normal((c, b, h, p, n)).astype(np.float32)
        decay = np.exp(-rng.random((c, b, h))).astype(np.float32)
        init = rng.standard_normal((b, h, p, n)).astype(np.float32)
        jitted = jax.jit(lambda i, xs: jax.lax.scan(_interchunk_step, i, xs))
        final, prevs = jitted(jnp.asarray(init),
                              (jnp.asarray(states), jnp.asarray(decay)))
        prev = init.copy()
        for k in range(c):
            np.testing.assert_array_equal(np.asarray(prevs[k]), prev)
            prev = prev * decay[k][..., None, None] + states[k]
        np.testing.assert_array_equal(np.asarray(final), prev)


@pytest.mark.slow
class TestMoE:
    def test_sorted_matches_dense_dispatch(self):
        cfg = get_smoke_config("dbrx-132b").with_(moe_capacity_factor=4.0)
        key = jax.random.PRNGKey(0)
        T, D = 64, cfg.d_model
        p = {
            "router": jax.random.normal(key, (D, cfg.num_experts), jnp.float32) * 0.1,
            "w_gate": jax.random.normal(key, (cfg.num_experts, D, cfg.d_ff), jnp.float32) * 0.05,
            "w_up": jax.random.normal(key, (cfg.num_experts, D, cfg.d_ff), jnp.float32) * 0.05,
            "w_down": jax.random.normal(key, (cfg.num_experts, cfg.d_ff, D), jnp.float32) * 0.05,
        }
        x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
        y_sorted, aux_s = moe_ffn_sorted(cfg, p, x)
        y_dense, aux_d = moe_ffn_dense(cfg, p, x)
        assert int(aux_s["dropped"]) == 0  # ample capacity: no drops
        np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_dense), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(aux_s["lb_loss"]), float(aux_d["lb_loss"]), rtol=1e-5)

    def test_capacity_drops_bounded(self):
        cfg = get_smoke_config("qwen3-moe-235b-a22b").with_(moe_capacity_factor=1.0)
        key = jax.random.PRNGKey(0)
        D = cfg.d_model
        p = {
            "router": jax.random.normal(key, (D, cfg.num_experts), jnp.float32),
            "w_gate": jnp.ones((cfg.num_experts, D, cfg.d_ff), jnp.float32) * 0.01,
            "w_up": jnp.ones((cfg.num_experts, D, cfg.d_ff), jnp.float32) * 0.01,
            "w_down": jnp.ones((cfg.num_experts, cfg.d_ff, D), jnp.float32) * 0.01,
        }
        x = jax.random.normal(jax.random.PRNGKey(2), (128, D), jnp.float32)
        y, aux = moe_ffn_sorted(cfg, p, x)
        assert y.shape == x.shape
        assert int(aux["dropped"]) < 128 * cfg.experts_per_token  # not everything dropped


@pytest.mark.slow
class TestDecodeConsistency:
    """prefill (decode_step replay) must agree with the parallel forward."""

    @pytest.mark.parametrize(
        "arch", ["qwen2-0.5b", "deepseek-7b", "mamba2-370m", "hymba-1.5b", "granite-34b"]
    )
    def test_last_token_logits_match(self, arch):
        cfg = get_smoke_config(arch).with_(remat="none")
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key, dtype=jnp.float32)
        B, S = 2, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        logits_par, _ = forward(cfg, params, tokens)
        last_dec, _ = prefill(cfg, params, tokens, max_len=32)
        np.testing.assert_allclose(
            np.asarray(last_dec), np.asarray(logits_par[:, -1]), rtol=2e-3, atol=2e-3
        )

    def test_sliding_window_decode_matches_forward(self):
        cfg = get_smoke_config("qwen2-0.5b").with_(remat="none", sliding_window=8)
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        B, S = 1, 24
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        logits_par, _ = forward(cfg, params, tokens)
        last_dec, _ = prefill(cfg, params, tokens, max_len=cfg.sliding_window)
        np.testing.assert_allclose(
            np.asarray(last_dec), np.asarray(logits_par[:, -1]), rtol=2e-3, atol=2e-3
        )

    @pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m", "hymba-1.5b"])
    def test_prefill_forward_matches_replay(self, arch):
        """The parallel prefill (serving path) must produce the same logits
        and a decode-compatible cache vs token-by-token replay."""
        cfg = get_smoke_config(arch).with_(remat="none")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        B, S = 2, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        max_len = max(S + 1, cfg.sliding_window)
        logits_pf, cache_pf = prefill_forward(cfg, params, tokens)
        logits_rp, cache_rp = prefill(cfg, params, tokens, max_len=max_len)
        np.testing.assert_allclose(
            np.asarray(logits_pf), np.asarray(logits_rp), rtol=2e-3, atol=2e-3
        )
        # continue decoding one step from both caches: identical next logits
        tok = jnp.argmax(logits_pf, axis=-1)[:, None].astype(jnp.int32)
        # pad prefill_forward cache to the replay cache's width if needed
        if "k" in cache_pf and cache_pf["k"].shape[2] < cache_rp["k"].shape[2]:
            padw = cache_rp["k"].shape[2] - cache_pf["k"].shape[2]
            for kk in ("k", "v"):
                cache_pf[kk] = jnp.pad(cache_pf[kk], ((0, 0), (0, 0), (0, padw), (0, 0), (0, 0)))
        l1, _ = decode_step(cfg, params, cache_pf, tok, jnp.int32(S))
        l2, _ = decode_step(cfg, params, cache_rp, tok, jnp.int32(S))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3, atol=2e-3)

    def test_encdec_decode(self):
        cfg = get_smoke_config("seamless-m4t-medium").with_(remat="none")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        B, S, SE = 2, 12, 8
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, SE, cfg.d_model), jnp.float32)
        logits_par, _ = forward(cfg, params, tokens, enc_frames=frames)
        last_dec, _ = prefill(cfg, params, tokens, max_len=32, enc_frames=frames)
        np.testing.assert_allclose(
            np.asarray(last_dec), np.asarray(logits_par[:, -1]), rtol=2e-3, atol=2e-3
        )


@pytest.mark.slow
class TestChunkedAttention:
    @pytest.mark.parametrize("window", [0, 64])
    def test_matches_full(self, window):
        from repro.models.attention import attend_chunked, attend_full

        B, S, H, K, D = 2, 256, 4, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        out = attend_chunked(q, k, v, causal=True, window=window, chunk=64)
        ref = attend_full(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_grads_match(self):
        from repro.models.attention import attend_chunked, attend_full

        B, S, H, K, D = 1, 128, 2, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        g1 = jax.grad(lambda q: attend_chunked(q, k, v, chunk=32).sum())(q)
        g2 = jax.grad(lambda q: attend_full(q, k, v).sum())(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
class TestMoEExpertParallel:
    def test_ep_matches_sorted_single_device(self):
        """shard_map EP path must equal the sorted-dispatch path (1-device
        mesh: E_loc = E, psum identity)."""
        import jax
        from jax.sharding import Mesh
        from repro.models.moe import moe_ffn_ep, moe_ffn_sorted
        from repro.sharding.ctx import activation_sharding

        cfg = get_smoke_config("dbrx-132b").with_(moe_capacity_factor=4.0)
        key = jax.random.PRNGKey(0)
        D = cfg.d_model
        p = {
            "router": jax.random.normal(key, (D, cfg.num_experts), jnp.float32) * 0.1,
            "w_gate": jax.random.normal(key, (cfg.num_experts, D, cfg.d_ff), jnp.float32) * 0.05,
            "w_up": jax.random.normal(key, (cfg.num_experts, D, cfg.d_ff), jnp.float32) * 0.05,
            "w_down": jax.random.normal(key, (cfg.num_experts, cfg.d_ff, D), jnp.float32) * 0.05,
        }
        x = jax.random.normal(jax.random.PRNGKey(1), (64, D), jnp.float32)
        y_ref, aux_ref = moe_ffn_sorted(cfg, p, x)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with mesh, activation_sharding(mesh):
            y_ep, aux_ep = jax.jit(lambda x: moe_ffn_ep(cfg, p, x))(x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(aux_ep["lb_loss"]), float(aux_ref["lb_loss"]), rtol=1e-4)
