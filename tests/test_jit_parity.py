"""Jitted engine-core regression tier.

PR 8 routes the wave chooser and the completion drain through jitted
fixed-shape kernels (`EngineConfig.jit_core`, `repro.core.jit_core`). Like
the wave and drain vectorizations before it (PRs 4-5), the jitted core must
be a pure *cost* change: with the toggle on, every scenario outcome — byte
counts, makespans, latency percentiles, retries, per-rail byte maps — has
to be bit-identical to the numpy path, because both run the same IEEE
double operations in the same order under `enable_x64`. These tests pin
that end-to-end across the whole scenario library (including the mid-run
fault-window scenarios), force the crossover to both extremes, and pin the
padded kernels against the scalar references with seeded randomized sweeps
that need no optional deps (the hypothesis twins live in
tests/test_properties.py).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import EngineConfig, FabricSpec, TelemetryStore, Topology
from repro.core import jit_core
from repro.core.jit_core import EngineJitCore, _bucket
from repro.core.scheduler import (
    tent_choose_wave,
    tent_choose_wave_padded_jnp,
    tent_on_complete_many_jnp,
)
from repro.scenarios import SCENARIOS, ScenarioRunner, get

pytestmark = pytest.mark.skipif(
    not jit_core.jax_available(), reason="jitted core requires jax")


def _policies(spec) -> dict:
    return ScenarioRunner(spec).run().to_dict()["policies"]


def _with_jit(spec, on=True):
    return dataclasses.replace(
        spec, engine=dataclasses.replace(spec.engine, jit_core=on))


class TestJitCoreBitIdentity:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_reports_identical_across_jit_toggle(self, name):
        """jit_core on vs off over the full scenario library: identical
        kernels modulo execution engine => identical decisions => identical
        fabric event sequence => every report metric matches exactly. The
        fault scenarios exercise the jitted chooser across exclusion
        windows, failure retries, and readmission."""
        spec = get(name)
        assert _policies(_with_jit(spec)) == _policies(spec)

    @pytest.mark.parametrize(
        "name", ["single_rail_flap", "elephant_mice_mix",
                 "degrade_recover_ramp"])
    def test_forced_crossover_parity(self, name, monkeypatch):
        """Crossover pinned to 1: every wave and every completion batch —
        including the single-slice and single-completion ones the adaptive
        crossover would route to numpy — goes through the jitted kernels,
        and the reports still cannot move."""
        monkeypatch.setattr(jit_core, "JIT_MIN", 1)
        monkeypatch.setattr(jit_core, "JIT_MIN_FLOOR", 1)
        monkeypatch.setattr(jit_core, "JIT_MIN_CEIL", 1)
        spec = get(name)
        assert _policies(_with_jit(spec)) == _policies(spec)

    def test_jit_kernels_actually_engage(self, monkeypatch):
        """Guard against the parity suite silently testing numpy-vs-numpy:
        both jitted kernels must actually dispatch. The chooser engages on
        any fat wave; batched completion drains only form on a zero-jitter
        fabric (distinct-timestamp completions drain per-op), so this
        drives an engine directly on one: 64 slices over 8 identical rails
        complete in same-timestamp groups of 8."""
        from repro.core import Fabric, TentEngine, Topology
        from repro.core.types import Location, MemoryKind

        counts = {"waves": 0, "drains": 0}
        orig_choose = EngineJitCore.choose_wave
        orig_drain = EngineJitCore.on_complete_many

        def counting_choose(self, sc, lengths):
            counts["waves"] += 1
            return orig_choose(self, sc, lengths)

        def counting_drain(self, slots, lengths, queued_at, t_obs):
            counts["drains"] += 1
            return orig_drain(self, slots, lengths, queued_at, t_obs)

        monkeypatch.setattr(EngineJitCore, "choose_wave", counting_choose)
        monkeypatch.setattr(EngineJitCore, "on_complete_many", counting_drain)
        monkeypatch.setattr(jit_core, "JIT_MIN", 1)
        monkeypatch.setattr(jit_core, "JIT_MIN_FLOOR", 1)
        monkeypatch.setattr(jit_core, "JIT_MIN_CEIL", 1)
        topo = Topology(FabricSpec())
        eng = TentEngine(
            topology=topo, fabric=Fabric(topo, seed=0, jitter=0.0),
            config=EngineConfig(jit_core=True))
        n = 4 << 20
        src = eng.register_segment(
            Location(node=0, kind=MemoryKind.HOST_DRAM, numa=0), n)
        dst = eng.register_segment(
            Location(node=1, kind=MemoryKind.HOST_DRAM, numa=0), n)
        src.write(0, np.arange(n, dtype=np.uint8))
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        np.testing.assert_array_equal(
            dst.read(0, n), np.arange(n, dtype=np.uint8))
        assert counts["waves"] > 0 and counts["drains"] > 0


# ---------------------------------------------------------------------------
# Padded kernels vs scalar references: seeded randomized sweeps (no
# optional deps). Padding rows, invalid slices, heavy exclusion — including
# the all-excluded fallback — and repeated slots are all drawn on purpose.
# ---------------------------------------------------------------------------


def _pad_choose_args(rng, n_c, n_s, all_excluded=False):
    q = rng.integers(0, 1 << 28, size=n_c)
    gl = rng.uniform(0.0, 1e7, size=n_c)
    gr = rng.uniform(0.0, 1e7, size=n_c)
    bw = rng.choice([1e9, 25e9, 100e9], size=n_c)
    b0 = rng.uniform(0.0, 1e-3, size=n_c)
    b1 = rng.uniform(0.05, 10.0, size=n_c)
    pen = rng.choice([1.0, 1.0, 1.5, np.inf], size=n_c)
    if all_excluded:
        ex = np.ones(n_c, dtype=bool)
    else:
        ex = rng.random(n_c) < 0.35
    lengths = rng.integers(1, 1 << 20, size=n_s)
    return q, gl, gr, bw, b0, b1, pen, ex, lengths


def _run_padded_choose(args, rr, gamma):
    q, gl, gr, bw, b0, b1, pen, ex, lengths = args
    n_c, n_s = len(q), len(lengths)
    pc, ps = _bucket(n_c), _bucket(n_s)

    def pad(a, n, fill, dtype=np.float64):
        out = np.full(n, fill, dtype=dtype)
        out[: len(a)] = a
        return out

    valid = np.zeros(ps, dtype=bool)
    valid[:n_s] = True
    from jax.experimental import enable_x64

    with enable_x64():
        c, qa, qo, rro = tent_choose_wave_padded_jnp(
            pad(q, pc, 0.0), pad(gl, pc, 0.0), pad(gr, pc, 0.0),
            pad(bw, pc, 1.0), pad(b0, pc, 0.0), pad(b1, pc, 1.0),
            pad(pen, pc, np.inf), pad(ex, pc, True, dtype=bool),
            pad(lengths, ps, 0.0), valid, rr, gamma)
        return (np.asarray(c)[:n_s].astype(np.int64),
                np.asarray(qa)[:n_s].astype(np.int64),
                np.asarray(qo)[:n_c].astype(np.int64), int(rro))


class TestPaddedChooseKernel:
    def test_matches_scalar_reference_randomized(self):
        rng = np.random.default_rng(29)
        for case in range(60):
            n_c = int(rng.integers(1, 11))
            n_s = int(rng.integers(1, 50))
            args = _pad_choose_args(rng, n_c, n_s)
            rr = int(rng.integers(0, 1000))
            gamma = float(rng.choice([0.0, 0.05, 0.2]))
            ref = tent_choose_wave(*args, rr, gamma=gamma)
            got = _run_padded_choose(args, rr, gamma)
            for r, g, label in zip(ref, got,
                                   ("choices", "queued_at", "queued", "rr")):
                assert np.array_equal(np.asarray(r), np.asarray(g)), \
                    f"case {case} {label}: {r} != {g}"

    def test_all_excluded_fallback_matches_scalar(self):
        """Every candidate soft-excluded: both paths must re-score without
        the exclusion mask (spray-anyway beats stalling) and still agree
        bit for bit — including the inf-penalty rails that stay out."""
        rng = np.random.default_rng(31)
        for case in range(20):
            n_c = int(rng.integers(2, 9))
            args = _pad_choose_args(rng, n_c, 8, all_excluded=True)
            ref = tent_choose_wave(*args, 5, gamma=0.05)
            got = _run_padded_choose(args, 5, 0.05)
            assert [np.asarray(r).tolist() for r in ref] == \
                [np.asarray(g).tolist() for g in got], f"case {case}"
            if np.isfinite(args[6]).any():  # some penalty finite
                assert (got[0] >= 0).all()  # fallback really selected rails

    def test_padding_rows_never_selected(self):
        """A padded candidate (penalty inf + excluded) must lose to any real
        rail even under the all-excluded fallback."""
        args = ([100], [0.0], [0.0], [1e9], [0.0], [1.0], [1.0], [True],
                [4096, 4096, 4096])
        choices, queued_at, queued, rr = _run_padded_choose(
            tuple(np.asarray(a, dtype=float) for a in args), 0, 0.05)
        assert (choices == 0).all()
        assert rr == 3 and queued[0] == 100 + 3 * 4096


def _seeded_store(rng, n_links):
    from repro.core.topology import LinkDesc
    from repro.core.types import LinkClass

    store = TelemetryStore()
    for i in range(n_links):
        desc = LinkDesc(link_id=i, node=0, link_class=LinkClass.RDMA,
                        index=i, numa=0,
                        bandwidth=float(rng.choice([25e9, 1e9])),
                        base_latency=5e-6)
        tl = store.ensure(desc)
        tl.queued_bytes = int(rng.integers(0, 1 << 30))
        tl.beta0 = float(rng.uniform(0.0, 1e-2))
        tl.beta1 = float(rng.uniform(0.05, 50.0))
        tl.ewma_service_time = float(rng.uniform(0.0, 1.0))
    return store


class TestPaddedDrainAdapter:
    def test_adapter_bit_equals_store_drain_randomized(self):
        """`EngineJitCore.on_complete_many` (gather -> padded jitted drain
        with scratch-slot batch padding -> scatter) vs the numpy store
        drain, heavy slot repetition included."""

        class _Policy:  # the drain path only touches the store
            _rr = 0
            gamma = 0.05

        rng = np.random.default_rng(47)
        for case in range(40):
            n_links = int(rng.integers(1, 7))
            seed = int(rng.integers(0, 1 << 30))
            a = _seeded_store(np.random.default_rng(seed), n_links)
            b = _seeded_store(np.random.default_rng(seed), n_links)
            m = int(rng.integers(1, 40))
            slots = rng.integers(0, n_links, size=m)
            lengths = rng.integers(0, 1 << 22, size=m)
            queued_at = rng.integers(0, 1 << 24, size=m)
            t_obs = rng.uniform(0.0, 5.0, size=m)
            a.on_complete_many(slots, lengths, queued_at, t_obs)
            EngineJitCore(_Policy(), b).on_complete_many(
                slots, lengths, queued_at, t_obs)
            for name in ("beta0_arr", "beta1_arr", "queued_arr",
                         "ewma_service_arr", "completions_arr"):
                x, y = getattr(a, name)[:a.n], getattr(b, name)[:b.n]
                assert (x == y).all(), f"case {case} {name}: {x} != {y}"

    def test_scratch_row_survives_padding(self):
        """Batch padding scatters into slot n; the write-back must discard
        it and leave rows 0..n-1 governed only by the real batch."""
        a = _seeded_store(np.random.default_rng(9), 3)
        b = _seeded_store(np.random.default_rng(9), 3)

        class _Policy:
            _rr = 0
            gamma = 0.05

        batch = ([0, 2, 2], [4096, 1 << 20, 0], [100, 5000, 0],
                 [0.25, 0.5, 0.75])
        a.on_complete_many(*(np.asarray(c) for c in batch))
        core = EngineJitCore(_Policy(), b)
        core.on_complete_many(*(np.asarray(c) for c in batch))
        assert (a.beta1_arr[:3] == b.beta1_arr[:3]).all()
        assert (a.queued_arr[:3] == b.queued_arr[:3]).all()
        assert core.drains == 1


class TestCrossoverTuner:
    def test_tune_tracks_the_wave_min_shape(self):
        store = _seeded_store(np.random.default_rng(1), 2)

        class _Policy:
            _rr = 0
            gamma = 0.05

        core = EngineJitCore(_Policy(), store)
        assert core.min_batch == jit_core.JIT_MIN
        core.tune(2.0 * jit_core.JIT_MIN)
        assert core.min_batch == jit_core.JIT_MIN_FLOOR
        core.tune(0.5 * jit_core.JIT_MIN)
        assert core.min_batch == jit_core.JIT_MIN_CEIL
        core.tune(1.2 * jit_core.JIT_MIN)
        assert core.min_batch == jit_core.JIT_MIN

    def test_jax_unavailable_falls_back_with_warning(self, monkeypatch):
        """jit_core requested in an environment without jax: the engine
        must warn once and run the numpy path, not crash."""
        monkeypatch.setattr(jit_core, "jax_available", lambda: False)
        spec = _with_jit(get("single_rail_flap"))
        with pytest.warns(RuntimeWarning, match="jax is unavailable"):
            on = _policies(spec)
        assert on == _policies(get("single_rail_flap"))
