"""Deliberate no-global-rng violations."""
import random
import time

import numpy as np


def draw_global():
    a = np.random.rand(4)  # VIOLATION: numpy global RNG
    np.random.seed(0)  # VIOLATION: seeding the global state
    b = random.random()  # VIOLATION: stdlib global RNG
    return a, b


def bad_seeds(obj):
    g1 = np.random.default_rng(id(obj))  # VIOLATION: id() seed
    g2 = np.random.default_rng(hash("x") % 100)  # VIOLATION: hash() seed
    g3 = np.random.default_rng(int(time.time()))  # VIOLATION: wall seed
    return g1, g2, g3
