"""Deliberate no-wall-clock violations (linted by tests/test_analysis.py
with this directory treated as engine source; never walked by the default
tree scan)."""
import time
from datetime import datetime
from time import perf_counter as pc


def stamp_now():
    t0 = time.time()  # VIOLATION: wall clock in engine source
    t1 = pc()  # VIOLATION: aliased from-import of perf_counter
    when = datetime.now()  # VIOLATION: datetime wall clock
    return t0, t1, when


def sleepy():
    time.sleep(0.1)  # VIOLATION: real sleeping on a simulated path
