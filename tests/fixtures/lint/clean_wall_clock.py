"""Known-clean twin of bad_wall_clock: virtual time only."""


def stamp_now(fabric):
    return fabric.now  # virtual clock: the only time source allowed


def elapsed(fabric, t0):
    return fabric.now - t0
