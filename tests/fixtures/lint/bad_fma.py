"""Deliberate fma-hazard violations: unguarded products feeding adds
inside compiled scan/jit bodies."""
import jax
import jax.numpy as jnp


def ewma_scan(xs, alpha):
    def step(carry, x):
        new = alpha * x + (1 - alpha) * carry  # VIOLATION x2: both products
        return new, new

    return jax.lax.scan(step, jnp.zeros(()), xs)


@jax.jit
def blend(u, v, w):
    return u * v + w  # VIOLATION: jitted kernel, direct mult into add


def index_math(xs):
    def step(carry, x):
        return carry + 4 * 8, x  # clean: integer-constant product

    return jax.lax.scan(step, 0, xs)
