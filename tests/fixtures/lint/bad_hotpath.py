"""Deliberate hot-path-alloc violations inside @hot_path bodies."""
import functools

from repro.analysis import hot_path


@hot_path
def drain(ops, registry, cb):
    for op in ops:
        registry.defer(lambda: cb(op))  # VIOLATION: per-iteration lambda
        handler = functools.partial(cb, op)  # VIOLATION: partial wrapper
        sizes = [o.nbytes for o in op.parts]  # VIOLATION: comp in loop
        handler(sizes)


@hot_path
def nested_def_in_loop(items):
    while items:
        def helper(x):  # VIOLATION: nested def per iteration
            return x + 1

        items = items[:-1] and helper(items)
