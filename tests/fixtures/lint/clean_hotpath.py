"""Known-clean twin of bad_hotpath: hoisted setup, no per-iteration churn."""
from repro.analysis import hot_path


@hot_path
def drain(ops, registry, cb):
    batch = [None] * len(ops)  # one-time setup before the loop: fine
    for i, op in enumerate(ops):
        batch[i] = op.nbytes  # writes into a preallocated buffer
        registry.defer_many(batch)
    return batch


def cold_path(ops, cb):
    # untagged: the alloc discipline does not apply here
    return [lambda: cb(op) for op in ops]
