"""Known-clean twin of bad_fma: every product routed through a division
(the PR 8 uncontractable-divide guard)."""
import jax
import jax.numpy as jnp


def ewma_scan(xs, alpha):
    def step(carry, x):
        one = jnp.where(x == x, 1.0, 2.0)  # traced, always exactly 1.0
        new = (alpha * x) / one + ((1 - alpha) * carry) / one
        return new, new

    return jax.lax.scan(step, jnp.zeros(()), xs)


@jax.jit
def blend(u, inv_v, w):
    return u / inv_v + w  # trailing division: not a contraction candidate


def eager_blend(u, v, w):
    return u * v + w  # clean: not inside a scan/jit body
