"""Known-clean twin of bad_unordered: sorted wrappers and membership."""


def report_rails(excluded_ids):
    out = []
    for r in sorted({1, 2, 3}):  # sorted() pins the order
        out.append(r)
    for e in sorted(set(excluded_ids)):
        out.append(e)
    return out


def membership(ids, probe):
    seen = set(ids)
    return probe in seen  # membership test, not iteration


def reduce_ok(ids):
    return len(set(ids)), min(set(ids) | {0})  # order-free reductions
