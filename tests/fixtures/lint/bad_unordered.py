"""Deliberate unordered-iter violations: set iteration on engine paths."""


def report_rails(excluded_ids):
    rails = {1, 2, 3}
    out = []
    for r in rails:  # VIOLATION: set-literal local iterated
        out.append(r)
    for e in set(excluded_ids):  # VIOLATION: set() call iterated
        out.append(e)
    return out


def merge(a, b):
    return [x for x in a | {0}]  # VIOLATION: set-union comprehension


def materialize(ids):
    return list(frozenset(ids))  # VIOLATION: list() over a frozenset
