"""Pragma exercise file: every violation here carries a suppression."""
# tentlint: disable-file=no-global-rng
import random
import time


def stamped():
    return time.time()  # tentlint: disable=no-wall-clock


def drawn():
    return random.random()  # covered by the disable-file pragma above


def still_bad():
    return time.perf_counter()  # unsuppressed: must still be flagged
