"""Known-clean twin of bad_global_rng: seeded generators only."""
import random

import numpy as np


def draw_seeded(seed: int):
    rng = np.random.default_rng(seed)
    pyr = random.Random(seed)
    return rng.random(4), pyr.random()


def derive(seed: int, lane: int):
    ss = np.random.SeedSequence(seed)
    return np.random.default_rng(ss.spawn(lane + 1)[lane])
