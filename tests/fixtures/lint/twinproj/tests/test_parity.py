"""Parity tests for the twinproj fixture kernels (textual references are
what the twin-drift rule checks for)."""
from ..kernels import drifted, drifted_jnp, good_kernel, good_kernel_jnp, waived_jnp


def test_good_kernel_parity():
    assert good_kernel_jnp(2.0, 3.0) == good_kernel(2.0, 3.0)


def test_drifted_parity():
    assert drifted_jnp(1.0, 0.5) == drifted(1.0, 0.5)


def test_waived_matches_scalar_twin():
    assert list(waived_jnp([1, 2, 3], 2)) == [
        good_kernel(1, 1), good_kernel(2, 1)]
