"""Twin-drift fixture mini-project: one clean kernel pair, one signature
drift, one orphan, one waived pair, one untested pair."""
import numpy as np

__numpy_twins__ = {
    "waived_jnp": ["good_kernel", "array-batch API vs scalar twin"],
}


def good_kernel(x, scale):
    return np.asarray(x) * scale


def good_kernel_jnp(x, scale):  # clean: twin + matching params + test
    return x * scale


def drifted(x, beta):
    return np.asarray(x) + beta


def drifted_jnp(x, alpha):  # VIOLATION: param names drifted (alpha vs beta)
    return x + alpha


def orphan_jnp(x):  # VIOLATION: no numpy twin anywhere
    return x


def waived_jnp(data, n):  # clean: registered waiver skips signature check
    return data[:n]


def untested(x):
    return np.abs(x)


def untested_jnp(x):  # VIOLATION: twin exists but no parity test names both
    return abs(x)


def _private_jnp(x):  # underscore-private: outside the twin contract
    return x
