"""Observability layer: flight recorder, provenance, metrics, trace export.

The contracts pinned here, in order of importance:

- **Passivity / parity** — attaching a `FlightRecorder` to a scenario run
  never changes the resulting report (the full `PolicyReport.to_dict()`,
  no keys excluded).
- **Determinism** — same spec + seed produces a byte-identical exported
  Chrome trace, run-to-run in one process (dense-id interning hides the
  process-global slice/batch counters).
- **Provenance** — every recorded wave's per-candidate score breakdown
  replays to exactly the choices the engine made (`replay_wave` re-runs
  Algorithm 1 from the recorded inputs and raises on divergence).
- **Healing cross-check** — the flight-recorder timeline re-derives the
  sub-50 ms healing number and it *equals* the report's stall matrix
  (same float ops, exact equality).
- **Uniform counter surface** — all workload kinds route the engine
  counters through one `MetricsRegistry`, so every report's `extra`
  carries the same keys.
- **Docs drift guards** — the scenario README's table stays in sync with
  `SCENARIOS`.
"""
import contextlib
import io
import json
import pathlib
import re

import numpy as np
import pytest

from repro.core import EngineConfig, FabricSpec, TentEngine
from repro.obs import (
    Counter,
    FlightRecorder,
    Histogram,
    MetricsRegistry,
    export_chrome_trace,
    to_json,
    validate_trace,
)
from repro.obs import events as EV
from repro.obs import explain
from repro.obs.explain import (
    healing_timeline,
    print_slice_chain,
    replay_wave,
    slice_chain,
)
from repro.scenarios import SCENARIOS, ScenarioRunner, get
from repro.scenarios.spec import ClusterWorkload

REPO = pathlib.Path(__file__).resolve().parent.parent
ENGINE_COUNTER_KEYS = (
    "slices_issued", "waves", "completions_drained", "completion_batches")


def _run_recorded(name, policy=None, capacity=1 << 18):
    spec = get(name)
    rec = FlightRecorder(capacity=capacity)
    rep = ScenarioRunner(spec).run_policy(
        policy or spec.policies[0], recorder=rec)
    return rec, rep


@pytest.fixture(scope="module")
def incast_flap():
    """multi_engine_incast_flap under tent+diffusion, recorded."""
    return _run_recorded("multi_engine_incast_flap", "tent+diffusion")


@pytest.fixture(scope="module")
def gossip_flap():
    """lossy_gossip_flap under tent+diffusion, recorded."""
    return _run_recorded("lossy_gossip_flap", "tent+diffusion")


@pytest.fixture(scope="module")
def serving_recorded():
    """serving_closed_loop_flap under tent, recorded (request spans)."""
    return _run_recorded("serving_closed_loop_flap", "tent")


class _FakeSlice:
    def __init__(self, slice_id, batch_id, src_offset, length):
        self.slice_id = slice_id
        self.batch_id = batch_id
        self.src_offset = src_offset
        self.length = length


class TestFlightRecorder:
    def test_append_and_order(self):
        rec = FlightRecorder(capacity=8)
        for i in range(5):
            rec.append(EV.POST, float(i), {"i": i})
        assert len(rec) == 5
        assert rec.dropped == 0
        evs = list(rec.events())
        assert [ts for ts, _, _ in evs] == [0.0, 1.0, 2.0, 3.0, 4.0]
        assert [pl["i"] for _, _, pl in evs] == list(range(5))

    def test_ring_wraparound_drops_oldest(self):
        rec = FlightRecorder(capacity=4)
        for i in range(7):
            rec.append(EV.POST, float(i), {"i": i})
        assert len(rec) == 4
        assert rec.dropped == 3
        assert [pl["i"] for _, _, pl in rec.events()] == [3, 4, 5, 6]

    def test_counts_by_kind_name(self):
        rec = FlightRecorder()
        rec.append(EV.WAVE, 0.0, {})
        rec.append(EV.COMPLETE, 1.0, {})
        rec.append(EV.COMPLETE, 2.0, {})
        assert rec.counts() == {"wave": 1, "complete": 2}

    def test_invalid_capacity(self):
        with pytest.raises(ValueError):
            FlightRecorder(capacity=0)

    def test_lazy_interning_first_seen_order(self):
        rec = FlightRecorder()
        a = _FakeSlice(900, 70, 0, 64)
        b = _FakeSlice(905, 70, 64, 64)
        rec.append(EV.WAVE, 0.0, {"slices": [b, a]})
        rec.append(EV.POST, 1.0, {"slice": a})
        # nothing interned until a read happens
        assert rec.n_slices() == 0
        evs = list(rec.events())
        # first-seen order over the event stream: b then a
        assert evs[0][2]["slices"] == [0, 1]
        assert evs[1][2]["slice"] == 1
        assert rec.n_slices() == 2
        assert rec.n_batches() == 1
        assert rec.slice_info(0) == (0, 64, 64)  # b: batch 0, offset 64
        # a second read is idempotent
        assert list(rec.events())[0][2]["slices"] == [0, 1]

    def test_interning_resumes_after_read(self):
        rec = FlightRecorder()
        rec.append(EV.POST, 0.0, {"slice": _FakeSlice(10, 1, 0, 8)})
        list(rec.events())
        rec.append(EV.POST, 1.0, {"slice": _FakeSlice(11, 1, 8, 8)})
        evs = list(rec.events())
        assert [pl["slice"] for _, _, pl in evs] == [0, 1]


class TestMetricsRegistry:
    def test_counter_and_gauge(self):
        reg = MetricsRegistry()
        c = reg.counter("retries")
        c.inc()
        c.inc(2)
        box = {"v": 7}
        reg.gauge("waves", lambda: box["v"])
        assert reg.collect() == {"retries": 3.0, "waves": 7.0}
        box["v"] = 9  # gauges are lazy: re-collection sees the new value
        assert reg.collect()["waves"] == 9.0

    def test_counter_is_idempotent_per_name(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")

    def test_registration_order_preserved(self):
        reg = MetricsRegistry()
        reg.gauge("z", lambda: 1)
        reg.counter("a")
        reg.gauge_group(lambda: {"m": 1.0, "b": 2.0})
        assert list(reg.collect()) == ["z", "a", "m", "b"]

    def test_histogram_summary(self):
        reg = MetricsRegistry()
        h = reg.histogram("ttft")
        assert reg.collect() == {"ttft_count": 0.0}
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        out = reg.collect()
        assert out["ttft_count"] == 3.0
        assert out["ttft_mean"] == pytest.approx(2.0)
        assert out["ttft_p50"] == pytest.approx(2.0)
        assert h.count == 3

    def test_timestamped_uses_clock(self):
        reg = MetricsRegistry(clock=lambda: 4.25)
        reg.counter("n").inc()
        ts, out = reg.timestamped()
        assert ts == 4.25 and out == {"n": 1.0}

    def test_standalone_primitives(self):
        c = Counter("c")
        c.inc(5)
        assert c.value == 5.0
        h = Histogram("h")
        h.observe(1.0, ts=0.5)
        assert h.count == 1


class TestZeroCostDefaults:
    def test_engine_recorder_off_by_default(self):
        eng = TentEngine(FabricSpec(n_nodes=2), config=EngineConfig(), seed=1)
        assert eng._rec is None
        assert eng.fabric._rec is None
        assert eng.health._rec is None


class TestReportParity:
    """Tracing ON vs OFF must produce byte-identical reports."""

    def test_cluster_report_parity(self, incast_flap):
        _, rep_on = incast_flap
        rep_off = ScenarioRunner(get("multi_engine_incast_flap")).run_policy(
            "tent+diffusion")
        assert rep_on.to_dict() == rep_off.to_dict()

    def test_single_engine_report_parity(self):
        rec, rep_on = _run_recorded("uniform_spray")
        rep_off = ScenarioRunner(get("uniform_spray")).run_policy("tent")
        assert rep_on.to_dict() == rep_off.to_dict()
        assert len(rec) > 0


class TestTraceDeterminism:
    """Same spec + seed => byte-identical exported trace."""

    def test_cluster_trace_bytes(self, incast_flap):
        rec1, _ = incast_flap
        rec2, _ = _run_recorded("multi_engine_incast_flap", "tent+diffusion")
        assert to_json(export_chrome_trace(rec1)) == \
            to_json(export_chrome_trace(rec2))

    def test_single_engine_trace_bytes(self):
        rec1, _ = _run_recorded("uniform_spray")
        rec2, _ = _run_recorded("uniform_spray")
        assert to_json(export_chrome_trace(rec1)) == \
            to_json(export_chrome_trace(rec2))


class TestTraceSchema:
    def test_validates_and_round_trips(self, incast_flap):
        rec, _ = incast_flap
        doc = export_chrome_trace(rec)
        assert validate_trace(doc) == []
        blob = to_json(doc)
        parsed = json.loads(blob)
        assert parsed["displayTimeUnit"] == "ms"
        assert parsed["otherData"]["dropped"] == 0
        evs = parsed["traceEvents"]
        assert len(evs) > 0
        # metadata names every process, spans carry microsecond timestamps
        assert any(e["ph"] == "M" and e["name"] == "process_name"
                   for e in evs)
        assert any(e["ph"] == "X" and e["dur"] >= 0 for e in evs)

    def test_serving_request_spans(self, serving_recorded):
        rec, _ = serving_recorded
        phases = [pl for _, k, pl in rec.events() if k == EV.PHASE]
        assert phases, "serving run recorded no request phases"
        kinds = {pl["phase"] for pl in phases}
        assert {"fetch", "prefill", "decode", "request"} <= kinds
        for pl in phases:
            if pl["phase"] == "request":
                assert pl["ttft"] >= 0.0
        doc = export_chrome_trace(rec)
        assert validate_trace(doc) == []
        assert any(e.get("tid") == 5 and e["ph"] == "X"
                   for e in doc["traceEvents"])


class TestDecisionProvenance:
    @pytest.mark.parametrize("fixture", ["incast_flap", "gossip_flap"])
    def test_every_wave_replays_to_recorded_choices(self, fixture, request):
        rec, _ = request.getfixturevalue(fixture)
        waves = [pl for _, k, pl in rec.events() if k == EV.WAVE]
        assert waves, "no waves recorded"
        for pl in waves:
            rows = replay_wave(pl)  # raises ProvenanceError on divergence
            assert len(rows) == len(pl["slices"])
            n_rails = len(pl["inputs"]["queued"])
            for row in rows:
                if not row["infeasible"]:
                    assert len(row["scores"]) == n_rails
                    assert row["chosen"] in row["window"] or row["fallback"]

    def test_replay_detects_tampering(self, incast_flap):
        rec, _ = incast_flap
        pl = next(pl for _, k, pl in rec.events() if k == EV.WAVE
                  if len(pl["slices"]) > 0)
        bad = dict(pl)
        choices = np.array(pl["choices"], copy=True)
        n_rails = len(pl["inputs"]["queued"])
        choices[0] = (int(choices[0]) + 1) % n_rails
        bad["choices"] = choices
        with pytest.raises(explain.ProvenanceError):
            replay_wave(bad)


class TestHealingCrossCheck:
    """Satellite: the sub-50 ms healing claim, re-derived from the flight
    recorder and cross-checked against the stall matrix — exact equality,
    because `healing_timeline` mirrors `ScenarioRunner._stall_ms` float op
    for float op."""

    @pytest.mark.parametrize("fixture", ["incast_flap", "gossip_flap"])
    def test_trace_heal_equals_stall_matrix(self, fixture, request):
        rec, rep = request.getfixturevalue(fixture)
        events = list(rec.events())
        h = healing_timeline(events, exclude_engines=("cache",))
        assert h["heal_ms"] == rep.stall_ms  # exact: same float ops
        assert h["heal_ms"] < 50.0
        assert h["onsets"], "no fault onset in a flap scenario?"
        assert h["first_failure"] is not None
        assert h["last_reroute"] is not None
        assert h["first_failure"] >= h["onsets"][0]

    def test_empty_timeline(self):
        h = healing_timeline([])
        assert h["heal_ms"] == -1.0 and h["onsets"] == []


class TestSliceChains:
    def test_wave_slice_chain_has_causal_steps(self, incast_flap):
        rec, _ = incast_flap
        events = list(rec.events())
        sid = next(pl["slices"][0] for _, k, pl in events if k == EV.WAVE)
        steps = [s for _, s, _ in slice_chain(rec, events, sid)]
        assert "intent" in steps
        assert "wave" in steps
        assert "complete" in steps
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            print_slice_chain(rec, events, sid)
        out = buf.getvalue()
        assert f"slice {sid}" in out
        assert "wave pick" in out and "score" in out

    def test_failed_slice_chain_shows_reroute(self, incast_flap):
        rec, _ = incast_flap
        events = list(rec.events())
        fails = [pl["slice"] for _, k, pl in events if k == EV.FAIL]
        assert fails, "flap scenario recorded no failures"
        steps = [s for _, s, _ in slice_chain(rec, events, fails[0])]
        assert "fail" in steps
        assert "reroute" in steps or "substitute" in steps


class TestExplainCLI:
    def test_main_runs_and_prints_chain(self, tmp_path, capsys):
        trace = tmp_path / "t.json"
        rv = explain.main([
            "--scenario", "uniform_spray", "--slice", "0",
            "--trace-out", str(trace)])
        assert rv == 0
        out = capsys.readouterr().out
        assert "uniform_spray" in out
        assert "slice 0" in out
        assert trace.exists()
        assert validate_trace(json.loads(trace.read_text())) == []


class TestUniformCounterSurface:
    """Satellite: every workload kind reports the engine counters through
    the one MetricsRegistry path."""

    def test_cluster_extra_keys(self, incast_flap):
        _, rep = incast_flap
        for key in ENGINE_COUNTER_KEYS:
            assert key in rep.extra, key
        # the cluster group adds the control-plane keys around them
        for key in ("engines", "diffusion_rounds", "rumors_sent"):
            assert key in rep.extra, key

    @pytest.mark.parametrize("name,policy", [
        ("uniform_spray", "tent"),          # closed loop
        ("hicache_serve", "tent"),          # serve table
    ])
    def test_single_engine_extra_keys(self, name, policy):
        rep = ScenarioRunner(get(name)).run_policy(policy)
        for key in ENGINE_COUNTER_KEYS:
            assert key in rep.extra, (name, key)
        assert rep.extra["slices_issued"] > 0

    def test_serving_extra_keys(self, serving_recorded):
        _, rep = serving_recorded
        for key in ENGINE_COUNTER_KEYS:
            assert key in rep.extra, key


class TestDocsDriftGuards:
    """Satellite: the scenario README's numbers track the library."""

    def test_scenario_table_matches_registry(self):
        text = (REPO / "src/repro/scenarios/README.md").read_text()
        section = text.split("## Named library", 1)[1].split("\n## ", 1)[0]
        rows = re.findall(r"^\| `([a-z0-9_]+)`\s*\|", section, re.M)
        assert len(rows) == len(SCENARIOS), (
            f"scenario README table has {len(rows)} rows, library has "
            f"{len(SCENARIOS)} — update src/repro/scenarios/README.md")
        assert set(rows) == set(SCENARIOS)

    def test_cluster_entry_count_prose(self):
        text = (REPO / "src/repro/scenarios/README.md").read_text()
        m = re.search(r"The (\w+) cluster entries", text)
        assert m, "cluster-entry prose missing from scenario README"
        words = {"two": 2, "three": 3, "four": 4, "five": 5, "six": 6,
                 "seven": 7, "eight": 8, "nine": 9, "ten": 10,
                 "eleven": 11, "twelve": 12}
        actual = sum(1 for s in SCENARIOS.values()
                     if isinstance(s.workload, ClusterWorkload))
        assert words.get(m.group(1)) == actual, (
            f"README says '{m.group(1)}' cluster entries, library has "
            f"{actual}")


class TestRecorderVsJitCore:
    """Record sites are statically absent from the jitted kernels
    (`repro.core.jit_core` traces no recorder appends), so attaching a
    FlightRecorder to a jit-enabled engine must loudly force the scalar
    path — and, because both paths are bit-exact, leave the report
    untouched."""

    def test_attach_disables_jit_with_warning(self):
        eng = TentEngine(FabricSpec(n_nodes=2),
                         config=EngineConfig(jit_core=True), seed=3)
        assert eng._jit is not None
        with pytest.warns(RuntimeWarning,
                          match="record sites cannot run under jit"):
            eng.attach_recorder(FlightRecorder())
        assert eng._jit is None

    def test_recorded_jit_run_matches_unrecorded_scalar_run(self):
        """recorder + jit_core => scalar path, report byte-identical to the
        plain jit-off run (tracing stays passive even when it evicts the
        jitted core)."""
        import dataclasses
        import warnings

        from repro.scenarios import ScenarioRunner, get

        spec = get("single_rail_flap")
        jit_spec = dataclasses.replace(
            spec, engine=dataclasses.replace(spec.engine, jit_core=True))
        with pytest.warns(RuntimeWarning,
                          match="record sites cannot run under jit"):
            rep_on = ScenarioRunner(jit_spec).run_policy(
                "tent", recorder=FlightRecorder())
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # scalar run must stay silent
            rep_off = ScenarioRunner(spec).run_policy("tent")
        assert rep_on.to_dict() == rep_off.to_dict()
