"""Fault-semantics unit tests for the fabric simulator and the resilience
paths around it: abort/completion accounting, failure-detection latency,
degrade-window bookkeeping, and the scheduler's soft-exclusion fallbacks.
These are the primitives the scenario regression tier leans on."""
import numpy as np
import pytest

from repro.core import Fabric, FabricSpec, TentPolicy, Topology
from repro.core.resilience import HealthConfig, HealthMonitor
from repro.core.scheduler import Candidate
from repro.core.telemetry import LinkTelemetry, TelemetryStore
from repro.core.topology import LinkDesc
from repro.core.types import LinkClass, TentError


def _fabric(jitter=0.0):
    return Fabric(Topology(FabricSpec()), seed=0, jitter=jitter)


def _nic(fabric, node=0, idx=0):
    return fabric.topology.rdma_nic(node, idx)


class _Recorder:
    def __init__(self, fabric):
        self.fabric = fabric
        self.events = []  # (ok, err, t_callback)

    def __call__(self, ok, t0, t1, err):
        self.events.append((ok, err, self.fabric.now))


class TestMidFlightAbort:
    def test_exactly_one_failure_completion(self):
        fab = _fabric()
        nic = _nic(fab)
        rec = _Recorder(fab)
        # 100 MB at 25 GB/s ~= 4 ms of service; fail the link at 1 ms.
        fab.post(nic.link_id, None, 100 << 20, rec)
        fab.schedule_failure(nic.link_id, at=1e-3, recover_at=10.0)
        fab.run_until_idle()
        assert len(rec.events) == 1
        ok, err, _ = rec.events[0]
        assert not ok and err == "LinkFailed"

    def test_abort_releases_the_link(self):
        fab = _fabric()
        nic = _nic(fab)
        fab.post(nic.link_id, None, 100 << 20, lambda *a: None)
        fab.schedule_failure(nic.link_id, at=1e-3, recover_at=2e-3)
        fab.run_until_idle()
        assert not fab.links[nic.link_id].outstanding
        # after recovery, the link serves new work normally
        rec = _Recorder(fab)
        fab.post(nic.link_id, None, 1 << 20, rec)
        fab.run_until_idle()
        assert rec.events and rec.events[0][0]

    def test_completion_after_window_opened_is_failure(self):
        """A failure window that opens after posting but before completion
        turns the completion into an error (no silent corruption)."""
        fab = _fabric()
        nic = _nic(fab)
        rec = _Recorder(fab)
        fab.post(nic.link_id, None, 100 << 20, rec)
        # window opens mid-flight and closes before the nominal end: the op
        # was in flight during a failure, so it must surface as failed
        fab.schedule_failure(nic.link_id, at=1e-3, recover_at=2e-3)
        fab.run_until_idle()
        assert [e[0] for e in rec.events] == [False]
        assert fab.links[nic.link_id].ops_failed + fab.links[nic.link_id].ops_completed <= 1


class TestFailDetectLatency:
    def test_post_to_failed_link_errors_after_detect_latency(self):
        fab = _fabric()
        nic = _nic(fab)
        fab.schedule_failure(nic.link_id, at=0.0, recover_at=1.0)
        fab.run_until(0.5)
        rec = _Recorder(fab)
        t_post = fab.now
        fab.post(nic.link_id, None, 1 << 20, rec)
        fab.run_until_idle()
        ok, err, t_cb = rec.events[0]
        assert not ok
        assert t_cb == pytest.approx(t_post + Fabric.FAIL_DETECT_LATENCY)

    def test_abort_surfaces_after_detect_latency(self):
        fab = _fabric()
        nic = _nic(fab)
        rec = _Recorder(fab)
        fab.post(nic.link_id, None, 100 << 20, rec)
        fab.schedule_failure(nic.link_id, at=1e-3, recover_at=1.0)
        fab.run_until_idle()
        _, _, t_cb = rec.events[0]
        assert t_cb == pytest.approx(1e-3 + Fabric.FAIL_DETECT_LATENCY)

    def test_error_ordering_vs_healthy_completions(self):
        """A short op on a healthy link posted at the failure instant
        completes before the failed op's error surfaces (the detection
        delay is what the engine's in-band retry must absorb)."""
        fab = _fabric()
        a, b = _nic(fab, 0, 0), _nic(fab, 0, 1)
        order = []
        fab.post(a.link_id, None, 100 << 20,
                 lambda ok, t0, t1, err: order.append(("a", ok)))
        fab.schedule_failure(a.link_id, at=1e-3, recover_at=1.0)
        fab.call_at(1e-3, lambda: fab.post(
            b.link_id, None, 1024, lambda ok, t0, t1, err: order.append(("b", ok))))
        fab.run_until_idle()
        assert order == [("b", True), ("a", False)]


class TestDegradeWindows:
    def test_multiplicative_overlap(self):
        fab = _fabric()
        nic = _nic(fab)
        link = fab.links[nic.link_id]
        fab.schedule_degradation(nic.link_id, at=0.0, until=1.0, factor=0.5)
        fab.schedule_degradation(nic.link_id, at=0.5, until=1.5, factor=0.5)
        bw = nic.bandwidth
        assert link.effective_bandwidth(0.25) == pytest.approx(0.5 * bw)
        assert link.effective_bandwidth(0.75) == pytest.approx(0.25 * bw)
        assert link.effective_bandwidth(1.25) == pytest.approx(0.5 * bw)
        assert link.effective_bandwidth(2.0) == pytest.approx(bw)

    def test_expired_windows_are_pruned(self):
        fab = _fabric()
        nic = _nic(fab)
        link = fab.links[nic.link_id]
        for i in range(10):
            fab.schedule_degradation(nic.link_id, at=i * 0.1, until=i * 0.1 + 0.05, factor=0.9)
        assert len(link.degrade_windows) == 10
        link.effective_bandwidth(0.57)  # six windows fully expired by now
        assert len(link.degrade_windows) == 4
        link.effective_bandwidth(10.0)
        assert link.degrade_windows == []

    def test_future_window_not_applied_early(self):
        fab = _fabric()
        nic = _nic(fab)
        link = fab.links[nic.link_id]
        fab.schedule_degradation(nic.link_id, at=1.0, until=2.0, factor=0.1)
        assert link.effective_bandwidth(0.5) == pytest.approx(nic.bandwidth)

    def test_fail_window_pruning(self):
        fab = _fabric()
        nic = _nic(fab)
        link = fab.links[nic.link_id]
        fab.schedule_failure(nic.link_id, at=0.1, recover_at=0.2)
        fab.schedule_failure(nic.link_id, at=0.4, recover_at=0.5)
        assert not link.is_failed(0.05)
        assert link.is_failed(0.15)
        assert not link.is_failed(0.3)  # first window pruned
        assert len(link.fail_windows) == 1
        assert link.is_failed(0.45)
        assert not link.is_failed(0.6)
        assert link.fail_windows == []


def _mk_tl(link_id, *, tier_bw=25e9, queued=0, excluded=False, failures=0):
    desc = LinkDesc(link_id=link_id, node=0, link_class=LinkClass.RDMA,
                    index=link_id, numa=0, bandwidth=tier_bw, base_latency=5e-6)
    tl = LinkTelemetry(desc=desc)
    tl.queued_bytes = queued
    tl.excluded = excluded
    tl.failures = failures
    return tl


class TestSoftExclusionFallback:
    def test_all_excluded_falls_back_to_cost_model(self):
        """Soft exclusion must not deadlock (scheduler.py): when every rail
        is excluded, the tier-feasible cost model chooses anyway."""
        pol = TentPolicy(store=TelemetryStore())
        cands = [
            Candidate(_mk_tl(0, queued=1 << 20, excluded=True), 1),
            Candidate(_mk_tl(1, queued=0, excluded=True), 1),
        ]
        chosen = pol.choose(cands, 64 << 10)
        assert chosen.link_id == 1  # least-queued wins despite exclusion

    def test_tier3_only_still_raises(self):
        pol = TentPolicy(store=TelemetryStore())
        cands = [Candidate(_mk_tl(0, excluded=True), 3)]  # tier-3 penalty inf
        with pytest.raises(TentError):
            pol.choose(cands, 64 << 10)

    def test_partial_exclusion_prefers_healthy(self):
        pol = TentPolicy(store=TelemetryStore())
        healthy = Candidate(_mk_tl(0, queued=8 << 20), 1)
        dead = Candidate(_mk_tl(1, queued=0, excluded=True), 1)
        assert pol.choose([healthy, dead], 64 << 10) is healthy

    def test_retry_chooser_reliability_order(self):
        mon = HealthMonitor(TelemetryStore(), HealthConfig())
        flaky_t1 = Candidate(_mk_tl(0, failures=3), 1)
        clean_t1 = Candidate(_mk_tl(1, failures=0), 1)
        clean_t2 = Candidate(_mk_tl(2, failures=0), 2)
        chosen = mon.choose_retry([clean_t2, flaky_t1, clean_t1], exclude_links=())
        assert chosen is clean_t1  # low tier first, then fewest failures

    def test_retry_chooser_excluded_fallback(self):
        """With every candidate soft-excluded, retries still pick the
        least-failed rail (liveness over latency, resilience.py)."""
        mon = HealthMonitor(TelemetryStore(), HealthConfig())
        a = Candidate(_mk_tl(0, excluded=True, failures=5), 1)
        b = Candidate(_mk_tl(1, excluded=True, failures=1), 1)
        assert mon.choose_retry([a, b], exclude_links=()) is b
        # the just-failed link is hard-excluded even then
        assert mon.choose_retry([a, b], exclude_links=(1,)) is a
        assert mon.choose_retry([b], exclude_links=(1,)) is None
