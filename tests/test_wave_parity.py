"""Wave-scheduler and array-store regression tier.

The vectorized hot path (array-backed `TelemetryStore`, cached
`StageCandidates`, `TentPolicy.choose_wave`, batched fabric posts) must be a
pure *cost* change: every scheduling decision, queue charge, and fabric
event has to be bit-identical to the pre-wave one-slice loop, which stays in
the engine as the scalar fallback (`EngineConfig.wave=False,
candidate_cache=False`). These tests pin that equivalence end-to-end, plus
the struct-of-arrays store's view/dict round-trips through the cluster
hooks (`apply_global` / `clear_global`)."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    FabricSpec,
    LinkTelemetry,
    TelemetryStore,
    TentEngine,
    Topology,
)
from repro.core.engine import WAVE_MIN
from repro.scenarios import ScenarioRunner, get


# ---------------------------------------------------------------------------
# Array-backed store: view round-trips and the cluster write hooks
# ---------------------------------------------------------------------------


def _store_with_links(n=4):
    store = TelemetryStore()
    topo = Topology(FabricSpec())
    return store, [store.ensure(l) for l in topo.links[:n]]


class TestArrayStore:
    def test_view_writes_land_in_arrays_and_back(self):
        store, (tl, *_) = _store_with_links(1)
        tl.queued_bytes = 123
        tl.beta1 = 2.5
        tl.excluded = True
        tl.consecutive_slow = 2
        slot = tl.slot
        assert store.queued_arr[slot] == 123
        assert store.beta1_arr[slot] == 2.5
        assert bool(store.excluded_arr[slot]) is True
        store.queued_arr[slot] = 77
        store.excluded_arr[slot] = False
        assert tl.queued_bytes == 77 and tl.excluded is False
        assert tl.consecutive_slow == 2

    def test_slot_map_stable_across_growth(self):
        """Slots must never move: StageCandidates caches them forever."""
        store = TelemetryStore()
        topo = Topology(FabricSpec(n_nodes=4))  # enough links to force regrowth
        views = [store.ensure(l) for l in topo.links]
        views[0].queued_bytes = 11
        views[3].beta0 = 0.5
        for v in views:  # registration grew the arrays several times
            assert store.slot_of(v.desc.link_id) == v.slot
        assert views[0].queued_bytes == 11
        assert views[3].beta0 == 0.5
        assert store.n == len(topo.links)

    def test_standalone_view_has_private_store(self):
        topo = Topology(FabricSpec())
        a = LinkTelemetry(desc=topo.links[0], beta0=0.1)
        b = LinkTelemetry(desc=topo.links[0], beta0=0.9)
        a.queued_bytes = 5
        assert b.queued_bytes == 0  # no shared arrays between standalone views
        assert a.beta0 == 0.1 and b.beta0 == 0.9

    def test_apply_global_clear_global_round_trip(self):
        store, (tl, *_) = _store_with_links(2)
        store.global_weight = 0.5
        lid = tl.desc.link_id
        store.apply_global({lid: 400})
        assert store.effective_queue(tl) == 0 + 0.5 * 400
        assert store.remote_pressure(lid) == 200.0
        tl.queued_bytes = 100  # array write must compose with the dict view
        assert store.effective_queue(tl) == 100 + 0.5 * 400
        store.clear_global()
        assert store.global_load == {}
        assert store.effective_queue(tl) == 100.0
        assert store.remote_pressure(lid) == 0.0
        # re-apply after clear: a rejoining engine starts clean
        store.apply_global({lid: 64})
        assert store.remote_pressure(lid) == 32.0

    def test_snapshot_reads_queue_array(self):
        store, (a, b, c, _) = _store_with_links(4)
        a.queued_bytes = 10
        c.on_schedule(7)
        store.charge_remote(999, 5)
        snap = store.snapshot()
        assert snap == {a.desc.link_id: 10, c.desc.link_id: 7, 999: 5}
        assert all(isinstance(v, int) for v in snap.values())

    def test_excluded_link_ids_vectorized_scan(self):
        store, views = _store_with_links(4)
        views[1].excluded = True
        views[3].excluded = True
        assert store.excluded_link_ids() == [
            views[1].desc.link_id, views[3].desc.link_id]

    def test_reset_all_vectorized(self):
        store, views = _store_with_links(3)
        for v in views:
            v.beta1 = 9.0
            v.consecutive_slow = 5
        store.reset_all()
        for v in views:
            assert v.beta1 == 1.0
            assert v.consecutive_slow == 0
            assert v.beta0 == v.beta0_prior


# ---------------------------------------------------------------------------
# Kernel parity: seeded randomized sweep (the hypothesis twin of this test
# in tests/test_properties.py explores adversarially; this one runs with no
# optional deps so every environment checks the equivalence)
# ---------------------------------------------------------------------------


class TestKernelParitySweep:
    def test_wave_kernel_replays_scalar_choose_randomized(self):
        from repro.core import Candidate, TentPolicy
        from repro.core.scheduler import tent_choose_wave
        from repro.core.topology import LinkDesc
        from repro.core.types import LinkClass

        rng = np.random.default_rng(7)
        tier_penalty = {1: 1.0, 2: 3.0}
        for case in range(200):
            n = int(rng.integers(2, 9))
            queues = rng.integers(0, 1 << 30, size=n)
            tiers = rng.choice([1, 2], size=n)
            excluded = rng.random(size=n) < 0.25
            beta0s = rng.uniform(0.0, 1e-2, size=n)
            beta1s = rng.uniform(0.05, 50.0, size=n)
            weight = float(rng.choice([0.0, 0.6]))
            lengths = rng.integers(1, 1 << 22, size=int(rng.integers(1, 25)))
            rr0 = int(rng.integers(0, 50))
            gamma = float(rng.choice([0.0, 0.05, 0.3]))

            def build():
                store = TelemetryStore()
                cands = []
                for i in range(n):
                    desc = LinkDesc(link_id=i, node=0, link_class=LinkClass.RDMA,
                                    index=i, numa=0, bandwidth=25e9,
                                    base_latency=5e-6)
                    tl = store.ensure(desc)
                    tl.queued_bytes = int(queues[i])
                    tl.beta0 = beta0s[i]
                    tl.beta1 = beta1s[i]
                    tl.excluded = bool(excluded[i])
                    cands.append(Candidate(tl, int(tiers[i])))
                store.global_weight = weight
                store.global_load = {i: int(queues[(i + 1) % n]) for i in range(n)}
                return store, cands

            store_a, cands_a = build()
            store_b, cands_b = build()
            policy = TentPolicy(gamma=gamma, store=store_a,
                                tier_penalty=dict(tier_penalty))
            policy._rr = rr0
            scalar = [cands_a.index(policy.choose(cands_a, int(L)))
                      for L in lengths]
            choices, queued_at, queued_out, rr_out = tent_choose_wave(
                queues,
                np.asarray([weight * store_b._foreign_load(i) if weight > 0
                            else 0.0 for i in range(n)]),
                np.zeros(n),
                np.full(n, 25e9), beta0s, beta1s,
                np.asarray([tier_penalty[t] for t in tiers]),
                excluded, lengths, rr0, gamma)
            assert list(choices) == scalar, f"case {case}"
            assert rr_out == policy._rr, f"case {case}"
            assert [int(c.telemetry.queued_bytes) for c in cands_a] == \
                [int(v) for v in queued_out], f"case {case}"


# ---------------------------------------------------------------------------
# Wave vs scalar engine: bit-identical scenario outcomes
# ---------------------------------------------------------------------------


def _policies(spec) -> dict:
    doc = ScenarioRunner(spec).run().to_dict()
    for rep in doc["policies"].values():
        # wave count is the one legitimately mode-dependent observable
        rep["extra"].pop("waves", None)
    return doc["policies"]


class TestWaveScalarBitIdentity:
    @pytest.mark.parametrize("name", ["single_rail_flap", "multi_engine_kv_incast"])
    def test_reports_identical(self, name):
        """Same spec, wave on vs the pre-wave loop: every metric — byte
        counts, makespans, latency percentiles, retries, per-rail byte maps
        — must match exactly (same decisions => same fabric event
        sequence). Covers the retry/exclusion interleave (flap) and the
        omega-blend cluster path (kv_incast)."""
        spec = get(name)
        wave = _policies(spec)
        scalar = _policies(dataclasses.replace(
            spec,
            engine=dataclasses.replace(
                spec.engine, wave=False, candidate_cache=False)))
        assert wave == scalar


# ---------------------------------------------------------------------------
# Wave dispatch mechanics
# ---------------------------------------------------------------------------


def _host(node, numa=0):
    from repro.core import Location, MemoryKind

    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


class TestWaveDispatch:
    def test_elephant_burst_uses_waves(self):
        eng = TentEngine(
            FabricSpec(), config=EngineConfig(max_inflight=4096), seed=3)
        src = eng.register_segment(_host(0), 64 << 20, materialize=False)
        dst = eng.register_segment(_host(1), 64 << 20, materialize=False)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 64 << 20)
        assert res.ok
        assert eng.waves >= 1
        assert eng.slices_issued >= 512  # decomposed elephant all issued

    def test_small_runs_take_scalar_path(self):
        """Below WAVE_MIN the dispatcher must not pay kernel setup: a
        single-slice transfer schedules without a wave."""
        eng = TentEngine(FabricSpec(), seed=3)
        src = eng.register_segment(_host(0), 4096, materialize=False)
        dst = eng.register_segment(_host(1), 4096, materialize=False)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 4096)
        assert res.ok
        assert eng.waves == 0
        assert eng.slices_issued == 1
        assert WAVE_MIN > 1

    def test_stage_cache_shared_across_transfers(self):
        eng = TentEngine(FabricSpec(), seed=3)
        src = eng.register_segment(_host(0), 8 << 20, materialize=False)
        dst = eng.register_segment(_host(1), 8 << 20, materialize=False)
        for _ in range(3):
            res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 8 << 20)
            assert res.ok
        # one direct rdma stage, cached once, reused by all three transfers
        assert len(eng._stage_cache) == 1
        sc = next(iter(eng._stage_cache.values()))
        assert sc.path_by_link.keys() == {p.local.link_id for p in sc.paths}

    def test_tagged_post_many_delivers_failures(self):
        """Batched posts on a failed link must surface per-op tagged error
        completions (the engine's retry path depends on them)."""
        from repro.core import Fabric

        topo = Topology(FabricSpec())
        fab = Fabric(topo, seed=0)
        lid = topo.rdma_nic(0, 0).link_id
        fab.schedule_failure(lid, at=0.0, recover_at=1.0)
        fab.run_until(0.0)
        got = []
        fab.post_many(
            [(lid, None, 1024, 0.0, 1.0, "a"), (lid, None, 2048, 0.0, 1.0, "b")],
            lambda tag, ok, t0, t1, err: got.append((tag, ok, err)),
        )
        fab.run_until_idle()
        assert got == [("a", False, "LinkFailed"), ("b", False, "LinkFailed")]

    def test_wave_counters_surface_in_reports(self):
        rep = ScenarioRunner(get("uniform_spray")).run_policy("tent")
        assert rep.extra["slices_issued"] > 0
        assert rep.extra["waves"] >= 0

    def test_mid_wave_batch_failure_drops_remaining_runs(self, monkeypatch):
        """If an earlier run of a wave fails its batch (scalar issue with
        substitution exhausted), later runs must drop that batch's slices
        exactly like the one-slice loop's pop-time check — no posts, no
        queue charges for a dead batch."""
        from repro.core import TentError, TransportPlan
        from repro.core.types import Location, MemoryKind

        eng = TentEngine(
            FabricSpec(), config=EngineConfig(max_inflight=4096), seed=0)
        # transfer A: 1 host slice (scalar run); B: 128-slice GPU elephant
        # on a different stage, grouped into the same wave behind A
        a_src = eng.register_segment(_host(0), 4096, materialize=False)
        a_dst = eng.register_segment(_host(1), 4096, materialize=False)
        gpu0 = Location(node=0, kind=MemoryKind.DEVICE_HBM, device=0, numa=0)
        gpu1 = Location(node=0, kind=MemoryKind.DEVICE_HBM, device=5, numa=1)
        b_src = eng.register_segment(gpu0, 8 << 20, materialize=False)
        b_dst = eng.register_segment(gpu1, 8 << 20, materialize=False)

        real_choose = eng.policy.choose
        monkeypatch.setattr(
            eng.policy, "choose",
            lambda cands, length: (_ for _ in ()).throw(
                TentError("NoEligibleDevice", "forced")) if length == 4096
            else real_choose(cands, length))
        monkeypatch.setattr(TransportPlan, "substitute", lambda self: False)

        b = eng.allocate_batch()
        eng.submit_transfer(b, [
            (a_src.segment_id, 0, a_dst.segment_id, 0, 4096),
            (b_src.segment_id, 0, b_dst.segment_id, 0, 8 << 20),
        ])
        state, _ = eng.get_transfer_status(b)
        assert state.value == "failed"
        assert eng.slices_issued == 0  # B's wave never posted
        assert eng.waves == 0
        assert all(tl.queued_bytes == 0 for _, tl in eng.store.items())
