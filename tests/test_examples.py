"""Smoke-run every `examples/*.py` so examples cannot silently rot.

All examples are launched concurrently (they are independent processes on
independent virtual clocks) and each test then waits on its own process, so
the wall cost of this module is roughly the slowest single example rather
than the sum."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
EXAMPLES = sorted((REPO / "examples").glob("*.py"))
TIMEOUT = 600


def test_example_set_is_discovered():
    names = {p.name for p in EXAMPLES}
    assert "quickstart.py" in names
    assert "multi_engine.py" in names  # the cluster control-plane example
    assert len(EXAMPLES) >= 5


@pytest.fixture(scope="module")
def example_procs():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    procs = {
        path.name: subprocess.Popen(
            [sys.executable, str(path)], cwd=REPO, env=env, text=True,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        )
        for path in EXAMPLES
    }
    yield procs
    for p in procs.values():
        if p.poll() is None:
            p.kill()


@pytest.mark.parametrize("name", [p.name for p in EXAMPLES])
def test_example_runs_clean(example_procs, name):
    p = example_procs[name]
    try:
        out, _ = p.communicate(timeout=TIMEOUT)
    except subprocess.TimeoutExpired:
        p.kill()
        out, _ = p.communicate()
        pytest.fail(f"{name} timed out after {TIMEOUT}s\n...{out[-2000:]}")
    assert p.returncode == 0, f"{name} exited {p.returncode}\n...{out[-4000:]}"
