"""Serving-substrate integration tests: HiCache tiering over TENT, the
checkpoint engine, and real-compute disaggregated generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import EngineConfig, FabricSpec, TentEngine
from repro.models import init_params
from repro.serving import (
    CheckpointEngine,
    DisaggregatedServer,
    HiCache,
    ServeSimConfig,
    ServingSimulator,
    from_table2,
    kv_bytes_per_token,
    make_cpu_pool,
    make_disk_pool,
    make_gpu_pool,
    monolithic_generate,
)
from repro.training import flatten_state


def _hicache(engine, cfg, *, gpu_pages=8, cpu_pages=32, disk_pages=64, page_tokens=16):
    pb = kv_bytes_per_token(cfg) * page_tokens
    return HiCache(
        engine,
        cfg,
        gpu_pool=make_gpu_pool(engine, 0, 0, page_bytes=pb, num_pages=gpu_pages),
        cpu_pool=make_cpu_pool(engine, 1, page_bytes=pb, num_pages=cpu_pages),
        disk_pool=make_disk_pool(engine, 1, page_bytes=pb, num_pages=disk_pages),
        page_tokens=page_tokens,
    )


class TestHiCache:
    def test_insert_then_fetch_hits(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        hc = _hicache(eng, cfg)
        tokens = list(range(64))
        hc.insert(tokens)
        res = hc.fetch_prefix(tokens)
        assert res.prefix_tokens == 64
        assert res.promoted_pages == 0  # already on GPU
        assert hc.hits == 1

    def test_eviction_demotes_and_refetch_promotes(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        hc = _hicache(eng, cfg, gpu_pages=4)
        # fill beyond GPU capacity: oldest pages demote to CPU tier
        first = list(range(64))  # 4 pages
        hc.insert(first)
        second = list(range(1000, 1064))
        hc.insert(second)
        counts = hc.tier_counts()
        assert counts["gpu"] == 4 and counts["cpu"] + counts["disk"] == 4
        # fetching the first conversation promotes its pages back up
        res = hc.fetch_prefix(first)
        assert res.prefix_tokens == 64
        assert res.promoted_pages > 0
        assert res.transfer_seconds > 0  # promotion really crossed the fabric

    def test_partial_prefix(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        hc = _hicache(eng, cfg)
        tokens = list(range(64))
        hc.insert(tokens)
        extended = tokens + list(range(5000, 5032))
        res = hc.fetch_prefix(extended)
        assert res.prefix_tokens == 64  # only the cached prefix

    def test_serving_sim_hicache_beats_baseline(self):
        cfg = get_smoke_config("qwen2-0.5b")
        sim_cfg = ServeSimConfig(clients=4, concurrency=2, turns=5, input_tokens=256,
                                 output_tokens=16)
        perf = from_table2()
        # baseline: no cache
        eng0 = TentEngine(FabricSpec())
        base = ServingSimulator(eng0, perf, hicache=None, sim_cfg=sim_cfg).run()
        # hicache via TENT
        eng1 = TentEngine(FabricSpec())
        hc = _hicache(eng1, cfg, gpu_pages=64, cpu_pages=256, disk_pages=512, page_tokens=64)
        cached = ServingSimulator(eng1, perf, hicache=hc, sim_cfg=sim_cfg).run()
        assert cached.input_throughput > base.input_throughput
        assert cached.round_avg_ttft[5] < base.round_avg_ttft[5]


class TestCheckpointEngine:
    def test_update_moves_real_weights(self):
        cfg = get_smoke_config("qwen2-0.5b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        table = flatten_state(params)
        eng = TentEngine(FabricSpec())
        ce = CheckpointEngine(eng, nodes=2, gpus_per_node=8)
        ce.register_checkpoint(table)
        res = ce.update(verify=True)
        assert res.seconds > 0
        assert res.bytes >= sum(v.nbytes for v in table.values())
        assert res.ranks == 16

    def test_tent_policy_not_slower_than_round_robin(self):
        # elephant-flow checkpoint (256 MB) so slice spraying has room to act
        table = {"w": np.random.default_rng(0).integers(0, 255, 256 << 20, np.uint8)}
        times = {}
        for policy in ("tent", "round_robin"):
            eng = TentEngine(FabricSpec(), config=EngineConfig(policy=policy), seed=3)
            # one rail is degraded — the telemetry-driven engine must route around
            nic = eng.topology.rdma_nic(0, 2)
            eng.fabric.schedule_degradation(nic.link_id, at=0.0, until=1e9, factor=0.15)
            ce = CheckpointEngine(eng, nodes=2, gpus_per_node=8)
            ce.register_checkpoint(table)
            times[policy] = ce.update().seconds
        assert times["tent"] <= times["round_robin"] * 1.02, times


@pytest.mark.slow
class TestDisaggregation:
    @pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m", "hymba-1.5b"])
    def test_matches_monolithic(self, arch):
        cfg = get_smoke_config(arch).with_(remat="none")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
        eng = TentEngine(FabricSpec())
        server = DisaggregatedServer(eng, cfg, params)
        res = server.generate(prompt, n_new=6, max_len=32)
        ref = monolithic_generate(cfg, params, prompt, n_new=6, max_len=32)
        np.testing.assert_array_equal(res.tokens, ref)
        assert res.kv_transfer_seconds > 0
        assert res.kv_bytes > 0
